"""MoE bulk-steal routing: the paper's technique inside the model.

Properties (hypothesis): no two assignments land in the same (expert,
slot); the steal is DROPLESS whenever total slack covers the overflow;
disabling the steal reproduces the GShard drop baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.models.moe import route_with_bulk_steal


def _route(seed, T, E, k, cap_factor, bulk):
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed), (T, E)) * 2.0, -1)
    capacity = max(int(T * k / E * cap_factor), k)
    return route_with_bulk_steal(probs, k, capacity, bulk_steal=bulk), capacity


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 64]),
       st.sampled_from([4, 8]), st.sampled_from([1, 2]))
def test_no_slot_collisions(seed, T, E, k):
    (expert, slot, w, valid), cap = _route(seed, T, E, k, 1.25, True)
    keys = np.asarray(expert) * cap + np.asarray(slot)
    keys = keys[np.asarray(valid)]
    assert len(keys) == len(set(keys.tolist())), "two tokens share a slot"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_dropless_when_slack_exists(seed):
    """capacity_factor >= 1 x top_k/E ratio => total slots >= assignments,
    so the bulk steal must place EVERY assignment."""
    T, E, k = 64, 8, 2
    (expert, slot, w, valid), cap = _route(seed, T, E, k, 1.0, True)
    assert cap * E >= T * k
    assert bool(jnp.all(valid)), "bulk steal dropped despite global slack"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_drop_baseline_loses_overflow(seed):
    """Skewed routing + no steal => drops; with steal => none."""
    T, E, k = 128, 8, 2
    # force skew: logits concentrated on expert 0
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    logits = logits.at[:, 0].add(4.0)
    probs = jax.nn.softmax(logits, -1)
    capacity = int(T * k / E)  # exactly enough slots globally
    _, _, _, valid_drop = route_with_bulk_steal(probs, k, capacity,
                                                bulk_steal=False)
    _, _, _, valid_steal = route_with_bulk_steal(probs, k, capacity,
                                                 bulk_steal=True)
    dropped = int(jnp.sum(~valid_drop))
    stolen_ok = int(jnp.sum(valid_steal))
    assert dropped > 0, "expected overflow in the skewed baseline"
    assert stolen_ok == T * k, "bulk steal should rescue every assignment"


def test_stolen_tokens_go_to_underloaded_experts():
    T, E, k = 64, 4, 1
    logits = jnp.zeros((T, E)).at[:, 0].add(5.0)  # everyone wants expert 0
    probs = jax.nn.softmax(logits, -1)
    capacity = T // E
    (expert, slot, w, valid), _ = (
        route_with_bulk_steal(probs, k, capacity, bulk_steal=True), None)
    counts = np.bincount(np.asarray(expert), minlength=E)
    assert counts.max() <= capacity
    assert bool(jnp.all(valid))
