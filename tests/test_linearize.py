"""The linearizability model checker (repro.analysis.linearize):
quick exhaustive sweep over all four backends, and — the part that
keeps the checker honest — seeded mutations of the relaxed reconcile
MUST be caught."""

import pytest

from repro.analysis import linearize


def test_quick_sweep_all_backends_linearizable():
    histories, violations = linearize.check_all(
        linearize.ALL_BACKENDS, geometries=((4, 2),), verbose=False)
    assert histories > 0
    assert violations == [], violations[:3]


def test_fenced_backends_exact_on_larger_ring():
    histories, violations = linearize.check_all(
        linearize.FENCED_BACKENDS, geometries=((8, 4),), verbose=False)
    assert histories > 0
    assert violations == [], violations[:3]


@pytest.mark.parametrize("name", sorted(linearize.MUTATIONS))
def test_seeded_mutations_are_caught(name):
    """Each seeded bug in the reconcile step must produce at least one
    violating history — otherwise the checker proves nothing."""
    _, violations = linearize.check_backend(
        "relaxed", capacity=4, max_steal=2,
        reconcile_fn=linearize.MUTATIONS[name])
    assert violations, f"mutation '{name}' survived the sweep undetected"


def test_mutation_split_actually_enumerates_interposed_owners():
    """Regression for the checker bug class that hides relaxed races:
    the read/reconcile split must happen BEFORE interleaving so owner
    ops can land between the two halves."""
    steps = linearize.expand_stealer([("steal_exact", 2)], split=True)
    assert [kind for kind, _ in steps] == ["read", "reconcile"]
    merged = list(linearize.interleavings([("pop",)], steps))
    assert [("read", ("steal_exact", 2)),
            ("owner", ("pop",)),
            ("reconcile", ("steal_exact", 2))] in merged


def test_cli_quick_exits_zero():
    assert linearize.main(["--quick"]) == 0


def test_cli_mutate_exits_zero_when_all_caught():
    assert linearize.main(["--mutate"]) == 0
