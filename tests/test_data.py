"""Data pipeline: determinism, resume-exactness, and steal conservation."""

import numpy as np

from repro.data.pipeline import WorkStealingPipeline
from repro.data.synthetic import SynthDataset, synth_batch


def test_synth_deterministic():
    a = synth_batch(7, 3, 11, 4, 16, 1000)
    b = synth_batch(7, 3, 11, 4, 16, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(7, 3, 12, 4, 16, 1000)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_dataset_state_resume():
    ds = SynthDataset(seed=1, shard=0, n_shards=4, batch=2, seq=8, vocab=100)
    for _ in range(5):
        ds.next()
    state = ds.state()
    next_a = ds.next()
    ds2 = SynthDataset.from_state(state, n_shards=4, batch=2, seq=8,
                                  vocab=100)
    next_b = ds2.next()
    np.testing.assert_array_equal(next_a["tokens"], next_b["tokens"])


def test_pipeline_serves_and_conserves():
    seen = []
    pipe = WorkStealingPipeline(
        n_hosts=3,
        make_batch=lambda shard, step: seen.append((shard, step))
        or {"shard": shard, "step": step},
        prefetch=8)
    for i in range(30):
        pipe.next_batch(i % 3)
    assert len(seen) == 30
    assert len(set(seen)) == 30, "a task descriptor was served twice"


def test_master_steal_moves_tasks():
    pipe = WorkStealingPipeline(
        n_hosts=2, make_batch=lambda s, t: {"s": s, "t": t}, prefetch=16)
    pipe.queues[0].refill()
    pipe.queues[1].refill()
    before = [len(q.q) for q in pipe.queues]
    moved = pipe.master.rebalance(slow=[0], fast=[1])
    after = [len(q.q) for q in pipe.queues]
    assert moved > 0
    assert sum(before) == sum(after), "steal lost/duplicated tasks"
    assert after[0] < before[0] and after[1] > before[1]
