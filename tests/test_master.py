"""Tests for the virtual-master rebalancing superstep.

Collectives are exercised through ``jax.vmap(axis_name=...)`` which gives the
exact SPMD semantics on one CPU device; the multi-device shard_map path is
covered by tests/test_master_spmd.py (subprocess with fake devices) and by
the production dry-run.
"""

import functools
from dataclasses import replace as dataclasses_replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.core import ops as bulk_ops
from repro.core.master import superstep
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import make_sharded_queues, vmapped_superstep

SPEC = jax.ShapeDtypeStruct((), jnp.int32)
OPS = bulk_ops.make_ops("reference")


def fill(qs, sizes):
    """Fill worker i with ``sizes[i]`` distinct task ids."""
    W = len(sizes)
    nxt = 1
    for i, n in enumerate(sizes):
        vals = np.zeros((max(sizes) + 1,), np.int32)
        vals[:n] = range(nxt, nxt + n)
        nxt += n
        qi = jax.tree_util.tree_map(lambda x: x[i], qs)
        qi, _ = OPS.push(qi, jnp.asarray(vals), n)
        qs = jax.tree_util.tree_map(
            lambda full, one: full.at[i].set(one), qs, qi
        )
    return qs, nxt - 1


def totals(qs):
    """Multiset of live task ids across all workers."""
    out = []
    W = qs.size.shape[0]
    for i in range(W):
        qi = jax.tree_util.tree_map(lambda x: x[i], qs)
        while int(qi.size) > 0:
            qi, item, valid = OPS.pop(qi)
            assert bool(valid)
            out.append(int(item))
    return out


def test_superstep_moves_work_to_idle():
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6, max_steal=16)
    qs = make_sharded_queues(4, 64, SPEC)
    qs, n_total = fill(qs, [20, 0, 0, 12])
    step = vmapped_superstep(pol)
    qs, stats = step(qs)
    sizes = np.asarray(qs.size)
    assert sizes.sum() == n_total  # conservation
    assert sizes[1] > 0 and sizes[2] > 0  # both idle lanes got work
    assert sizes[0] == 10  # victim 0 donated floor(20*0.5)
    assert sizes[3] == 6


def test_superstep_noop_when_balanced():
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6, max_steal=16)
    qs = make_sharded_queues(4, 64, SPEC)
    qs, n_total = fill(qs, [4, 5, 4, 5])
    step = vmapped_superstep(pol)
    qs2, stats = step(qs)
    np.testing.assert_array_equal(np.asarray(qs2.size), np.asarray(qs.size))
    assert int(stats.n_transferred[0]) == 0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=2, max_size=6), st.integers(1, 4))
def test_superstep_conserves_tasks(sizes, rounds):
    W = len(sizes)
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8, max_steal=32)
    qs = make_sharded_queues(W, 128, SPEC)
    qs, n_total = fill(qs, sizes)
    ids_before = sorted(totals(qs))
    qs = make_sharded_queues(W, 128, SPEC)
    qs, _ = fill(qs, sizes)
    step = vmapped_superstep(pol)
    for _ in range(rounds):
        qs, _ = step(qs)
    ids_after = sorted(totals(qs))
    assert ids_after == ids_before  # nothing lost, duplicated, or invented


def test_superstep_reduces_imbalance():
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8, max_steal=64)
    qs = make_sharded_queues(8, 256, SPEC)
    sizes = [100, 0, 0, 0, 0, 0, 0, 0]
    qs, _ = fill(qs, sizes)
    step = vmapped_superstep(pol)
    for _ in range(6):
        qs, _ = step(qs)
    s = np.asarray(qs.size)
    assert s.sum() == 100
    assert s.max() <= 60  # load spread out
    assert (s > 0).sum() >= 4


# ---------------------------------------------------------------------------
# Compact vs dense exchange: same plan, same queues, W x less payload
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=2, max_size=6),
       st.integers(1, 4), st.sampled_from(["reference", "auto", "relaxed"]))
def test_compact_exchange_matches_dense_oracle(sizes, rounds, backend):
    """The compact exchange must produce bit-identical queues to the
    dense-exchange oracle from any starting state, on both the reference
    backend and the geometry-resolved auto routing (which exercises the
    fused ring_transfer kernel where the geometry admits it)."""
    W = len(sizes)
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32, backend=backend)
    results = {}
    for exchange in ("compact", "dense"):
        qs = make_sharded_queues(W, 128, SPEC)
        qs, _ = fill(qs, sizes)
        step = vmapped_superstep(
            dataclasses_replace(pol, exchange=exchange))
        for _ in range(rounds):
            qs, stats = step(qs)
        results[exchange] = (qs, stats)
    qc, sc = results["compact"]
    qd, sd = results["dense"]
    np.testing.assert_array_equal(np.asarray(qc.size), np.asarray(qd.size))
    # identical live multisets, lane by lane (not just sizes)
    assert totals(qc) == totals(qd)
    for f in ("sizes_before", "sizes_after", "n_transferred", "n_steals"):
        np.testing.assert_array_equal(np.asarray(getattr(sc, f)),
                                      np.asarray(getattr(sd, f)))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=2, max_size=6),
       st.integers(1, 5))
def test_compact_exchange_conserves_tasks(sizes, rounds):
    """No task lost, duplicated, or invented across randomized compact
    rounds (the dense-path conservation property, re-asserted on the
    compact path on its own)."""
    W = len(sizes)
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32, exchange="compact")
    qs = make_sharded_queues(W, 128, SPEC)
    qs, _ = fill(qs, sizes)
    ids_before = sorted(totals(qs))
    qs = make_sharded_queues(W, 128, SPEC)
    qs, _ = fill(qs, sizes)
    step = vmapped_superstep(pol)
    for _ in range(rounds):
        qs, _ = step(qs)
    assert sorted(totals(qs)) == ids_before


def test_compact_zero_transfer_fast_path():
    """A balanced round plans no transfers: the compact exchange reports
    zero exchange payload (the lax.cond skipped the collective) while
    the dense exchange still pays the full W * max_steal outbox."""
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6,
                      max_steal=16)
    item_bytes = 4  # one int32 per item (SPEC)
    for exchange, expected in (("compact", 0),
                               ("dense", 4 * 16 * item_bytes)):
        qs = make_sharded_queues(4, 64, SPEC)
        qs, _ = fill(qs, [4, 5, 4, 5])  # balanced: no (victim, thief) pair
        step = vmapped_superstep(dataclasses_replace(pol, exchange=exchange))
        qs2, stats = step(qs)
        np.testing.assert_array_equal(np.asarray(qs2.size),
                                      np.asarray(qs.size))
        assert int(stats.n_transferred[0]) == 0
        assert int(stats.bytes_moved[0]) == expected


def test_compact_payload_is_w_times_smaller():
    """On a round that DOES move work, the dense exchange injects exactly
    W x the compact exchange's payload per lane (the Fig. 10 claim)."""
    W, max_steal = 8, 16
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6,
                      max_steal=max_steal)
    moved = {}
    for exchange in ("compact", "dense"):
        qs = make_sharded_queues(W, 64, SPEC)
        qs, _ = fill(qs, [20, 0, 0, 0, 12, 0, 0, 0])
        step = vmapped_superstep(dataclasses_replace(pol, exchange=exchange))
        qs, stats = step(qs)
        assert int(stats.n_transferred[0]) > 0
        moved[exchange] = int(stats.bytes_moved[0])
    assert moved["compact"] == max_steal * 4
    assert moved["dense"] == W * moved["compact"]
