"""PagedQueue spill/refill coverage: low-watermark boundary behaviour,
refill after a steal empties the device ring, and pushes larger than one
page (ISSUE 2 satellite — the host-paging layer had no direct tests)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queue import DEFAULT_QUEUE_LIMIT, PagedQueue

SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _batch(values):
    return jnp.asarray(np.asarray(values, np.int32))


def _pop_all(pq):
    out = []
    while True:
        item, valid = pq.pop()
        if not valid:
            break
        out.append(int(item))
    return out


def test_spill_then_drain_preserves_all_items():
    pq = PagedQueue(8, SPEC, low_watermark=2)
    pushed = []
    for base in range(0, 40, 5):
        vals = list(range(base, base + 5))
        pq.push(_batch(vals), 5)
        pushed.extend(vals)
    assert pq.total_size() == len(pushed)
    assert pq.pages, "overflow must have spilled to host pages"
    got = _pop_all(pq)
    assert sorted(got) == sorted(pushed)  # nothing lost or duplicated
    assert pq.total_size() == 0


def test_spill_on_nearly_empty_ring_never_oversteals():
    """Regression: overflowing a ring holding fewer than spill_n items
    used to run the spill steal with proportion > 1, driving the queue
    size negative and losing/duplicating tasks (the _steal_plan clamp
    and the capped spill proportion both guard this now)."""
    pq = PagedQueue(16, SPEC)  # _spill_n = 8
    pq.push(_batch(range(4)), 4)           # ring holds 4 < spill_n
    pq.push(_batch(range(100, 113)), 13)   # overflow: spill p would be 8/4
    assert int(pq.state.size) >= 0
    assert pq.total_size() == 17
    got = _pop_all(pq)
    assert sorted(got) == sorted(list(range(4)) + list(range(100, 113)))


def test_steal_plan_clamps_out_of_range_proportions():
    from repro.core.ops import _steal_plan

    for p, size, expect in [(2.0, 4, 4), (1.0, 4, 4), (-1.0, 4, 0),
                            (0.5, 10, 5), (3.0, 100, 32)]:
        n = int(_steal_plan(jnp.int32(size), p, queue_limit=0, max_steal=32))
        assert n == expect, (p, size, n)


def test_low_watermark_boundary_triggers_refill_exactly():
    pq = PagedQueue(8, SPEC, low_watermark=2)
    # One host page of 3, ring holding 4.  (The direct injection also
    # credits _net_in so the sanitizer's spill/refill audit stays
    # balanced when the suite runs under REPRO_CHECK=1.)
    pq.pages.append((np.arange(100, 103, dtype=np.int32), 3))
    pq._net_in += 3
    pq.push(_batch([1, 2, 3, 4]), 4)
    # size 4 > watermark 2: pop must NOT refill yet.
    item, valid = pq.pop()
    assert valid and len(pq.pages) == 1
    item, valid = pq.pop()
    assert valid and len(pq.pages) == 1
    # size now == watermark: next pop refills the page first.
    item, valid = pq.pop()
    assert valid
    assert not pq.pages
    assert int(pq.state.size) >= 3  # page contents spliced into the ring


def test_refill_after_steal_empties_device_ring():
    pq = PagedQueue(8, SPEC, low_watermark=2)
    for base in range(0, 24, 4):
        pq.push(_batch(list(range(base, base + 4))), 4)
    assert pq.pages
    # Steal everything the ring holds (proportion 1.0 consumes pages
    # first, then the device ring).
    got = pq.steal(1.0)
    assert sum(n for _, n in got) > 0
    remaining = pq.total_size()
    # The owner keeps popping: refill must pull any leftover pages back
    # into the (possibly emptied) ring.
    out = _pop_all(pq)
    assert len(out) == remaining
    assert pq.total_size() == 0 and not pq.pages


def test_push_larger_than_one_page():
    pq = PagedQueue(8, SPEC, low_watermark=2)
    # 20 items into a capacity-8 ring: the surplus beyond one spill must
    # land on host pages in one call.
    vals = list(range(20))
    pq.push(_batch(vals), 20)
    assert pq.total_size() == 20
    assert pq.pages, "surplus must be paged"
    got = _pop_all(pq)
    assert sorted(got) == vals


def test_steal_respects_queue_limit_on_device_ring():
    pq = PagedQueue(8, SPEC, low_watermark=0)
    pq.push(_batch([7]), 1)  # below DEFAULT_QUEUE_LIMIT
    assert int(pq.state.size) < DEFAULT_QUEUE_LIMIT or pq.pages == []
    got = pq.steal(1.0)
    assert got == []  # abort: the ring is under the paper's queue limit
    assert pq.total_size() == 1
