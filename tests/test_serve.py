"""Serving: prefill+decode consistency vs teacher-forced forward, the
wave engine, and the admission master's bulk-steal invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model
from repro.serve.engine import Replica, ServeCluster
from repro.serve.kv_cache import pad_cache
from repro.serve.scheduler import AdmissionMaster, Request
from repro.core.policy import StealPolicy


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-9b", "mamba2-2.7b",
                                  "zamba2-7b", "qwen3-moe-30b-a3b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy decode logits from (prefill + decode_step*) must match the
    teacher-forced forward pass at the same positions.

    MoE archs get a loose absolute tolerance: capacity routing is batch-
    dependent (the bulk steal reroutes overflow differently for a 2-token
    decode step than for the 40-token forward), a known property of
    capacity-based MoE inference.
    """
    cfg = configs.reduced(configs.get(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, extra = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 1,
                              cfg.vocab_size, jnp.int32)
    # cached path
    logits_p, cache = jax.jit(model.prefill)(params, toks[:, :S])
    cache = model.grow_cache(cache, S + extra)
    got = [logits_p[:, 0]]
    for t in range(extra - 1):
        lg, cache = jax.jit(model.decode_step)(params, cache,
                                               toks[:, S + t:S + t + 1])
        got.append(lg[:, 0])
    got = jnp.stack(got, axis=1)          # (B, extra, V)
    # teacher-forced path: hidden -> head at the same positions
    hidden = model.forward(params, toks)
    head = model._head(params).astype(model.cdtype)
    ref_all = jnp.einsum("bsd,dv->bsv", hidden, head).astype(jnp.float32)
    from repro.models.layers import softcap
    ref_all = softcap(ref_all, cfg.final_logit_softcap)
    ref = ref_all[:, S - 1:S + extra - 1]
    if cfg.n_experts:
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-1, rtol=0)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-2, rtol=2e-2)


def test_wave_engine_generates():
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep = Replica(model, params, wave_size=4, max_seq=64)
    reqs = [Request(prompt=[1, 2, 3], max_new=5) for _ in range(3)]
    done = rep.run_wave(reqs)
    assert all(len(r.output) == 5 for r in done)


def test_cluster_serves_all_with_straggler():
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reps = [Replica(model, params, wave_size=4, max_seq=64)
            for _ in range(2)]
    reps[0].speed = 0.25   # straggler
    # aggressive watermarks so the master keeps feeding the fast replica
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=2)
    cluster = ServeCluster(reps, AdmissionMaster(2, policy=pol))
    reqs = [Request(prompt=[1, 2], max_new=2) for _ in range(12)]
    cluster.submit(reqs)
    done = cluster.run_until_drained()
    assert len(done) == 12
    st_ = cluster.master.stats()
    assert st_["stolen"] > 0, "master never rebalanced the straggler"
    assert st_["completed"][1] > st_["completed"][0]


def test_cluster_on_device_admission_lanes():
    """``ServeCluster(execution="vmap")`` swaps the host AdmissionMaster
    for ``repro.distributed.RuntimeAdmissionMaster``: request IDs live
    on executor lanes, every rebalance is a real device superstep, and
    the cluster still serves everything (the "mesh" flavour of the same
    master is exercised on the 8-device lane by test_distributed.py)."""
    from repro.distributed.serve import RuntimeAdmissionMaster

    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reps = [Replica(model, params, wave_size=4, max_seq=64)
            for _ in range(2)]
    reps[0].speed = 0.25   # straggler
    cluster = ServeCluster(reps, rebalance_rounds=2, execution="vmap",
                           admission_capacity=64)
    assert isinstance(cluster.master, RuntimeAdmissionMaster)
    reqs = [Request(prompt=[1, 2], max_new=2) for _ in range(12)]
    cluster.submit(reqs)
    done = cluster.run_until_drained()
    assert len(done) == 12
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    st_ = cluster.master.stats()
    assert st_["execution"] == "vmap"
    assert st_["stolen"] > 0, "device master never rebalanced"
    # waves and REAL executor rounds share one telemetry stream
    tel = cluster.telemetry
    assert tel is cluster.master.runtime.telemetry
    assert len(tel.waves) > 0 and len(tel.rounds) > 0
    assert tel.total_served == 12


def test_cluster_waves_flow_through_executor_telemetry():
    """Every cluster tick appends one WaveRecord to the SAME telemetry
    stream the master's rebalance rounds write — one unified source."""
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reps = [Replica(model, params, wave_size=4, max_seq=64)
            for _ in range(2)]
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=2)
    cluster = ServeCluster(reps, AdmissionMaster(2, policy=pol),
                           rebalance_rounds=2)
    cluster.submit([Request(prompt=[1, 2], max_new=2) for _ in range(8)])
    done = cluster.run_until_drained()
    tel = cluster.telemetry
    assert tel is cluster.master.telemetry  # one stream, not a copy
    assert len(tel.waves) > 0
    assert tel.total_served == len(done) == 8
    assert tel.total_tokens > 0
    # each wave logged the post-wave per-replica loads
    assert all(len(w.loads) == 2 for w in tel.waves)
    summ = tel.summary()
    assert summ["waves"] == len(tel.waves)
    assert summ["served"] == 8
    # rebalance rounds landed in the same stream
    assert summ["rounds"] == len(tel.rounds) > 0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 20), min_size=1, max_size=12),
       st.integers(2, 5))
def test_admission_master_conserves_requests(batch_sizes, n_replicas):
    """No request lost or duplicated across admission + rebalance rounds."""
    master = AdmissionMaster(n_replicas)
    all_ids = set()
    for n in batch_sizes:
        reqs = [Request(prompt=[1], max_new=1) for _ in range(n)]
        all_ids.update(r.rid for r in reqs)
        master.submit(reqs)
        master.rebalance()
    seen = []
    for rq in master.replicas:
        while True:
            r = rq.q.pop()
            if r is None:
                break
            seen.append(r.rid)
    assert sorted(seen) == sorted(all_ids)
