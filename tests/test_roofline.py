"""Roofline extraction: collective-bytes HLO parsing on known snippets."""

import pytest

from repro.launch.roofline import collective_bytes, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[2,4,8]") == 2 * 4 * 8 * 2
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("token[]") == 0


def test_all_gather_result_bytes():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(f32[4,128]{1,0} %x), dimensions={0},
      replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}
"""
    out = collective_bytes(hlo, 16)
    expect = 64 * 128 * 4 * (15 / 16)
    assert abs(out["all-gather"] - expect) < 1


def test_all_reduce_ring_bytes():
    hlo = "%ar = f32[1024]{0} all-reduce(f32[1024]{0} %g), replica_groups={{0,1,2,3}}"
    out = collective_bytes(hlo, 4)
    expect = 2 * 1024 * 4 * (3 / 4)
    assert abs(out["all-reduce"] - expect) < 1


def test_permute_and_mixed():
    hlo = """
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %x), source_target_pairs={{0,1}}
  %rs = f32[16]{0} reduce-scatter(f32[64]{0} %y), replica_groups={{0,1,2,3}}
"""
    out = collective_bytes(hlo, 4)
    assert out["collective-permute"] == 8 * 8 * 2
    assert abs(out["reduce-scatter"] - 64 * 4 * 0.75) < 1
    assert out["total"] == pytest.approx(
        out["collective-permute"] + out["reduce-scatter"])


def test_ignores_non_collectives():
    hlo = "%d = f32[128,128]{1,0} dot(f32[128,128] %a, f32[128,128] %b)"
    assert collective_bytes(hlo, 8)["total"] == 0
