"""Unit + property tests for the JAX bulk work-stealing queue.

Every test drives the queue through a :class:`repro.core.ops.BulkOps`
backend and is parametrized over ``backend in ("reference", "auto",
"relaxed")`` — the paper's single-contract / many-implementations
discipline (``"relaxed"`` is the fence-free multiplicity-tolerant
variant, which must be observationally identical).  The
linearizability property tests mirror the paper's §III-B argument: for
any sequence of owner bulk-pushes / pops and stealer bulk-steals, the
queue behaves exactly like a sequential deque where the owner operates
at the head and the stealer detaches suffixes at the tail — no task is
lost, duplicated, or reordered.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.core import ops as bulk_ops

CAP = 64
SPEC = jax.ShapeDtypeStruct((), jnp.int32)
BACKENDS = ("reference", "auto", "relaxed")


@pytest.fixture(params=BACKENDS)
def ops(request):
    """A BulkOps backend for the standard CAP=64 test geometry."""
    return bulk_ops.make_ops(request.param, capacity=CAP, max_push=16,
                             max_pop=8, max_steal=64)


def batch_of(values):
    """Fixed-width batch buffer (width 16) holding ``values``."""
    buf = np.zeros((16,), np.int32)
    buf[: len(values)] = values
    return jnp.asarray(buf), len(values)


def test_make_ops_registry():
    assert set(BACKENDS) <= set(bulk_ops.available_backends())
    assert bulk_ops.make_ops("reference").resolved == "reference"
    assert bulk_ops.make_ops("pallas").resolved == "pallas"
    with pytest.raises(ValueError):
        bulk_ops.make_ops("no-such-backend")
    # an existing instance passes through unchanged
    o = bulk_ops.make_ops("reference")
    assert bulk_ops.make_ops(o) is o


def test_auto_resolves_once_from_geometry(monkeypatch):
    monkeypatch.delenv(bulk_ops.BACKEND_ENV_VAR, raising=False)
    # compatible geometry: kernel routing on
    o = bulk_ops.make_ops("auto", capacity=512, max_push=256, max_pop=256,
                          max_steal=256)
    assert (o.kernel_push, o.kernel_pop, o.kernel_steal) == (True,) * 3
    assert o.resolved == "pallas"
    # kernel-incompatible geometry: falls back to the reference routing
    o = bulk_ops.make_ops("auto", capacity=500, max_push=200, max_pop=200,
                          max_steal=200)
    assert o.resolved == "reference"
    # unknown geometry: conservative reference
    assert bulk_ops.make_ops("auto").resolved == "reference"


def test_auto_env_override(monkeypatch):
    monkeypatch.setenv(bulk_ops.BACKEND_ENV_VAR, "reference")
    o = bulk_ops.make_ops("auto", capacity=512, max_push=256, max_pop=256,
                          max_steal=256)
    assert o.resolved == "reference"
    # explicit names are never overridden
    assert bulk_ops.make_ops("pallas").resolved == "pallas"


def test_auto_incompatible_geometry_matches_reference():
    """'auto' on a kernel-incompatible geometry must produce results
    identical to the reference backend (it IS the reference routing)."""
    cap, max_steal = 100, 48  # not block-alignable
    auto = bulk_ops.make_ops("auto", capacity=cap, max_push=16,
                             max_pop=8, max_steal=max_steal)
    ref = bulk_ops.make_ops("reference")
    assert auto.resolved == "reference"
    qa = bulk_ops.make_queue(cap, SPEC)
    qr = bulk_ops.make_queue(cap, SPEC)
    b, n = batch_of(list(range(1, 13)))
    qa, na = auto.push(qa, b, n)
    qr, nr = ref.push(qr, b, n)
    assert int(na) == int(nr)
    qa, ba, nsa = auto.steal(qa, 0.4, max_steal=max_steal)
    qr, br, nsr = ref.steal(qr, 0.4, max_steal=max_steal)
    assert int(nsa) == int(nsr)
    np.testing.assert_array_equal(np.asarray(ba), np.asarray(br))
    qa, ba, npa = auto.pop_bulk(qa, 8, 5)
    qr, br, npr = ref.pop_bulk(qr, 8, 5)
    assert int(npa) == int(npr)
    np.testing.assert_array_equal(np.asarray(ba), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(qa.buf), np.asarray(qr.buf))
    assert int(qa.lo) == int(qr.lo) and int(qa.size) == int(qr.size)


def test_push_pop_lifo(ops):
    q = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of([1, 2, 3])
    q, pushed = ops.push(q, b, n)
    assert int(pushed) == 3 and int(q.size) == 3
    q, item, valid = ops.pop(q)
    assert bool(valid) and int(item) == 3  # owner pops newest (LIFO)
    q, item, valid = ops.pop(q)
    assert int(item) == 2
    q, item, valid = ops.pop(q)
    assert int(item) == 1
    q, _, valid = ops.pop(q)
    assert not bool(valid) and int(q.size) == 0


def test_pop_empty_is_null(ops):
    q = bulk_ops.make_queue(CAP, SPEC)
    q, _, valid = ops.pop(q)
    assert not bool(valid)
    assert int(q.size) == 0


def test_push_clamps_to_capacity(ops):
    q = bulk_ops.make_queue(4, SPEC)
    b, n = batch_of([1, 2, 3, 4, 5, 6])
    q, pushed = ops.push(q, b, n)
    assert int(pushed) == 4 and int(q.size) == 4


def test_steal_proportion_matches_paper_arithmetic(ops):
    # Listing 4: keep floor(sz * (1-p)); steal the rest.
    q = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of(list(range(1, 11)))  # 10 items, oldest=1
    q, _ = ops.push(q, b, n)
    q, stolen, ns = ops.steal(q, 0.3, max_steal=16)
    assert int(ns) == 10 - int(10 * 0.7)  # = 3
    np.testing.assert_array_equal(np.asarray(stolen)[: int(ns)], [1, 2, 3])
    assert int(q.size) == 7


def test_steal_aborts_below_queue_limit(ops):
    q = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of([7])
    q, _ = ops.push(q, b, n)
    q, _, ns = ops.steal(q, 0.9, max_steal=16, queue_limit=2)
    assert int(ns) == 0 and int(q.size) == 1


def test_steal_takes_oldest_side(ops):
    q = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of([10, 11, 12, 13])
    q, _ = ops.push(q, b, n)
    q, stolen, ns = ops.steal(q, 0.5, max_steal=16)
    np.testing.assert_array_equal(np.asarray(stolen)[: int(ns)], [10, 11])
    # Owner still pops newest first.
    q, item, _ = ops.pop(q)
    assert int(item) == 13


def test_steal_exact_masks_dead_rows(ops):
    q = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of([5, 6, 7, 8])
    q, _ = ops.push(q, b, n)
    q, blk, ns = ops.steal_exact(q, 2, max_steal=8)
    arr = np.asarray(blk)
    np.testing.assert_array_equal(arr[:2], [5, 6])
    assert (arr[2:] == 0).all()  # masked — safe for summing collectives


def test_steal_counted_equals_steal(ops):
    q1 = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of(list(range(1, 13)))
    q1, _ = ops.push(q1, b, n)
    q2 = bulk_ops.QueueState(*q1)
    a1, s1, n1 = ops.steal(q1, 0.4, max_steal=16)
    a2, s2, n2 = bulk_ops.steal_counted(q2, 0.4, max_steal=16)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(
        np.asarray(s1)[: int(n1)], np.asarray(s2)[: int(n2)]
    )
    assert int(a1.size) == int(a2.size)


def test_ring_wraparound(ops):
    q = bulk_ops.make_queue(8, SPEC)
    seq = 0
    for _ in range(10):  # cycle the ring several times
        b, n = batch_of([seq, seq + 1, seq + 2])
        q, pushed = ops.push(q, b, n)
        assert int(pushed) == 3
        got = []
        for _ in range(3):
            q, item, valid = ops.pop(q)
            assert bool(valid)
            got.append(int(item))
        assert got == [seq + 2, seq + 1, seq]
        seq += 3


def test_pop_bulk_order(ops):
    q = bulk_ops.make_queue(CAP, SPEC)
    b, n = batch_of([1, 2, 3, 4, 5])
    q, _ = ops.push(q, b, n)
    q, blk, ns = ops.pop_bulk(q, 4, 3)
    assert int(ns) == 3
    np.testing.assert_array_equal(np.asarray(blk)[:3], [3, 4, 5])
    assert int(q.size) == 2


def test_donate_matches_pure(ops):
    """donate=True (jitted, state donated where supported) is bit-identical
    to the pure path — the old *_inplace triplets collapsed to a flag."""
    b = jnp.arange(1, 17, dtype=jnp.int32)
    q_f = bulk_ops.make_queue(CAP, SPEC)
    q_i = bulk_ops.make_queue(CAP, SPEC)

    q_f, n_f = ops.push(q_f, b, jnp.int32(10))
    q_i, n_i = ops.push(q_i, b, jnp.int32(10), donate=True)
    assert int(n_f) == int(n_i) == 10

    q_f, blk_f, p_f = ops.pop_bulk(q_f, 8, jnp.int32(3))
    q_i, blk_i, p_i = ops.pop_bulk(q_i, 8, jnp.int32(3), donate=True)
    assert int(p_f) == int(p_i)
    np.testing.assert_array_equal(np.asarray(blk_f), np.asarray(blk_i))

    q_f, s_f, ns_f = ops.steal_exact(q_f, jnp.int32(4), max_steal=8)
    q_i, s_i, ns_i = ops.steal_exact(q_i, jnp.int32(4), max_steal=8,
                                     donate=True)
    assert int(ns_f) == int(ns_i)
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_i))

    q_f, it_f, v_f = ops.pop(q_f)
    q_i, it_i, v_i = ops.pop(q_i, donate=True)
    assert bool(v_f) == bool(v_i) and int(it_f) == int(it_i)
    assert int(q_f.lo) == int(q_i.lo) and int(q_f.size) == int(q_i.size)
    np.testing.assert_array_equal(np.asarray(q_f.buf), np.asarray(q_i.buf))


# ---------------------------------------------------------------------------
# Property: linearizability against a sequential deque model
# ---------------------------------------------------------------------------

ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("push"), st.integers(1, 12)),
        st.tuples(st.just("pop"), st.just(0)),
        st.tuples(st.just("pop_bulk"), st.integers(1, 8)),
        st.tuples(st.just("steal"), st.floats(0.05, 0.95)),
    ),
    min_size=1,
    max_size=40,
)


@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=30, deadline=None)
@given(ops_strategy)
def test_linearizable_against_model(backend, program):
    """Every interleaving of bulk ops at superstep granularity matches the
    sequential deque: owner at head, stealer at tail, nothing lost/dup'd —
    for every backend."""
    ops = bulk_ops.make_ops(backend, capacity=128, max_push=16, max_pop=8,
                            max_steal=64)
    q = bulk_ops.make_queue(128, SPEC)
    model = []  # index 0 = oldest (tail), -1 = newest (head)
    next_val = 1
    produced, consumed = set(), []

    for op, arg in program:
        if op == "push":
            vals = list(range(next_val, next_val + arg))
            next_val += arg
            b, n = batch_of(vals)
            q, pushed = ops.push(q, b, n)
            pushed = int(pushed)
            model.extend(vals[:pushed])
            produced.update(vals[:pushed])
        elif op == "pop":
            q, item, valid = ops.pop(q)
            if model:
                assert bool(valid) and int(item) == model.pop()
                consumed.append(int(item))
            else:
                assert not bool(valid)
        elif op == "pop_bulk":
            q, blk, ns = ops.pop_bulk(q, 8, arg)
            ns = int(ns)
            expect = model[len(model) - ns :]
            del model[len(model) - ns :]
            np.testing.assert_array_equal(np.asarray(blk)[:ns], expect)
            consumed.extend(expect)
        elif op == "steal":
            q, blk, ns = ops.steal(q, arg, max_steal=64)
            ns = int(ns)
            # Paper arithmetic on the model:
            sz = len(model)
            expect_n = 0 if sz < 2 else min(sz - int(sz * (1.0 - arg)), 64)
            assert ns == expect_n
            expect = model[:ns]
            del model[:ns]
            np.testing.assert_array_equal(np.asarray(blk)[:ns], expect)
            consumed.extend(expect)
        assert int(q.size) == len(model)

    # Conservation: consumed + remaining == produced, no duplicates.
    remaining = model
    assert len(set(consumed)) == len(consumed)
    assert set(consumed) | set(remaining) == produced


@settings(max_examples=20, deadline=None)
@given(
    st.integers(2, 6),
    st.lists(st.integers(0, 40), min_size=2, max_size=6),
)
def test_plan_transfers_invariants(n_workers, sizes):
    from repro.core.policy import StealPolicy, plan_transfers

    sizes = (sizes + [0] * n_workers)[:n_workers]
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=8, max_steal=32)
    plan = np.asarray(plan_transfers(jnp.asarray(sizes, jnp.int32), pol))
    srcs = plan[:, 0]
    amts = plan[:, 1]
    assert (amts >= 0).all() and (amts <= 32).all()
    # At most one steal per victim (single-stealer invariant).
    victims = srcs[amts > 0]
    assert len(victims) == len(set(victims.tolist()))
    # A victim never donates more than it has, and only if above watermark.
    for t in range(n_workers):
        if amts[t] > 0:
            v = srcs[t]
            assert v != t
            assert sizes[v] >= pol.high_watermark
            assert amts[t] <= sizes[v]
            assert sizes[t] <= pol.low_watermark
