"""Unit + property tests for the JAX bulk work-stealing queue.

The linearizability property tests mirror the paper's §III-B argument: for
any sequence of owner bulk-pushes / pops and stealer bulk-steals, the queue
behaves exactly like a sequential deque where the owner operates at the head
and the stealer detaches suffixes at the tail — no task is lost, duplicated,
or reordered.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.core import queue as q_ops

CAP = 64
SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def batch_of(values):
    """Fixed-width batch buffer (width 16) holding ``values``."""
    buf = np.zeros((16,), np.int32)
    buf[: len(values)] = values
    return jnp.asarray(buf), len(values)


def test_push_pop_lifo():
    q = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of([1, 2, 3])
    q, pushed = q_ops.push(q, b, n)
    assert int(pushed) == 3 and int(q.size) == 3
    q, item, valid = q_ops.pop(q)
    assert bool(valid) and int(item) == 3  # owner pops newest (LIFO)
    q, item, valid = q_ops.pop(q)
    assert int(item) == 2
    q, item, valid = q_ops.pop(q)
    assert int(item) == 1
    q, _, valid = q_ops.pop(q)
    assert not bool(valid) and int(q.size) == 0


def test_pop_empty_is_null():
    q = q_ops.make_queue(CAP, SPEC)
    q, _, valid = q_ops.pop(q)
    assert not bool(valid)
    assert int(q.size) == 0


def test_push_clamps_to_capacity():
    q = q_ops.make_queue(4, SPEC)
    b, n = batch_of([1, 2, 3, 4, 5, 6])
    q, pushed = q_ops.push(q, b, n)
    assert int(pushed) == 4 and int(q.size) == 4


def test_steal_proportion_matches_paper_arithmetic():
    # Listing 4: keep floor(sz * (1-p)); steal the rest.
    q = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of(list(range(1, 11)))  # 10 items, oldest=1
    q, _ = q_ops.push(q, b, n)
    q, stolen, ns = q_ops.steal(q, 0.3, max_steal=16)
    assert int(ns) == 10 - int(10 * 0.7)  # = 3
    np.testing.assert_array_equal(np.asarray(stolen)[: int(ns)], [1, 2, 3])
    assert int(q.size) == 7


def test_steal_aborts_below_queue_limit():
    q = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of([7])
    q, _ = q_ops.push(q, b, n)
    q, _, ns = q_ops.steal(q, 0.9, max_steal=16, queue_limit=2)
    assert int(ns) == 0 and int(q.size) == 1


def test_steal_takes_oldest_side():
    q = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of([10, 11, 12, 13])
    q, _ = q_ops.push(q, b, n)
    q, stolen, ns = q_ops.steal(q, 0.5, max_steal=16)
    np.testing.assert_array_equal(np.asarray(stolen)[: int(ns)], [10, 11])
    # Owner still pops newest first.
    q, item, _ = q_ops.pop(q)
    assert int(item) == 13


def test_steal_exact_masks_dead_rows():
    q = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of([5, 6, 7, 8])
    q, _ = q_ops.push(q, b, n)
    q, blk, ns = q_ops.steal_exact(q, 2, max_steal=8)
    arr = np.asarray(blk)
    np.testing.assert_array_equal(arr[:2], [5, 6])
    assert (arr[2:] == 0).all()  # masked — safe for summing collectives


def test_steal_counted_equals_steal():
    q1 = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of(list(range(1, 13)))
    q1, _ = q_ops.push(q1, b, n)
    q2 = q_ops.QueueState(*q1)
    a1, s1, n1 = q_ops.steal(q1, 0.4, max_steal=16)
    a2, s2, n2 = q_ops.steal_counted(q2, 0.4, max_steal=16)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(
        np.asarray(s1)[: int(n1)], np.asarray(s2)[: int(n2)]
    )
    assert int(a1.size) == int(a2.size)


def test_ring_wraparound():
    q = q_ops.make_queue(8, SPEC)
    seq = 0
    for _ in range(10):  # cycle the ring several times
        b, n = batch_of([seq, seq + 1, seq + 2])
        q, pushed = q_ops.push(q, b, n)
        assert int(pushed) == 3
        got = []
        for _ in range(3):
            q, item, valid = q_ops.pop(q)
            assert bool(valid)
            got.append(int(item))
        assert got == [seq + 2, seq + 1, seq]
        seq += 3


def test_pop_bulk_order():
    q = q_ops.make_queue(CAP, SPEC)
    b, n = batch_of([1, 2, 3, 4, 5])
    q, _ = q_ops.push(q, b, n)
    q, blk, ns = q_ops.pop_bulk(q, 4, 3)
    assert int(ns) == 3
    np.testing.assert_array_equal(np.asarray(blk)[:3], [3, 4, 5])
    assert int(q.size) == 2


# ---------------------------------------------------------------------------
# Property: linearizability against a sequential deque model
# ---------------------------------------------------------------------------

ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("push"), st.integers(1, 12)),
        st.tuples(st.just("pop"), st.just(0)),
        st.tuples(st.just("pop_bulk"), st.integers(1, 8)),
        st.tuples(st.just("steal"), st.floats(0.05, 0.95)),
    ),
    min_size=1,
    max_size=40,
)


@settings(max_examples=60, deadline=None)
@given(ops_strategy)
def test_linearizable_against_model(ops):
    """Every interleaving of bulk ops at superstep granularity matches the
    sequential deque: owner at head, stealer at tail, nothing lost/dup'd."""
    q = q_ops.make_queue(128, SPEC)
    model = []  # index 0 = oldest (tail), -1 = newest (head)
    next_val = 1
    produced, consumed = set(), []

    for op, arg in ops:
        if op == "push":
            vals = list(range(next_val, next_val + arg))
            next_val += arg
            b, n = batch_of(vals)
            q, pushed = q_ops.push(q, b, n)
            pushed = int(pushed)
            model.extend(vals[:pushed])
            produced.update(vals[:pushed])
        elif op == "pop":
            q, item, valid = q_ops.pop(q)
            if model:
                assert bool(valid) and int(item) == model.pop()
                consumed.append(int(item))
            else:
                assert not bool(valid)
        elif op == "pop_bulk":
            q, blk, ns = q_ops.pop_bulk(q, 8, arg)
            ns = int(ns)
            expect = model[len(model) - ns :]
            del model[len(model) - ns :]
            np.testing.assert_array_equal(np.asarray(blk)[:ns], expect)
            consumed.extend(expect)
        elif op == "steal":
            q, blk, ns = q_ops.steal(q, arg, max_steal=64)
            ns = int(ns)
            # Paper arithmetic on the model:
            sz = len(model)
            expect_n = 0 if sz < 2 else min(sz - int(sz * (1.0 - arg)), 64)
            assert ns == expect_n
            expect = model[:ns]
            del model[:ns]
            np.testing.assert_array_equal(np.asarray(blk)[:ns], expect)
            consumed.extend(expect)
        assert int(q.size) == len(model)

    # Conservation: consumed + remaining == produced, no duplicates.
    remaining = model
    assert len(set(consumed)) == len(consumed)
    assert set(consumed) | set(remaining) == produced


@settings(max_examples=20, deadline=None)
@given(
    st.integers(2, 6),
    st.lists(st.integers(0, 40), min_size=2, max_size=6),
)
def test_plan_transfers_invariants(n_workers, sizes):
    from repro.core.policy import StealPolicy, plan_transfers

    sizes = (sizes + [0] * n_workers)[:n_workers]
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=8, max_steal=32)
    plan = np.asarray(plan_transfers(jnp.asarray(sizes, jnp.int32), pol))
    srcs = plan[:, 0]
    amts = plan[:, 1]
    assert (amts >= 0).all() and (amts <= 32).all()
    # At most one steal per victim (single-stealer invariant).
    victims = srcs[amts > 0]
    assert len(victims) == len(set(victims.tolist()))
    # A victim never donates more than it has, and only if above watermark.
    for t in range(n_workers):
        if amts[t] > 0:
            v = srcs[t]
            assert v != t
            assert sizes[v] >= pol.high_watermark
            assert amts[t] <= sizes[v]
            assert sizes[t] <= pol.low_watermark
