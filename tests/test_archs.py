"""Per-architecture smoke tests (assigned requirement): a REDUCED config
of the same family runs one forward/train step on CPU, asserting output
shapes and no NaNs.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step

B, S = 2, 32


def make_batch(cfg):
    if cfg.family == "vlm":
        s_text = S - cfg.n_patches
        return {
            "tokens": jnp.ones((B, s_text), jnp.int32),
            "labels": jnp.ones((B, s_text), jnp.int32),
            "patches": jnp.ones((B, cfg.n_patches, cfg.frontend_dim),
                                jnp.float32),
        }
    if cfg.family == "encdec":
        return {
            "frames": jnp.ones((B, S, cfg.frontend_dim), jnp.float32),
            "tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.fixture(scope="module", params=list(configs.ARCH_IDS))
def arch_setup(request):
    cfg = configs.reduced(configs.get(request.param))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = make_batch(cfg)
    loss = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"


def test_train_step_updates_and_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = make_batch(cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(new_opt.step) == 1
    # at least one parameter actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved, f"{arch}: no parameter changed"
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN in params"


def test_prefill_decode_shapes(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = make_batch(cfg)
    if cfg.family == "vlm":
        logits, cache = jax.jit(model.prefill)(params, batch["tokens"],
                                               batch["patches"])
    elif cfg.family == "encdec":
        logits, cache = jax.jit(model.prefill)(params, batch["frames"],
                                               batch["tokens"])
    else:
        logits, cache = jax.jit(model.prefill)(params, batch["tokens"])
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert logits.shape[2] == cfg.padded_vocab
    tok = jnp.ones((B, 1), jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: NaN decode logits"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_param_count_analytic_close(arch_setup):
    """ModelConfig.param_count (used for MODEL_FLOPS) tracks real init."""
    arch, cfg, model, params = arch_setup
    analytic = cfg.param_count()
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert abs(analytic - actual) / actual < 0.05, (
        f"{arch}: analytic {analytic} vs actual {actual}")
