"""Sharded lowering smoke: a miniature version of the production dry-run
on an 8-device host mesh, run in a subprocess (device count must be set
before jax initializes, and the main test process keeps 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.configs.base import ParallelConfig, ShapeConfig
    from repro.launch.roofline import normalize_cost_analysis
    from repro.models import build_model
    from repro.models.zoo import input_specs
    from repro.train.optimizer import AdamWConfig, adamw_init, opt_state_specs
    from repro.train.trainer import make_train_step

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    arch = os.environ["ARCH"]
    cfg = configs.reduced(configs.get(arch))
    par = ParallelConfig()
    model = build_model(cfg, par)
    shape = ShapeConfig("tiny_train", seq_len=32, global_batch=4,
                        kind="train")
    sds, ps = input_specs(cfg, shape, par)

    def ns(tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(adamw_init, params_sds)
    step = make_train_step(model, AdamWConfig())
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(ns(model.param_specs()),
                          ns(opt_state_specs(model.param_specs())),
                          ns(ps)),
        ).lower(params_sds, opt_sds, sds)
        compiled = lowered.compile()
        ca = normalize_cost_analysis(compiled.cost_analysis())
    assert ca.get("flops", 0) > 0
    print("SHARDED-OK", arch, int(ca["flops"]))
""")


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-moe-30b-a3b",
                                  "mamba2-2.7b"])
def test_sharded_train_step_lowers(arch):
    env = dict(os.environ, ARCH=arch,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert f"SHARDED-OK {arch}" in out.stdout, out.stderr[-2000:]
