"""Training substrate: convergence, grad-accumulation equivalence,
checkpoint atomicity + elastic restore, fault handling."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import synth_batch
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.fault import StragglerMonitor, run_supervised
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _batch(cfg, step=0, B=8, S=32):
    raw = synth_batch(0, 0, step, B, S, cfg.vocab_size)
    return {"tokens": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"])}


def test_loss_decreases(setup):
    cfg, model, params = setup
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                      total_steps=30)))
    first = None
    for i in range(30):
        params, opt, m = step(params, opt, _batch(cfg, i))
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.8, (first, float(m["loss"]))


def test_grad_accumulation_equivalence(setup):
    """microbatch=2 must match the full-batch gradient step (same math,
    different schedule — the overlap trick must not change results)."""
    cfg, model, params = setup
    batch = _batch(cfg, B=8)
    ocfg = AdamWConfig(lr=1e-3)
    full = jax.jit(make_train_step(model, ocfg, microbatch=0))
    acc = jax.jit(make_train_step(model, ocfg, microbatch=2))
    p1, _, m1 = full(params, adamw_init(params), batch)
    p2, _, m2 = acc(params, adamw_init(params), batch)
    # loss means over microbatches differ by chunking of the mean; params
    # must agree to fp tolerance
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, params = setup
    opt = adamw_init(params)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, (params, opt), extra={"data": {"step": 7}})
    (p2, o2), step, extra = ckpt.restore(d, (params, opt))
    assert step == 7 and extra["data"]["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k(tmp_path, setup):
    cfg, model, params = setup
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"w": jnp.full((2,), s)}, keep=2)
    assert ckpt.latest_steps(d) == [4, 5]


def test_elastic_restore_device_put(tmp_path, setup):
    """Restore places leaves with explicit shardings (single-device here;
    the same path re-shards onto any mesh)."""
    cfg, model, params = setup
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, params)
    shardings = jax.tree_util.tree_map(
        lambda _: jax.devices()[0], params)
    p2, _, _ = ckpt.restore(d, params, shardings=shardings)
    for leaf in jax.tree_util.tree_leaves(p2):
        assert leaf.devices() == {jax.devices()[0]}


def test_run_supervised_restarts():
    calls = []

    def run(resume):
        calls.append(resume)
        if len(calls) < 3:
            raise RuntimeError("simulated node failure")
        return 42

    assert run_supervised(run, max_restarts=3) == 42
    assert calls == [None, -1, -1]


def test_run_supervised_reraises_exits():
    """SystemExit (GracefulExit's sys.exit) and GeneratorExit must
    propagate, not be retried as crashes."""
    for exc in (SystemExit, GeneratorExit, KeyboardInterrupt):
        calls = []

        def run(resume, _exc=exc, _calls=calls):
            _calls.append(resume)
            raise _exc()

        with pytest.raises(exc):
            run_supervised(run, max_restarts=3)
        assert calls == [None]  # no restart attempts


def test_checkpoint_queue_state_keys(tmp_path):
    """NamedTuple leaves (QueueState) flatten to field-named keys, not
    GetAttrKey reprs, and round-trip bit-identically."""
    from repro.runtime import StealRuntime

    rt = StealRuntime(2, 8, {"x": jax.ShapeDtypeStruct((), jnp.int32)})
    rt.push(0, {"x": jnp.arange(5, dtype=jnp.int32)}, 5)
    q = rt.queues
    flat = ckpt._flatten(q)
    assert set(flat) == {"buf/x", "lo", "size"}, set(flat)
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, q)
    q2, step, _ = ckpt.restore(d, q)
    assert step == 3
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), q, q2)
    # read-compat: a checkpoint written under the legacy repr-style keys
    # still restores through the fallback probe
    legacy = {ckpt._legacy_path_key(p): np.asarray(leaf)
              for p, leaf in jax.tree_util.tree_flatten_with_path(q)[0]}
    q3 = ckpt._unflatten(q, legacy)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), q, q3)


def test_straggler_monitor_flags_slow_steps():
    import time

    mon = StragglerMonitor(alpha=0.5, threshold=1.5, warmup=1)
    flagged = 0
    for i in range(8):
        mon.start()
        time.sleep(0.03 if i == 5 else 0.002)
        flagged += bool(mon.observe())
    assert flagged >= 1
    assert mon.straggler_steps == flagged
