"""`repro.distributed` on a real mesh axis: the FULL round loop under
shard_map, exercised on 8 fake host devices.

Same dual execution shape as ``tests/test_sharded_superstep.py``: with
>= 8 devices (the CI lane exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest
starts) the checks run in-process; otherwise a subprocess sets the flag
before jax initializes and runs the identical checks.

The checks — the mesh executor is not "close to" the vmapped one, it is
bit-identical:

* ``MeshStealRuntime.run_fused`` (scan + early-exit while_loop) and
  ``round()`` produce bit-identical queues (buf/lo/size), RebalanceStats,
  telemetry ``RoundRecord`` streams (incl. ``bytes_moved``) and
  adaptive-proportion trajectories to ``StealRuntime`` — flat AND
  hierarchical (2x4 pod mesh), both exchanges, reference + auto
  backends;
* ``run_fused(k, until_drained=True)`` drains the Fig. 9 DAG workload
  (worker body with a collective) under shard_map, conserving the
  explored-node count and matching the vmapped drain round-for-round;
* ``launch_runtime`` selects both modes and validates its inputs;
* ``parallel_solve(execution="mesh")`` returns the DP optimum with the
  same superstep/exploration trajectory as the vmap path;
* ``RuntimeAdmissionMaster(execution="mesh")`` admits/rebalances request
  IDs on device lanes.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

_HAVE_8 = jax.device_count() >= 8

_CHECKS = textwrap.dedent("""
    import dataclasses

    import jax, jax.numpy as jnp
    import numpy as np
    from jax import lax

    from repro.core.policy import StealPolicy
    from repro.distributed import (MeshStealRuntime, RuntimeAdmissionMaster,
                                   launch_runtime)
    from repro.launch.mesh import make_worker_mesh
    from repro.runtime import StealRuntime

    SPEC = jax.ShapeDtypeStruct((), jnp.int32)
    SIZES = [40, 0, 0, 0, 25, 0, 3, 0]

    def seed(rt):
        nxt = 1
        for i, n in enumerate(SIZES):
            if n:
                rt.push(i, jnp.arange(nxt, nxt + n, dtype=jnp.int32), n)
                nxt += n

    def assert_identical(vm, ms, stats_pairs=()):
        np.testing.assert_array_equal(np.asarray(vm.queues.size),
                                      np.asarray(ms.queues.size))
        np.testing.assert_array_equal(np.asarray(vm.queues.lo),
                                      np.asarray(ms.queues.lo))
        np.testing.assert_array_equal(np.asarray(vm.queues.buf),
                                      np.asarray(ms.queues.buf))
        for sv, sm in stats_pairs:
            for f in sv._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(sv, f)), np.asarray(getattr(sm, f)),
                    err_msg=f)
        assert vm.telemetry.rounds == ms.telemetry.rounds  # RoundRecords ==
        if vm.controller is not None:
            assert vm.controller.history == ms.controller.history

    def parity_checks():
        for pod_size in (None, 4):
            for backend in ("reference", "auto"):
                for exchange in ("compact", "dense"):
                    pol = StealPolicy(proportion=0.5, low_watermark=2,
                                      high_watermark=8, max_steal=32,
                                      exchange=exchange)
                    vm = launch_runtime(8, 128, SPEC, execution="vmap",
                                        pod_size=pod_size, policy=pol,
                                        backend=backend)
                    ms = launch_runtime(8, 128, SPEC, execution="mesh",
                                        pod_size=pod_size, policy=pol,
                                        backend=backend)
                    assert isinstance(ms, MeshStealRuntime)
                    assert ms.ops == vm.ops
                    seed(vm); seed(ms)
                    _, sv = vm.round()
                    _, sm = ms.round()
                    vm.run_fused(2)
                    ms.run_fused(2)
                    cv, _, rv = vm.run_fused(3, until_drained=True)
                    cm, _, rm = ms.run_fused(3, until_drained=True)
                    assert rv == rm
                    assert_identical(vm, ms, [(sv, sm)])
        print("PARITY-OK")

    N_NODES, BATCH, FANOUT = 3000, 16, 4

    def dag_body(ops):
        def body(q, carry):
            q, nodes, n_popped = ops.pop_bulk(q, BATCH, jnp.int32(BATCH))
            valid = jnp.arange(BATCH, dtype=jnp.int32) < n_popped
            kids = (nodes[:, None] * FANOUT + 1
                    + jnp.arange(FANOUT, dtype=jnp.int32)[None, :])
            live = valid[:, None] & (kids < N_NODES)
            flat, flive = kids.reshape(-1), live.reshape(-1)
            order = jnp.argsort(~flive, stable=True)
            flat = jnp.where(flive[order], flat[order], 0)
            q, _ = ops.push(q, flat, jnp.sum(flive.astype(jnp.int32)))
            # a worker-body collective, like the DD solver's incumbent
            peak = lax.pmax(carry, "workers")
            return q, carry + jnp.sum(valid.astype(jnp.int32)) + 0 * peak
        return body

    def dag_drain_checks():
        pol = StealPolicy(proportion=0.5, low_watermark=4,
                          high_watermark=32, max_steal=64)
        results = {}
        for mode in ("vmap", "mesh"):
            rt = launch_runtime(8, 1024, SPEC, execution=mode, policy=pol,
                                max_pop=BATCH)
            rt.push(0, jnp.zeros((1,), jnp.int32), 1)
            body = dag_body(rt.ops)
            carry = jnp.zeros((8,), jnp.int32)
            rounds = 0
            while rt.total_size() > 0 and rounds < 500:
                carry, _, r = rt.run_fused(16, body, carry,
                                           until_drained=True)
                rounds += r
            results[mode] = (int(jnp.sum(carry)), rounds,
                             rt.telemetry.rounds,
                             rt.controller.history)
        assert results["vmap"][0] == results["mesh"][0] == N_NODES
        assert results["vmap"][1] == results["mesh"][1]
        assert results["vmap"][2] == results["mesh"][2]
        assert results["vmap"][3] == results["mesh"][3]
        print("DAG-DRAIN-OK", results["mesh"][1])

    def launch_checks():
        try:
            launch_runtime(8, 64, SPEC, execution="threads")
        except ValueError as e:
            assert "execution" in str(e)
        else:
            raise AssertionError("bad execution accepted")
        try:
            launch_runtime(4, 64, SPEC, execution="mesh",
                           mesh=make_worker_mesh(8))
        except ValueError as e:
            assert "devices" in str(e)
        else:
            raise AssertionError("mismatched mesh accepted")
        try:
            make_worker_mesh(10_000)
        except ValueError as e:
            assert "devices" in str(e)
        else:
            raise AssertionError("oversized mesh accepted")
        try:  # a flat pinned mesh must not silently drop pod_size
            launch_runtime(8, 64, SPEC, execution="mesh",
                           mesh=make_worker_mesh(8), pod_size=4)
        except ValueError as e:
            assert "pod_size" in str(e)
        else:
            raise AssertionError("flat mesh + pod_size accepted")
        # pinned 2-axis mesh round-trips
        mesh = make_worker_mesh(8, pod_size=4)
        rt = launch_runtime(8, 64, SPEC, execution="mesh", mesh=mesh,
                            pod_size=4)
        assert rt.pod_size == 4 and rt.n_workers == 8
        print("LAUNCH-OK")

    def solver_checks():
        from repro.core.dd.knapsack import dp_solve, random_instance
        from repro.core.dd.parallel import parallel_solve

        inst = random_instance(10, seed=3)
        expect = dp_solve(inst)
        out = {}
        for mode in ("vmap", "mesh"):
            got, stats = parallel_solve(inst, n_workers=8, explore_width=8,
                                        batch=4, capacity=1024,
                                        execution=mode)
            assert got == expect, (mode, got, expect)
            assert stats["execution"] == mode
            out[mode] = stats
        # same optimum AND the same superstep trajectory
        assert out["vmap"]["supersteps"] == out["mesh"]["supersteps"]
        assert out["vmap"]["explored"] == out["mesh"]["explored"]
        assert (out["vmap"]["per_worker_explored"]
                == out["mesh"]["per_worker_explored"])
        print("SOLVER-OK", out["mesh"]["supersteps"])

    def serve_checks():
        from repro.serve.scheduler import Request

        master = RuntimeAdmissionMaster(8, execution="mesh", capacity=64)
        reqs = [Request(prompt=[1, 2, 3]) for _ in range(20)]
        # all 20 to one replica (bulk admission picks the least loaded
        # ONCE per submit call)
        master.submit(reqs)
        loads = [r.load() for r in master.replicas]
        assert sum(loads) == 20 and max(loads) == 20
        moved = master.rebalance_many(8)
        assert moved > 0
        loads = [r.load() for r in master.replicas]
        assert sum(loads) == 20 and max(loads) < 20
        wave = master.replicas[int(np.argmax(loads))].pop_wave(4)
        assert len(wave) == 4 and all(isinstance(r, Request) for r in wave)
        st = master.stats()
        assert st["execution"] == "mesh" and st["stolen"] == moved
        assert st["telemetry"]["rounds"] == master.rounds
        print("SERVE-OK")

    def decode_parity_checks():
        # The continuous-batching decode engine is bit-identical between
        # vmap lanes and the per-device mesh: same served tokens per
        # request AND the same admit/first/finish round stamps.
        from repro import configs
        from repro.models import build_model
        from repro.serve.decode import DecodeCluster, DecodePolicy
        from repro.serve.scheduler import Request

        cfg = configs.reduced(configs.get("llama3.2-1b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        data = [(list(rng.integers(1, 100, size=int(rng.integers(1, 7)))),
                 int(rng.integers(1, 5))) for _ in range(10)]
        out = {}
        for mode in ("vmap", "mesh"):
            cl = DecodeCluster(
                model, params, n_lanes=8, capacity=32, execution=mode,
                policy=DecodePolicy(n_slots=2, max_prompt=8, max_new=4,
                                    page_size=4))
            reqs = [Request(prompt=p, max_new=mn) for p, mn in data]
            cl.submit(reqs[:6]); cl.step(); cl.submit(reqs[6:])
            done = cl.run_until_drained(max_steps=100)
            assert len(done) == len(data), (mode, len(done))
            # rid auto-increments globally across clusters; compare by
            # submission index
            idx = {r.rid: i for i, r in enumerate(reqs)}
            out[mode] = (
                sorted((idx[r.rid], tuple(r.output)) for r in done),
                sorted((idx[r.rid], r.admit, r.first, r.finish, r.tokens)
                       for r in cl.telemetry.requests))
        assert out["vmap"][0] == out["mesh"][0]   # served tokens
        assert out["vmap"][1] == out["mesh"][1]   # SLO round stamps
        print("DECODE-PARITY-OK")

    def run_checks():
        assert jax.device_count() >= 8, jax.device_count()
        parity_checks()
        dag_drain_checks()
        launch_checks()
        solver_checks()
        serve_checks()
        decode_parity_checks()
        print("DISTRIBUTED-OK")
""")


@pytest.mark.skipif(not _HAVE_8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 before jax init (CI lane)")
def test_distributed_inprocess():
    ns = {}
    exec(compile(_CHECKS, "<distributed-checks>", "exec"), ns)
    ns["run_checks"]()


@pytest.mark.skipif(_HAVE_8, reason="in-process variant runs instead")
def test_distributed_subprocess():
    script = ('import os\n'
              'os.environ["XLA_FLAGS"] = '
              '"--xla_force_host_platform_device_count=8"\n'
              + _CHECKS + "\nrun_checks()\n")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "DISTRIBUTED-OK" in out.stdout, out.stderr[-3000:]
