"""Telemetry edge cases (ISSUE 10 satellite): empty-stream summary,
direct ``reduce_round_stats`` unit coverage (flat and hierarchical
xpod accounting), and degenerate percentile inputs."""

import numpy as np
import pytest

from repro.runtime.telemetry import Telemetry, reduce_round_stats


class _Stats:
    """Minimal RebalanceStats stand-in: numpy leaves, leading axis =
    lanes, with the xpod fields the hierarchical reduction reads."""

    def __init__(self, n_steals, n_transferred, bytes_moved,
                 n_steals_xpod=None, n_transferred_xpod=None,
                 bytes_moved_xpod=None):
        self.n_steals = np.asarray(n_steals)
        self.n_transferred = np.asarray(n_transferred)
        self.bytes_moved = np.asarray(bytes_moved)
        if n_steals_xpod is not None:
            self.n_steals_xpod = np.asarray(n_steals_xpod)
            self.n_transferred_xpod = np.asarray(n_transferred_xpod)
            self.bytes_moved_xpod = np.asarray(bytes_moved_xpod)


def test_summary_on_empty_stream():
    tele = Telemetry()
    s = tele.summary()
    assert s["rounds"] == 0
    assert s["steals"] == 0
    assert s["proportion_mean"] == 0.0
    assert s["proportion_final"] == 0.0
    assert s["imbalance_final"] == 0.0
    assert s["straggler_steps"] == 0
    assert "waves" not in s and "requests" not in s and "faults" not in s
    assert tele.phase_summary() == {"timed_rounds": 0}


def test_reduce_round_stats_flat_reads_replicated_element():
    # Flat mode: counters are replicated across lanes — element 0 exact.
    stats = _Stats([7, 7, 7, 7], [30, 30, 30, 30], [120, 120, 120, 120])
    assert reduce_round_stats(stats, n_workers=4) == (7, 30, 120)


def test_reduce_round_stats_hierarchical_sums_intra_plus_xpod_once():
    # 2 pods x 2 lanes.  Intra-pod counters replicate WITHIN a pod
    # (lane (p, 0) carries pod p's share); the cross-pod share lives in
    # the *_xpod fields, replicated across lane-0 representatives.
    stats = _Stats(
        n_steals=[3, 3, 5, 5],            # pod0 intra=3, pod1 intra=5
        n_transferred=[12, 12, 20, 20],
        bytes_moved=[48, 48, 80, 80],
        n_steals_xpod=[2, 0, 2, 0],       # xpod share, counted ONCE
        n_transferred_xpod=[8, 0, 8, 0],
        bytes_moved_xpod=[32, 0, 32, 0],
    )
    n_steals, n_transferred, bytes_moved = reduce_round_stats(
        stats, n_workers=4, pod_size=2)
    assert n_steals == 3 + 5 + 2
    assert n_transferred == 12 + 20 + 8
    # bytes_moved is PER-LANE: the busiest lane's intra payload plus the
    # pod-level share — not a sum over pods.
    assert bytes_moved == 80 + 32


def test_reduce_round_stats_hierarchical_zero_xpod_round():
    stats = _Stats([4, 4, 6, 6], [16, 16, 24, 24], [64, 64, 96, 96],
                   n_steals_xpod=[0, 0, 0, 0],
                   n_transferred_xpod=[0, 0, 0, 0],
                   bytes_moved_xpod=[0, 0, 0, 0])
    assert reduce_round_stats(stats, n_workers=4, pod_size=2) \
        == (10, 40, 96)


def test_single_request_percentiles_collapse_to_its_values():
    tele = Telemetry()
    tele.record_request(rid=0, admit=2, first=5, finish=9, tokens=4)
    s = tele.summary()
    assert s["requests"] == 1
    # One sample: every percentile is that sample.
    assert s["ttft_p50"] == s["ttft_p95"] == s["ttft_p99"] == 3.0
    assert s["latency_p50"] == s["latency_p99"] == 7.0
    wave = tele.record_wave(loads=[1, 2], served=1)
    assert wave.ttft_p99 == 3.0 and wave.latency_p95 == 7.0


def test_wave_round_alignment_and_fault_log_stamps():
    tele = Telemetry()
    tele.record(sizes=np.asarray([3, 1]), n_steals=1, n_transferred=1,
                proportion=0.5)
    tele.record_fault("kill", lane=1)
    w = tele.record_wave(loads=[2, 2], served=0)
    assert w.round == 1                      # closed after round 0
    assert tele.fault_log == [("kill", 1, 1)]
    tele.record_fault("restart")             # not lane-attributed
    assert tele.fault_log[-1] == ("restart", -1, 1)
    assert tele.summary()["faults"] == {"kill": 1, "restart": 1}


def test_record_phases_roundtrip():
    tele = Telemetry()
    tele.record(sizes=np.asarray([2, 2]), n_steals=0, n_transferred=0,
                proportion=0.5,
                phases={"t_worker": 0.6, "t_exchange": 0.2,
                        "t_splice": 0.1, "t_adaptive": 0.1,
                        "t_round": 1.0, "phase_estimated": True})
    ps = tele.phase_summary()
    assert ps["timed_rounds"] == 1 and ps["estimated_rounds"] == 1
    assert ps["wall_s"] == pytest.approx(1.0)
    assert ps["phases"]["worker_body"]["fraction"] == pytest.approx(0.6)
