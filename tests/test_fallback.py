"""Silent-downgrade observability: when ``auto`` routes an op to the
reference path (geometry predicate rejection) or REPRO_QUEUE_BACKEND
overrides an ``auto`` request, a one-shot BackendFallbackWarning names
the reason.  (The relaxed->fenced case is covered in test_relaxed.)"""

import warnings

import pytest

from repro.core import ops as bulk_ops


@pytest.fixture(autouse=True)
def _fresh():
    bulk_ops.reset_fallback_warnings()
    yield
    bulk_ops.reset_fallback_warnings()


def _fallback_msgs(rec):
    return [str(r.message) for r in rec
            if issubclass(r.category, bulk_ops.BackendFallbackWarning)]


def test_auto_geometry_rejection_warns_once_per_op():
    # capacity 100 with bound 24: 100 % block != 0 for every shrunken
    # block choice, so all kernel predicates reject.
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops = bulk_ops.make_ops("auto", capacity=100, max_push=24,
                                max_steal=24)
        bulk_ops.make_ops("auto", capacity=100, max_push=24, max_steal=24)
    assert ops.resolved == "reference"
    msgs = _fallback_msgs(rec)
    assert msgs, "no fallback warning for a rejected auto geometry"
    assert all("auto" in m and "reference" in m for m in msgs)
    # one-shot: the repeat construction added nothing
    assert len(msgs) == len(set(msgs))


def test_auto_supported_geometry_is_silent():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops = bulk_ops.make_ops("auto", capacity=256, max_push=128,
                                max_steal=128)
    assert ops.name == "auto"
    assert _fallback_msgs(rec) == []


def test_env_override_of_auto_warns(monkeypatch):
    monkeypatch.setenv(bulk_ops.BACKEND_ENV_VAR, "reference")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops = bulk_ops.make_ops("auto", capacity=256, max_steal=128)
        bulk_ops.make_ops("auto", capacity=256, max_steal=128)
    assert ops.resolved == "reference"
    msgs = _fallback_msgs(rec)
    assert len(msgs) == 1
    assert bulk_ops.BACKEND_ENV_VAR in msgs[0]
    assert "reference" in msgs[0]


def test_explicit_backend_request_is_silent(monkeypatch):
    """Asking for 'reference' by name is not a downgrade."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        bulk_ops.make_ops("reference")
    assert _fallback_msgs(rec) == []
