"""The runtime conservation sanitizer (repro.analysis.sanitize):
check=True / REPRO_CHECK=1 wrap every BulkOps call with invariant
checks.  Clean ops sail through; corrupted backends, broken counters
and paging bugs are caught."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitize
from repro.analysis.sanitize import CheckedBulkOps, SanitizerError
from repro.core import ops as bulk_ops

SPEC = jax.ShapeDtypeStruct((), jnp.int32)


@pytest.fixture(autouse=True)
def _clean_slate():
    sanitize.reset_violations()
    yield
    sanitize.reset_violations()


def _seeded(values, cap=16, *, check=True):
    ops = bulk_ops.make_ops("reference", check=check)
    q = bulk_ops.make_queue(cap, SPEC)
    buf = np.zeros((max(len(values), 1),), np.int32)
    buf[: len(values)] = values
    q, _ = ops.push(q, jnp.asarray(buf), len(values))
    return ops, q


# -- wiring -----------------------------------------------------------------


def test_check_true_wraps_and_env_wraps(monkeypatch):
    assert isinstance(bulk_ops.make_ops("reference", check=True),
                      CheckedBulkOps)
    assert not isinstance(bulk_ops.make_ops("reference", check=False),
                          CheckedBulkOps)
    monkeypatch.setenv(bulk_ops.CHECK_ENV_VAR, "1")
    assert isinstance(bulk_ops.make_ops("reference"), CheckedBulkOps)
    monkeypatch.delenv(bulk_ops.CHECK_ENV_VAR)
    assert not isinstance(bulk_ops.make_ops("reference"), CheckedBulkOps)


def test_wrapping_is_idempotent_and_delegates():
    inner = bulk_ops.make_ops("relaxed", capacity=64, max_steal=16,
                              check=False)
    once = bulk_ops.make_ops(inner, check=True)
    twice = bulk_ops.make_ops(once, check=True)
    assert isinstance(once, CheckedBulkOps)
    assert twice.inner is once.inner  # no double wrap
    assert once.resolved == inner.resolved
    assert once.multiplicity_bound(16) == inner.multiplicity_bound(16)


def test_clean_ops_record_nothing():
    ops, q = _seeded([1, 2, 3, 4, 5])
    q, batch, n = ops.pop_bulk(q, 4, jnp.int32(2))
    q, batch, n = ops.steal(q, 0.5, max_steal=8, queue_limit=0)
    q, item, valid = ops.pop(q)
    assert sanitize.violations() == ()
    sanitize.assert_clean()


# -- corrupted backends are caught ------------------------------------------


class _LyingOps(bulk_ops.BulkOps):
    """Reference backend that misreports the push count."""

    def __init__(self):
        super().__init__("reference")

    def push(self, q, batch, n, *, donate=False):
        q2, n_pushed = super().push(q, batch, n, donate=donate)
        return q2, n_pushed + 1


class _LeakyOps(bulk_ops.BulkOps):
    """Reference backend whose steal drops the stolen rows' cursor bump
    (items duplicated: still in the ring AND in the stolen batch)."""

    def __init__(self):
        super().__init__("reference")

    def steal_exact(self, q, n, *, max_steal, donate=False):
        _, batch, n_out = super().steal_exact(q, n, max_steal=max_steal,
                                              donate=donate)
        return q, batch, n_out  # "forgot" the lo += n linearization write


def test_misreported_count_is_caught():
    checked = CheckedBulkOps(_LyingOps())
    q = bulk_ops.make_queue(8, SPEC)
    with pytest.raises(SanitizerError, match="push"):
        checked.push(q, jnp.arange(3, dtype=jnp.int32), jnp.int32(3))


def test_missing_linearization_write_is_caught():
    checked = CheckedBulkOps(_LeakyOps())
    _, q = _seeded([1, 2, 3, 4])
    with pytest.raises(SanitizerError, match="steal_exact"):
        checked.steal_exact(q, jnp.int32(2), max_steal=4)


# -- violation lifecycle ----------------------------------------------------


def test_record_then_raise_pending_drains():
    sanitize.record_violation("synthetic A")
    sanitize.record_violation("synthetic B")
    assert len(sanitize.violations()) == 2
    with pytest.raises(SanitizerError, match="synthetic A"):
        sanitize.raise_pending("test context")
    assert sanitize.violations() == ()  # drained
    sanitize.assert_clean()


def test_eager_violation_raises_immediately():
    with pytest.raises(SanitizerError, match="boom"):
        sanitize.record_violation("boom", eager=True)


# -- traced path: checks run inside jit via debug callbacks -----------------


def test_traced_op_records_violation():
    checked = CheckedBulkOps(_LyingOps())

    @jax.jit
    def step(q):
        q, _ = checked.push(q, jnp.arange(3, dtype=jnp.int32), jnp.int32(3))
        return q

    q = step(bulk_ops.make_queue(8, SPEC))
    jax.block_until_ready(q.size)
    assert any("push" in v for v in sanitize.violations())
    with pytest.raises(SanitizerError):
        sanitize.raise_pending("traced push")


def test_traced_superstep_conservation():
    sizes = jnp.asarray([[3, 4], [5, 6]], jnp.int32)
    ok = jnp.asarray([[7, 0], [2, 9]], jnp.int32)     # sums conserved
    bad = jnp.asarray([[7, 1], [2, 9]], jnp.int32)    # one item appeared
    sanitize.trace_check_superstep(sizes, ok, capacity=16)
    jax.effects_barrier()
    assert sanitize.violations() == ()
    sanitize.trace_check_superstep(sizes, bad, capacity=16)
    jax.effects_barrier()
    assert any("conserv" in v for v in sanitize.violations())


# -- multiset fingerprints --------------------------------------------------


def _lanes(*value_lists):
    """Stack single-lane queues into the (lanes, capacity) layout the
    executor-level fingerprint expects."""
    qs = [_seeded(v, check=False)[1] for v in value_lists]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *qs)


def test_fingerprint_is_order_independent():
    fa = sanitize.queues_fingerprint(_lanes([1, 2, 3], [4, 5]))
    fb = sanitize.queues_fingerprint(_lanes([5, 4], [3, 1, 2]))
    sanitize.check_conserved(fa, fb, context="permuted")
    assert sanitize.violations() == ()


def test_fingerprint_detects_lost_item():
    fa = sanitize.queues_fingerprint(_lanes([1, 2, 3], [4]))
    fb = sanitize.queues_fingerprint(_lanes([1, 2], [4]))
    sanitize.check_conserved(fa, fb, context="lost")
    assert any("lost" in v for v in sanitize.violations())


# -- PagedQueue spill/refill accounting -------------------------------------


def test_paged_queue_accounting_clean(monkeypatch):
    monkeypatch.setenv(bulk_ops.CHECK_ENV_VAR, "1")
    from repro.core.queue import PagedQueue

    pq = PagedQueue(16, SPEC, backend="reference")
    assert pq._check
    for start in (0, 20, 40):   # overflow -> host pages
        pq.push(jnp.arange(start, start + 12, dtype=jnp.int32), 12)
    got = pq.steal(0.5)
    assert sum(n for _, n in got) > 0
    while pq.pop()[1]:
        pass
    assert pq.total_size() == 0
    sanitize.assert_clean()


def test_paged_queue_broken_accounting_is_caught(monkeypatch):
    monkeypatch.setenv(bulk_ops.CHECK_ENV_VAR, "1")
    from repro.core.queue import PagedQueue

    pq = PagedQueue(16, SPEC, backend="reference")
    pq.push(jnp.arange(8, dtype=jnp.int32), 8)
    pq.pages.append((np.arange(4, dtype=np.int32), 4))  # smuggled items
    with pytest.raises(SanitizerError, match="accounting"):
        pq.pop()
