"""Fault injection, recovery, snapshots, elastic resize, eviction.

Same dual execution shape as ``tests/test_distributed.py``: with >= 8
devices (the CI ``chaos`` lane exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and
``REPRO_CHECK=1`` before pytest starts) the checks run in-process;
otherwise a subprocess sets both before jax initializes and runs the
identical checks.

The checks:

* **Deterministic replay, kill mid-drain** — the Fig. 9 DAG workload
  with a seeded ``FaultPlan`` killing a lane mid-drain: vmap and mesh
  execute the identical failure and recovery (queues, telemetry,
  adaptive trajectory bit-identical), every node is still explored
  exactly once (the dead ring is redistributed through the
  proportion-1.0 recovery superstep), and the sanitizer sees zero
  violations.
* **Snapshot -> crash -> resume** — a run snapshotting every k rounds is
  killed; a fresh runtime restores the latest snapshot, resumes, and
  lands on the bit-identical final queue state of the uninterrupted run.
* **Elastic re-shard** — a snapshot written by the 8-device mesh runtime
  restores bit-identically onto the single-device vmapped runtime and
  onto a fresh mesh.
* **Shrink / grow** — evacuation drains doomed lanes through recovery
  steals; the rebuilt smaller/larger runtime preserves the exact item
  multiset and carries telemetry + rounds.
* **Planned eviction** — both admission masters drain an evicted
  replica's queued requests onto survivors, stop admitting to it, and
  re-admit it later.
* **Straggler wiring** — ``note_straggler`` counts into telemetry and
  temporarily boosts the emitted steal proportion.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

_HAVE_8 = jax.device_count() >= 8

_CHECKS = textwrap.dedent("""
    import tempfile

    import jax, jax.numpy as jnp
    import numpy as np
    from jax import lax

    from repro.core.policy import StealPolicy
    from repro.distributed import (MeshStealRuntime, evacuate, grow,
                                   launch_runtime, shrink)
    from repro.launch.mesh import make_worker_mesh
    from repro.runtime import FaultPlan, StealRuntime
    from repro.runtime.resilience import NEVER, FaultState, recovery_plan

    SPEC = jax.ShapeDtypeStruct((), jnp.int32)
    DSPEC = {"x": SPEC}

    def tree_eq(a, b):
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                       np.asarray(y)), a, b)

    def items_of(rt):
        q = jax.tree_util.tree_map(np.asarray, rt.queues)
        leaf = q.buf["x"] if isinstance(q.buf, dict) else q.buf
        cap = leaf.shape[1]
        out = []
        for i in range(rt.n_workers):
            lo, sz = int(q.lo[i]), int(q.size[i])
            out += [int(leaf[i][(lo + j) % cap]) for j in range(sz)]
        return sorted(out)

    # -- deterministic replay: kill one lane mid-drain on the fig9 DAG ------

    N_NODES, BATCH, FANOUT = 3000, 16, 4

    def dag_body(ops):
        def body(q, carry):
            q, nodes, n_popped = ops.pop_bulk(q, BATCH, jnp.int32(BATCH))
            valid = jnp.arange(BATCH, dtype=jnp.int32) < n_popped
            kids = (nodes[:, None] * FANOUT + 1
                    + jnp.arange(FANOUT, dtype=jnp.int32)[None, :])
            live = valid[:, None] & (kids < N_NODES)
            flat, flive = kids.reshape(-1), live.reshape(-1)
            order = jnp.argsort(~flive, stable=True)
            flat = jnp.where(flive[order], flat[order], 0)
            q, _ = ops.push(q, flat, jnp.sum(flive.astype(jnp.int32)))
            peak = lax.pmax(carry, "workers")
            return q, carry + jnp.sum(valid.astype(jnp.int32)) + 0 * peak
        return body

    def replay_checks():
        pol = StealPolicy(proportion=0.5, low_watermark=4,
                          high_watermark=32, max_steal=64)
        # Lane 3 dies at round 6 (mid-drain), lane 5 straggles, one
        # exchange is dropped — all scheduled, all replayed identically.
        plan = FaultPlan(kills=((3, 6),), delays=((5, 4, 2),), drops=(8,))
        results = {}
        for mode in ("vmap", "mesh"):
            rt = launch_runtime(8, 1024, SPEC, execution=mode, policy=pol,
                                max_pop=BATCH, fault_plan=plan)
            rt.push(0, jnp.zeros((1,), jnp.int32), 1)
            body = dag_body(rt.ops)
            carry = jnp.zeros((8,), jnp.int32)
            rounds = 0
            while rt.total_size() > 0 and rounds < 500:
                carry, _, r = rt.run_fused(16, body, carry,
                                           until_drained=True)
                rounds += r
            assert (rt.sizes()[rt.dead_lanes()] == 0).all()
            results[mode] = (int(jnp.sum(carry)),
                             np.asarray(carry).tolist(), rounds,
                             rt.telemetry.summary(),
                             rt.controller.history,
                             np.asarray(rt.sizes()).tolist())
        # every node explored exactly once, despite the kill
        assert results["vmap"][0] == results["mesh"][0] == N_NODES
        # dead lane's carry froze at its kill round, identically
        assert results["vmap"][1] == results["mesh"][1]
        assert results["vmap"][2] == results["mesh"][2]  # rounds to drain
        assert results["vmap"][3] == results["mesh"][3]  # telemetry summary
        assert results["vmap"][4] == results["mesh"][4]  # proportions
        assert results["vmap"][5] == results["mesh"][5]  # final sizes
        print("REPLAY-OK", results["mesh"][2])

    def replay_determinism_checks():
        # The same seed gives the same plan; replaying the same plan on
        # the same workload gives bit-identical queues.
        assert (FaultPlan.random(8, seed=11, n_kills=2, n_delays=1)
                == FaultPlan.random(8, seed=11, n_kills=2, n_delays=1))
        assert (FaultPlan.random(8, seed=11, n_kills=2)
                != FaultPlan.random(8, seed=12, n_kills=2))
        plan = FaultPlan.random(8, seed=11, n_kills=2, n_drops=1)
        outs = []
        for _ in range(2):
            rt = StealRuntime(8, 128, DSPEC,
                              policy=StealPolicy(backend="reference"),
                              fault_plan=plan)
            rng = np.random.default_rng(3)
            for w in range(8):
                n = int(rng.integers(5, 40))
                rt.push(w, {"x": jnp.arange(w * 100, w * 100 + n,
                                            dtype=jnp.int32)}, n)
            rt.run_fused(18)
            outs.append(jax.tree_util.tree_map(np.asarray, rt.queues))
        tree_eq(outs[0], outs[1])
        print("REPLAY-DETERMINISM-OK")

    # -- snapshot -> crash -> bit-identical resume ---------------------------

    def snapshot_resume_checks():
        pol = StealPolicy(backend="reference")
        plan = FaultPlan(kills=((2, 5),))

        def mk(mode):
            rt = launch_runtime(8, 128, DSPEC, execution=mode, policy=pol,
                                fault_plan=plan)
            rng = np.random.default_rng(5)
            for w in range(8):
                n = int(rng.integers(5, 40))
                rt.push(w, {"x": jnp.arange(w * 100, w * 100 + n,
                                            dtype=jnp.int32)}, n)
            return rt

        for mode in ("vmap", "mesh"):
            gold = mk(mode)
            for _ in range(9):
                gold.round()

            d = tempfile.mkdtemp()
            crashing = mk(mode)
            crashing.attach_snapshots(d, every=3)
            for _ in range(7):   # "crash" after round 7; snapshot at 6
                crashing.round()
            del crashing

            resumed = mk(mode)
            step = resumed.restore_state(d)
            assert step == 6, step
            while resumed.rounds_run < 9:
                resumed.round()
            tree_eq(jax.tree_util.tree_map(np.asarray, gold.queues),
                    jax.tree_util.tree_map(np.asarray, resumed.queues))
            assert resumed.rounds_run == gold.rounds_run
            assert (resumed.controller.proportion
                    == gold.controller.proportion)
            assert resumed.telemetry.fault_events.get("restore") == 1
        print("SNAPSHOT-RESUME-OK")

    # -- elastic re-shard: mesh snapshot -> 1 device / fresh mesh ------------

    def elastic_reshard_checks():
        pol = StealPolicy(backend="reference")
        plan = FaultPlan(kills=((2, 5),))
        ms = MeshStealRuntime(make_worker_mesh(8), 128, DSPEC, policy=pol,
                              fault_plan=plan)
        rng = np.random.default_rng(5)
        for w in range(8):
            n = int(rng.integers(5, 40))
            ms.push(w, {"x": jnp.arange(w * 100, w * 100 + n,
                                        dtype=jnp.int32)}, n)
        for _ in range(7):
            ms.round()
        d = tempfile.mkdtemp()
        ms.save_state(d)

        # onto ONE device (the vmapped runtime): bit-identical state
        vm = StealRuntime(8, 128, DSPEC, policy=pol, fault_plan=plan)
        vm.restore_state(d)
        tree_eq(jax.tree_util.tree_map(np.asarray, ms.queues),
                jax.tree_util.tree_map(np.asarray, vm.queues))
        assert vm.rounds_run == ms.rounds_run
        assert len(set(jax.tree_util.tree_leaves(vm.queues)[0].devices())) == 1

        # onto a fresh mesh: bit-identical AND lane-sharded again
        ms2 = MeshStealRuntime(make_worker_mesh(8), 128, DSPEC, policy=pol,
                               fault_plan=plan)
        ms2.restore_state(d)
        tree_eq(jax.tree_util.tree_map(np.asarray, ms.queues),
                jax.tree_util.tree_map(np.asarray, ms2.queues))
        assert len(set(jax.tree_util.tree_leaves(
            ms2.queues)[0].devices())) == 8

        # and the re-sharded runtimes CONTINUE identically
        ms.round(); vm.round(); ms2.round()
        tree_eq(jax.tree_util.tree_map(np.asarray, ms.queues),
                jax.tree_util.tree_map(np.asarray, vm.queues))
        tree_eq(jax.tree_util.tree_map(np.asarray, ms.queues),
                jax.tree_util.tree_map(np.asarray, ms2.queues))
        print("ELASTIC-RESHARD-OK")

    # -- shrink / grow -------------------------------------------------------

    def shrink_grow_checks():
        pol = StealPolicy(backend="reference")
        for mode in ("vmap", "mesh"):
            rt = launch_runtime(8, 128, DSPEC, execution=mode, policy=pol,
                                fault_plan=FaultPlan())
            rng = np.random.default_rng(0)
            for w in range(8):
                n = int(rng.integers(5, 40))
                rt.push(w, {"x": jnp.arange(w * 100, w * 100 + n,
                                            dtype=jnp.int32)}, n)
            before = items_of(rt)
            rt.round()
            small = shrink(rt, [2, 5])
            assert small.n_workers == 6
            assert items_of(small) == before            # exact multiset
            assert small.telemetry.fault_events["shrink"] == 2
            big = grow(small, 2)
            assert big.n_workers == 8
            assert items_of(big) == before
            assert (big.sizes()[-2:] == 0).all()        # newcomers empty
            big.round(); big.round()
            assert (big.sizes()[-2:] > 0).any()         # ...then fed
            assert items_of(big) == before
        # can't evacuate everything
        rt = StealRuntime(2, 64, DSPEC, policy=pol, fault_plan=FaultPlan())
        try:
            evacuate(rt, [0, 1])
        except ValueError as e:
            assert "live lane" in str(e)
        else:
            raise AssertionError("evacuating every lane accepted")
        print("SHRINK-GROW-OK")

    # -- planned eviction (both admission masters) ---------------------------

    def evict_checks():
        from repro.distributed import RuntimeAdmissionMaster
        from repro.serve.scheduler import AdmissionMaster, Request

        def drive(master):
            master.submit([Request(prompt=[1, 2, 3]) for _ in range(24)])
            master.rebalance_many(8)
            victim = int(np.argmax([len(r.q) if hasattr(r.q, "__len__")
                                    else 0 for r in master.replicas]))
            queued_before = sum(
                len(r.q) for r in master.replicas)
            drained = master.evict(victim)
            assert drained > 0
            assert sum(len(r.q) for r in master.replicas) == queued_before
            assert len(master.replicas[victim].q) == 0
            assert master.replicas[victim].evicted
            # admission skips the evicted replica
            target = master.submit([Request(prompt=[4])])
            assert target != victim
            st = master.stats()
            assert st["evicted"] == [victim]
            assert st["telemetry"]["faults"]["evict"] == 1
            master.readmit(victim)
            assert not master.replicas[victim].evicted
            assert master.stats()["evicted"] == []

        drive(AdmissionMaster(4))
        for mode in ("vmap", "mesh"):
            drive(RuntimeAdmissionMaster(8, execution=mode, capacity=64))
        print("EVICT-OK")

    # -- straggler wiring ----------------------------------------------------

    def straggler_checks():
        rt = StealRuntime(4, 64, DSPEC,
                          policy=StealPolicy(backend="reference"),
                          fault_plan=FaultPlan())
        p0 = rt.proportion
        rt.note_straggler(rounds=3, factor=2.0)
        assert rt.proportion > p0
        assert rt.telemetry.summary()["straggler_steps"] == 1
        rt.push(0, {"x": jnp.arange(30, dtype=jnp.int32)}, 30)
        for _ in range(4):
            rt.round()
        assert rt.proportion <= max(p0, rt.controller.proportion)  # decayed
        assert rt.controller._boost_rounds_left == 0
        print("STRAGGLER-OK")

    def fault_state_checks():
        # schedule compilation + mutation semantics
        plan = FaultPlan(kills=((1, 4), (1, 2)), delays=((0, 3, 2),),
                         drops=(5, 5, 7))
        st = FaultState(plan, 4)
        assert st.kill_round[1] == 2          # earliest kill wins
        assert list(st.drop_rounds) == [5, 7]  # deduped, sorted
        assert st.dead_at(3)[1] and not st.dead_at(1)[1]
        st.revive(1)
        assert st.kill_round[1] == NEVER
        try:
            FaultPlan(kills=((0, 1), (1, 1))).validate(2)
        except ValueError as e:
            assert "every lane" in str(e)
        else:
            raise AssertionError("total-kill plan accepted")
        # fault + hierarchical compose now (PR 9); construction accepts
        # and kill-on-already-dead raises instead of rescheduling.
        hrt = StealRuntime(4, 64, DSPEC, pod_size=2, fault_plan=FaultPlan())
        hrt.kill_lane(1)
        try:
            hrt.kill_lane(1)
        except ValueError as e:
            assert "already dead" in str(e)
        else:
            raise AssertionError("double kill accepted")
        hrt.revive_lane(1)
        hrt.kill_lane(1)  # legal again after revive
        # revive clears the lane's straggler attribution/boost
        srt = StealRuntime(4, 64, DSPEC,
                           policy=StealPolicy(backend="reference"),
                           fault_plan=FaultPlan())
        p0 = srt.proportion
        srt.note_straggler(rounds=50, factor=2.0, lane=2)
        assert srt.proportion > p0
        srt.kill_lane(2)
        srt.revive_lane(2)
        assert srt.proportion == p0   # boost cleared, not pre-penalized
        assert srt.controller._boost_rounds_left == 0
        # recovery_plan: dead fullest -> alive emptiest, capacity-clamped
        sizes = jnp.asarray([10, 50, 7, 0], jnp.int32)
        dead = jnp.asarray([False, True, False, True])
        plan = np.asarray(recovery_plan(sizes, dead, max_steal=64,
                                        capacity=64))
        assert plan[2].tolist() == [1, 50]   # emptiest survivor robs lane 1
        assert plan[1][1] == 0 and plan[0][1] == 0 and plan[3][1] == 0
        plan = np.asarray(recovery_plan(sizes, dead, max_steal=16,
                                        capacity=64))
        assert plan[2].tolist() == [1, 16]   # window-bounded per round
        plan = np.asarray(recovery_plan(sizes, dead, max_steal=64,
                                        capacity=52))
        assert plan[2].tolist() == [1, 45]   # free-space clamp (52 - 7)
        sizes = jnp.asarray([50, 50, 7, 0], jnp.int32)
        dead = jnp.asarray([False, True, False, False])
        plan = np.asarray(recovery_plan(sizes, dead, max_steal=64,
                                        capacity=52))
        assert plan[3].tolist() == [1, 50]
        assert int(plan[:, 1].sum()) == 50
        print("FAULT-STATE-OK")

    def run_checks():
        assert jax.device_count() >= 8, jax.device_count()
        fault_state_checks()
        replay_determinism_checks()
        replay_checks()
        snapshot_resume_checks()
        elastic_reshard_checks()
        shrink_grow_checks()
        evict_checks()
        straggler_checks()
        print("RESILIENCE-OK")
""")


@pytest.mark.skipif(not _HAVE_8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 before jax init (CI chaos lane)")
def test_resilience_inprocess():
    ns = {}
    exec(compile(_CHECKS, "<resilience-checks>", "exec"), ns)
    ns["run_checks"]()


@pytest.mark.skipif(_HAVE_8, reason="in-process variant runs instead")
def test_resilience_subprocess():
    script = ('import os\n'
              'os.environ["XLA_FLAGS"] = '
              '"--xla_force_host_platform_device_count=8"\n'
              'os.environ["REPRO_CHECK"] = "1"\n'
              + _CHECKS + "\nrun_checks()\n")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "RESILIENCE-OK" in out.stdout, out.stderr[-3000:]
