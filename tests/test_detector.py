"""FailureDetector state machine unit tests (host-only, no devices).

The detector is the ONE escalation policy every layer shares
(``runtime.attach_detector``, both admission masters,
``ServeCluster.auto_evict_after``); these tests pin its transition
semantics so the integration suites (tests/test_hierarchical_fault.py,
tests/test_decode.py) can rely on them.
"""

import pytest

from repro.runtime.detector import (DEAD, HEALTHY, SUSPECTED,
                                    DetectorPolicy, FailureDetector)


def test_policy_validation():
    with pytest.raises(ValueError, match="suspect_after"):
        DetectorPolicy(suspect_after=0)
    with pytest.raises(ValueError, match="healthy_after"):
        DetectorPolicy(healthy_after=0)
    with pytest.raises(ValueError, match="dead_after"):
        DetectorPolicy(suspect_after=3, dead_after=2)
    DetectorPolicy(dead_after=None)          # death escalation disabled
    DetectorPolicy(suspect_after=3, dead_after=3)


def test_happy_path_stays_healthy():
    det = FailureDetector(2)
    for _ in range(20):
        assert det.observe(0, slow=False) == HEALTHY
    assert det.states() == [HEALTHY, HEALTHY]
    assert det.streak(0) == 0


def test_suspect_then_recover():
    det = FailureDetector(1, DetectorPolicy(suspect_after=2, dead_after=None,
                                            healthy_after=2))
    assert det.observe(0, slow=True) == HEALTHY      # streak 1 < 2
    assert det.observe(0, slow=True) == SUSPECTED    # streak 2
    assert det.observe(0, slow=False) == SUSPECTED   # 1 fast < healthy_after
    assert det.observe(0, slow=False) == HEALTHY     # 2 fast
    assert det.streak(0) == 0


def test_fast_resets_slow_streak():
    det = FailureDetector(1, DetectorPolicy(suspect_after=3, dead_after=4))
    det.observe(0, True)
    det.observe(0, True)
    det.observe(0, False)                            # streak resets
    det.observe(0, True)
    det.observe(0, True)
    assert det.state(0) == HEALTHY                   # never reached 3
    det.observe(0, True)
    assert det.state(0) == SUSPECTED


def test_dead_escalation_and_callbacks():
    events = []
    det = FailureDetector(
        2, DetectorPolicy(suspect_after=2, dead_after=4),
        on_suspect=lambda w: events.append(("suspect", w)),
        on_dead=lambda w: events.append(("dead", w)),
        on_revive=lambda w: events.append(("revive", w)))
    for _ in range(4):
        det.observe(1, slow=True)
    assert det.state(1) == DEAD
    # on_suspect fires on EVERY slow observation at/past the threshold
    # (rounds 2 and 3), then on_dead once at round 4's observation.
    assert events == [("suspect", 1), ("suspect", 1), ("dead", 1)]
    # corpses short-circuit: further observations are ignored
    assert det.observe(1, slow=False) == DEAD
    assert det.observe(1, slow=True) == DEAD
    assert events[-1] == ("dead", 1)
    # revive clears everything and fires on_revive
    det.revive(1)
    assert det.state(1) == HEALTHY and det.streak(1) == 0
    assert events[-1] == ("revive", 1)
    # reviving a non-dead lane resets streaks but fires no callback
    det.observe(0, slow=True)
    det.revive(0)
    assert det.streak(0) == 0
    assert events[-1] == ("revive", 1)


def test_dead_after_none_never_kills():
    det = FailureDetector(1, DetectorPolicy(suspect_after=1, dead_after=None))
    for _ in range(50):
        det.observe(0, slow=True)
    assert det.state(0) == SUSPECTED


def test_per_lane_isolation():
    det = FailureDetector(3, DetectorPolicy(suspect_after=2, dead_after=3))
    for _ in range(3):
        det.observe(2, slow=True)
        det.observe(0, slow=False)
    assert det.states() == [HEALTHY, HEALTHY, DEAD]


def test_lane_range_checked():
    det = FailureDetector(2)
    with pytest.raises(ValueError, match="out of range"):
        det.observe(2, slow=True)
    with pytest.raises(ValueError, match="out of range"):
        det.revive(-1)
    with pytest.raises(ValueError, match="n_lanes"):
        FailureDetector(0)


def test_serve_cluster_policy_equivalence():
    """The policy ServeCluster maps auto_evict_after onto: every slow
    wave suspects (boost), ``dead_after`` consecutive slow waves kill,
    one fast wave resets — exactly the old ad-hoc streak counter."""
    boosts, deaths = [], []
    det = FailureDetector(
        1, DetectorPolicy(suspect_after=1, dead_after=3, healthy_after=1),
        on_suspect=lambda w: boosts.append(w),
        on_dead=lambda w: deaths.append(w))
    det.observe(0, True)
    det.observe(0, True)
    det.observe(0, False)     # streak broken at 2: no death
    assert deaths == [] and len(boosts) == 2
    det.observe(0, True)
    det.observe(0, True)
    det.observe(0, True)      # 3 in a row -> dead
    assert deaths == [0] and len(boosts) == 4
