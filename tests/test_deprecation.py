"""The use_kernel dialect keeps working for one release: every shim
emits ``DeprecationWarning`` and returns results identical to the
equivalent ``BulkOps`` backend call."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops as bulk_ops
from repro.core import queue as q_ops
from repro.core.policy import StealPolicy
from repro.runtime import StealRuntime

CAP = 64
SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _seeded(n=10):
    q = bulk_ops.make_queue(CAP, SPEC)
    ref = bulk_ops.make_ops("reference")
    q, _ = ref.push(q, jnp.arange(1, 17, dtype=jnp.int32), jnp.int32(n))
    return q


@pytest.mark.parametrize("use_kernel", [False, True])
def test_queue_shims_warn_and_match_backend(use_kernel):
    backend = bulk_ops.make_ops("pallas" if use_kernel else "reference")
    batch = jnp.arange(1, 17, dtype=jnp.int32)
    q0 = _seeded()

    with pytest.warns(DeprecationWarning, match="push"):
        q_s, n_s = q_ops.push(q0, batch, jnp.int32(5),
                              use_kernel=use_kernel)
    q_b, n_b = backend.push(q0, batch, jnp.int32(5))
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(q_s.buf), np.asarray(q_b.buf))

    with pytest.warns(DeprecationWarning, match="pop_bulk"):
        q_s, b_s, n_s = q_ops.pop_bulk(q0, 8, jnp.int32(4),
                                       use_kernel=use_kernel)
    q_b, b_b, n_b = backend.pop_bulk(q0, 8, jnp.int32(4))
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))

    with pytest.warns(DeprecationWarning, match="steal_exact"):
        q_s, b_s, n_s = q_ops.steal_exact(q0, jnp.int32(4), max_steal=8,
                                          use_kernel=use_kernel)
    q_b, b_b, n_b = backend.steal_exact(q0, jnp.int32(4), max_steal=8)
    assert int(n_s) == int(n_b)
    assert int(q_s.lo) == int(q_b.lo)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))

    with pytest.warns(DeprecationWarning, match="steal"):
        q_s, b_s, n_s = q_ops.steal(q0, 0.5, max_steal=8,
                                    use_kernel=use_kernel)
    q_b, b_b, n_b = backend.steal(q0, 0.5, max_steal=8)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))


def test_inplace_shims_warn_and_match_donate():
    backend = bulk_ops.make_ops("reference")
    batch = jnp.arange(1, 17, dtype=jnp.int32)
    q0 = _seeded()

    with pytest.warns(DeprecationWarning, match="push_inplace"):
        q_s, n_s = q_ops.push_inplace(q0, batch, jnp.int32(5))
    q_b, n_b = backend.push(q0, batch, jnp.int32(5), donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(q_s.buf), np.asarray(q_b.buf))

    with pytest.warns(DeprecationWarning, match="pop_bulk_inplace"):
        q_s, b_s, n_s = q_ops.pop_bulk_inplace(q0, 8, jnp.int32(4))
    q_b, b_b, n_b = backend.pop_bulk(q0, 8, jnp.int32(4), donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))

    with pytest.warns(DeprecationWarning, match="steal_exact_inplace"):
        q_s, b_s, n_s = q_ops.steal_exact_inplace(q0, jnp.int32(4),
                                                  max_steal=8)
    q_b, b_b, n_b = backend.steal_exact(q0, jnp.int32(4), max_steal=8,
                                        donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))


def test_inplace_ops_bundle_warns_and_matches_donate():
    """The pre-BulkOps ``inplace_ops()`` bundle keeps its old surface."""
    import repro.core as core_pkg

    # package-level re-exports of the shims still resolve
    assert core_pkg.push is q_ops.push
    assert core_pkg.steal_exact is q_ops.steal_exact
    with pytest.warns(DeprecationWarning, match="inplace_ops"):
        bundle = q_ops.inplace_ops()
    backend = bulk_ops.make_ops("reference")
    q0 = _seeded()
    batch = jnp.arange(1, 17, dtype=jnp.int32)
    q_s, n_s = bundle.push(q0, batch, jnp.int32(5))
    q_b, n_b = backend.push(q0, batch, jnp.int32(5), donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(q_s.buf), np.asarray(q_b.buf))
    q_s, b_s, n_s = bundle.steal(q0, 0.5, max_steal=8, use_kernel=True)
    q_b, b_b, n_b = bulk_ops.make_ops("pallas").steal(q0, 0.5, max_steal=8,
                                                      donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))
    q_s, it_s, v_s = bundle.pop(q0)
    q_b, it_b, v_b = backend.pop(q0, donate=True)
    assert bool(v_s) == bool(v_b) and int(it_s) == int(it_b)
    q_s, b_s, n_s = bundle.pop_bulk(q0, 8, jnp.int32(3))
    q_b, b_b, n_b = backend.pop_bulk(q0, 8, jnp.int32(3), donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))
    q_s, b_s, n_s = bundle.steal_exact(q0, jnp.int32(2), max_steal=8)
    q_b, b_b, n_b = backend.steal_exact(q0, jnp.int32(2), max_steal=8,
                                        donate=True)
    assert int(n_s) == int(n_b)
    np.testing.assert_array_equal(np.asarray(b_s), np.asarray(b_b))


def test_policy_use_kernel_maps_to_backend():
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        pol = StealPolicy(use_kernel=True)
    assert pol.backend == "pallas"
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        pol = StealPolicy(use_kernel=False)
    assert pol.backend == "reference"
    # no shim kwarg -> no warning, replace() keeps the backend silently
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pol = StealPolicy(backend="auto")
        import dataclasses
        pol2 = dataclasses.replace(pol, proportion=0.3)
    assert pol2.backend == "auto" and pol2.proportion == 0.3


def test_runtime_use_kernel_maps_to_backend():
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        rt = StealRuntime(2, 64, SPEC, use_kernel=True)
    assert rt.ops.resolved == "pallas"
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        rt = StealRuntime(2, 64, SPEC, use_kernel=False)
    assert rt.ops.resolved == "reference"


def test_new_surface_is_warning_free():
    """The whole new-dialect hot path raises no DeprecationWarning."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=4,
                          max_steal=16, backend="auto")
        rt = StealRuntime(2, 64, SPEC, policy=pol)
        rt.push(0, jnp.arange(1, 9, dtype=jnp.int32), 8)
        rt.round()
        rt.run_fused(2)
        rt.run_fused(2, until_drained=True)
        ops = bulk_ops.make_ops("auto", capacity=CAP, max_push=16,
                                max_pop=8, max_steal=32)
        q = bulk_ops.make_queue(CAP, SPEC)
        q, _ = ops.push(q, jnp.arange(8), 8, donate=True)
        q, _, _ = ops.steal(q, 0.5, max_steal=32)
        q, _, _ = ops.pop_bulk(q, 8, 2)
        q, _, _ = ops.pop(q)