"""The ``use_kernel`` dialect had its one deprecation release (PR 3 -> PR 4)
and is now REMOVED: the shims are gone from the surface, the old keyword
raises, and the whole replacement dialect (``BulkOps`` backends +
``donate=``) is warning-free.  The behavioural parity the shims were
tested for lives on in the ``backend=``-parametrized suites
(test_queue / test_runtime / test_master)."""

import inspect
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import ops as bulk_ops
from repro.core import queue as q_ops
from repro.core.dd.parallel import parallel_solve
from repro.core.policy import StealPolicy
from repro.runtime import StealRuntime

CAP = 64
SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def test_queue_shims_are_gone():
    """No module-level op functions, no *_inplace variants, no bundle."""
    for name in ("push", "pop_bulk", "steal", "steal_exact",
                 "push_inplace", "pop_bulk_inplace", "steal_exact_inplace",
                 "inplace_ops", "InPlaceOps"):
        assert not hasattr(q_ops, name), name
    import repro.core as core_pkg

    for name in ("push", "pop_bulk", "steal", "steal_exact"):
        assert not hasattr(core_pkg, name), name
    # the non-deprecated survivors still resolve
    assert core_pkg.pop is q_ops.pop
    assert core_pkg.make_queue is bulk_ops.make_queue


def test_use_kernel_kwarg_raises_everywhere():
    with pytest.raises(TypeError):
        StealPolicy(use_kernel=True)
    with pytest.raises(TypeError):
        StealRuntime(2, CAP, SPEC, use_kernel=True)
    assert "use_kernel" not in inspect.signature(parallel_solve).parameters
    assert "use_kernel" not in inspect.signature(StealPolicy).parameters


def test_new_surface_is_warning_free():
    """The whole replacement-dialect hot path raises no
    DeprecationWarning."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=4,
                          max_steal=16, backend="auto")
        rt = StealRuntime(2, 64, SPEC, policy=pol)
        rt.push(0, jnp.arange(1, 9, dtype=jnp.int32), 8)
        rt.round()
        rt.run_fused(2)
        rt.run_fused(2, until_drained=True)
        ops = bulk_ops.make_ops("auto", capacity=CAP, max_push=16,
                                max_pop=8, max_steal=32)
        q = bulk_ops.make_queue(CAP, SPEC)
        q, _ = ops.push(q, jnp.arange(8), 8, donate=True)
        q, _, _ = ops.steal(q, 0.5, max_steal=32)
        q, _, _ = ops.pop_bulk(q, 8, 2)
        q, _, _ = ops.pop(q)
