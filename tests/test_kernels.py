"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracle (assigned requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dd_expand.ops import expand_layer_bulk
from repro.kernels.dd_expand.ref import expand_ref
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.queue_steal.ops import steal_gather
from repro.kernels.queue_steal.ref import ring_gather_ref
from repro.kernels.queue_transfer.ops import transfer_splice
from repro.kernels.queue_transfer.ref import ring_transfer_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- flash

FLASH_CASES = [
    # (B, S, T, H, K, hd, causal, window, softcap, dtype)
    (2, 128, 128, 4, 2, 64, True, None, None, jnp.float32),
    (1, 256, 256, 4, 4, 64, True, 128, None, jnp.float32),
    (2, 128, 256, 8, 2, 32, True, None, 50.0, jnp.float32),
    (1, 128, 128, 2, 1, 128, False, None, None, jnp.float32),
    (1, 128, 128, 4, 4, 64, True, None, None, jnp.bfloat16),
    (2, 128, 256, 4, 2, 32, True, 64, 30.0, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, S, T, H, K, hd, causal, window, cap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, K, hd), jnp.float32).astype(dtype)
    out_k = mha(q, k, v, causal=causal, window=window, softcap=cap,
                interpret=True)
    ke = jnp.repeat(k, H // K, 2)
    ve = jnp.repeat(v, H // K, 2)
    out_r = attention_ref(q, ke, ve, causal=causal, window=window,
                          softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


# ----------------------------------------------------------- queue_steal

STEAL_CASES = [
    (512, 8, 256, 0, 100, jnp.float32),
    (512, 8, 256, 500, 256, jnp.float32),     # wraps
    (1024, 16, 512, 777, 333, jnp.float32),
    (256, 4, 256, 255, 256, jnp.int32),       # full wrap, int payload
    (256, 4, 128, 13, 0, jnp.float32),        # empty steal
    (256, 128, 256, 100, 200, jnp.bfloat16),
]


@pytest.mark.parametrize("case", STEAL_CASES)
def test_queue_steal_matches_ref(case):
    cap, W, max_steal, lo, n, dtype = case
    if jnp.issubdtype(dtype, jnp.integer):
        buf = jax.random.randint(KEY, (cap, W), 0, 1000, dtype)
    else:
        buf = jax.random.normal(KEY, (cap, W), jnp.float32).astype(dtype)
    out_k = steal_gather(buf, jnp.int32(lo), jnp.int32(n),
                         max_steal=max_steal, interpret=True)
    out_r = ring_gather_ref(buf, lo, n, max_steal)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ----------------------------------------------------- queue_transfer

# (cap, W, n_lanes, max_steal, head, src_row, n, dtype)
TRANSFER_CASES = [
    (512, 8, 4, 256, 0, 0, 100, jnp.float32),
    (512, 8, 4, 256, 500, 3, 256, jnp.float32),   # splice wraps the ring
    (1024, 16, 8, 128, 777, 5, 33, jnp.float32),
    (256, 4, 4, 64, 255, 2, 64, jnp.int32),       # int payload, wrap
    (256, 4, 4, 64, 13, 1, 0, jnp.float32),       # empty transfer
    (256, 128, 2, 128, 100, 1, 77, jnp.bfloat16),
]


@pytest.mark.parametrize("case", TRANSFER_CASES)
def test_queue_transfer_matches_ref(case):
    cap, W, n_lanes, max_steal, head, src_row, n, dtype = case
    ks = jax.random.split(KEY, 2)
    if jnp.issubdtype(dtype, jnp.integer):
        buf = jax.random.randint(ks[0], (cap, W), 0, 1000, dtype)
        gathered = jax.random.randint(ks[1], (n_lanes, max_steal, W), 0,
                                      1000, dtype)
    else:
        buf = jax.random.normal(ks[0], (cap, W), jnp.float32).astype(dtype)
        gathered = jax.random.normal(ks[1], (n_lanes, max_steal, W),
                                     jnp.float32).astype(dtype)
    out_k = transfer_splice(buf, gathered, jnp.int32(head),
                            jnp.int32(src_row), jnp.int32(n),
                            max_steal=max_steal, interpret=True)
    out_r = ring_transfer_ref(buf, gathered.reshape(-1, W),
                              head, src_row * max_steal, n)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_queue_transfer_equals_select_then_push():
    """The fused transfer must equal the two-step oracle: select the
    victim's window row, then ring-scatter it at the head."""
    from repro.kernels.queue_push.ref import ring_scatter_ref

    cap, W, n_lanes, max_steal = 512, 8, 4, 128
    ks = jax.random.split(KEY, 2)
    buf = jax.random.normal(ks[0], (cap, W), jnp.float32)
    gathered = jax.random.normal(ks[1], (n_lanes, max_steal, W), jnp.float32)
    for head, src_row, n in [(0, 0, 128), (450, 3, 100), (77, 2, 1)]:
        fused = transfer_splice(buf, gathered, jnp.int32(head),
                                jnp.int32(src_row), jnp.int32(n),
                                max_steal=max_steal, interpret=True)
        two_step = ring_scatter_ref(buf, gathered[src_row], head, n)
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(two_step))


# --------------------------------------------------------------- ssd_scan

SSD_CASES = [
    (2, 64, 4, 16, 32, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 256, 8, 64, 128, 128),
    (1, 64, 1, 8, 8, 64),       # single chunk (S == Q)
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_ref(case):
    B, S, nh, hd, ns, Q = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, ns)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, ns)) * 0.3
    D = jnp.ones((nh,))
    y_k, fin_k = ssd(x, dt, A, Bm, Cm, D, chunk=Q, interpret=True)
    y_r, fin_r = ssd_chunked(x, dt, A, Bm, Cm, D, Q)
    np.testing.assert_allclose(y_k, y_r, atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(fin_k, fin_r, atol=5e-5, rtol=5e-4)


def test_ssd_decode_consistency():
    """Chunked scan == running mamba_decode_step token by token (state)."""
    from repro.kernels.ssd_scan.ref import ssd_chunk_ref

    Q, hd, ns = 16, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Q, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Q,)))
    a = -jnp.exp(jax.random.normal(ks[2], ()) * 0.3)
    Bm = jax.random.normal(ks[3], (Q, ns)) * 0.3
    Cm = jax.random.normal(ks[4], (Q, ns)) * 0.3
    y, state = ssd_chunk_ref(x, dt, a, Bm, Cm, jnp.float32(0.0),
                             jnp.zeros((hd, ns)))
    # sequential recurrence oracle
    st = jnp.zeros((hd, ns))
    ys = []
    for t in range(Q):
        dA = jnp.exp(dt[t] * a)
        st = st * dA + dt[t] * jnp.outer(x[t], Bm[t])
        ys.append(st @ Cm[t])
    np.testing.assert_allclose(y, jnp.stack(ys), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(state, st, atol=1e-4, rtol=1e-3)


# -------------------------------------------------------------- dd_expand

@pytest.mark.parametrize("N", [256, 512, 1024])
@pytest.mark.parametrize("wp", [(3, 8), (50, 1), (0, 0)])
def test_dd_expand_matches_ref(N, wp):
    w, p = wp
    s = jax.random.randint(KEY, (N,), -1, 100, jnp.int32)
    v = jax.random.randint(KEY, (N,), 0, 50, jnp.int32)
    sk, vk = expand_layer_bulk(s, v, w, p, interpret=True)
    sr, vr = expand_ref(s, v, w, p)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
