"""The static lint pass (repro.analysis.lint): the live tree must lint
clean, and each rule must catch its planted fixture — K1 (kernel
package missing predicate/oracle/parity test), D1 (use-after-donate),
U1 (use_kernel-era patterns)."""

from pathlib import Path

from repro.analysis import lint

REPO = lint.REPO_ROOT


def _rules(findings):
    return sorted({f.rule for f in findings})


def test_tree_lints_clean():
    findings = lint.lint_paths([REPO / d for d in lint.DEFAULT_PATHS])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_planted_use_after_donate_is_caught(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "def f(ops, q, batch, n):\n"
        "    q2, pushed = ops.push(q, batch, n, donate=True)\n"
        "    return q.size, pushed\n")
    findings = lint.lint_file(bad)
    assert _rules(findings) == ["D1"]
    assert findings[0].line == 3 and "donated at line 2" in findings[0].message


def test_planted_dotted_use_after_donate_is_caught(tmp_path):
    bad = tmp_path / "bad_attr.py"
    bad.write_text(
        "def f(self, batch, n):\n"
        "    out = self.ops.push(self.state, batch, n, donate=True)\n"
        "    return self.state.size\n")
    findings = lint.lint_file(bad)
    assert _rules(findings) == ["D1"]
    assert "self.state.size" in findings[0].message


def test_same_statement_rebind_is_clean(tmp_path):
    good = tmp_path / "good.py"
    good.write_text(
        "def f(ops, q, batch, n):\n"
        "    q, pushed = ops.push(q, batch, n, donate=True)\n"
        "    return q.size, pushed\n"
        "def g(self, batch, n):\n"
        "    self.state, pushed = self.ops.push(self.state, batch, n,\n"
        "                                       donate=True)\n"
        "    return self.state.size, pushed\n")
    assert lint.lint_file(good) == []


def test_donate_false_is_clean(tmp_path):
    good = tmp_path / "pure.py"
    good.write_text(
        "def f(ops, q, batch, n):\n"
        "    q2, pushed = ops.push(q, batch, n, donate=False)\n"
        "    return q.size, pushed\n")
    assert lint.lint_file(good) == []


def test_use_kernel_era_patterns_are_caught(tmp_path):
    bad = tmp_path / "legacy.py"
    bad.write_text(
        "def caller(q):\n"
        "    return steal(q, use_kernel=True)\n"
        "def push_inplace(q, batch, n):\n"
        "    return q\n")
    findings = lint.lint_file(bad)
    assert _rules(findings) == ["U1"]
    assert len(findings) == 2


def test_docstring_mentions_are_exempt(tmp_path):
    ok = tmp_path / "docs_only.py"
    ok.write_text(
        '"""The old use_kernel= flags and push_inplace variants are\n'
        'gone (PR 3)."""\n'
        "X = 1\n")
    assert lint.lint_file(ok) == []


def test_kernel_package_missing_predicate_is_caught(tmp_path):
    """K1 on a synthetic repo root: a kernel package with no
    *_supported predicate, no oracle, and no parity test yields all
    three findings."""
    pkg = tmp_path / "src" / "repro" / "kernels" / "fancy_op"
    pkg.mkdir(parents=True)
    (pkg / "kernel.py").write_text("def run(x):\n    return x\n")
    (tmp_path / "tests").mkdir()
    findings = lint.lint_paths([], root=tmp_path)
    assert _rules(findings) == ["K1"]
    msgs = "\n".join(f.message for f in findings)
    assert "geometry predicate" in msgs
    assert "oracle" in msgs
    assert "parity test" in msgs


def test_aliasing_kernel_without_donating_op_is_caught(tmp_path):
    """K2 on a synthetic repo root: an input_output_aliases kernel whose
    BulkOps op is not donate-jitted."""
    pkg = tmp_path / "src" / "repro" / "kernels" / "queue_push"
    pkg.mkdir(parents=True)
    (pkg / "kernel.py").write_text(
        "def ring_scatter_supported(c, b):\n    return True\n"
        "def run(x):\n"
        "    return pallas_call(k, input_output_aliases={4: 0})(x)\n")
    (pkg / "ref.py").write_text("def ref(x):\n    return x\n")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_parity.py").write_text("import repro.kernels.queue_push\n")
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir(parents=True)
    (core / "ops.py").write_text(
        "import types, jax\n"
        "def _donating():\n"
        "    return types.SimpleNamespace(push=jax.jit(_push))\n"
        "class BulkOps:\n"
        "    def push(self, q, batch, n):\n"
        "        return q, n\n")
    findings = lint.lint_paths([], root=tmp_path)
    assert _rules(findings) == ["K2"]
    msgs = "\n".join(f.message for f in findings)
    assert "donate_argnums" in msgs
    assert "donate= keyword" in msgs


def test_cli_clean_tree(capsys):
    assert lint.main([]) == 0
    assert "clean" in capsys.readouterr().out
