"""`sharded_superstep` on a real mesh axis: the shard_map production
driver, exercised on 8 fake host devices.

Two execution shapes for one test body:

* when the process already has >= 8 devices (the CI lane exports
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest
  starts) the checks run IN-PROCESS — this is the lane that actually
  exercises the shard_map path alongside the vmap lanes the rest of the
  suite uses;
* otherwise (the tier-1 run on a 1-device host) a subprocess sets the
  flag before jax initializes and runs the identical checks, mirroring
  ``tests/test_sharding.py``.

The checks: the shard_map driver conserves tasks, returns the FULL
``RebalanceStats`` (not just ``sizes_after``), matches the vmapped
driver lane-for-lane on both exchanges, honours an explicitly pinned
``ops=`` backend, and runs hierarchically over a pod axis.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

_HAVE_8 = jax.device_count() >= 8

_CHECKS = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.core import ops as bulk_ops
    from repro.core.master import RebalanceStats
    from repro.core.policy import StealPolicy
    from repro.core.sharded_queue import (make_sharded_queues,
                                          sharded_superstep,
                                          vmapped_superstep)

    SPEC = jax.ShapeDtypeStruct((), jnp.int32)
    OPS = bulk_ops.make_ops("reference")
    SIZES = [40, 0, 0, 0, 25, 0, 3, 0]

    def fill(qs, sizes):
        nxt = 1
        for i, n in enumerate(sizes):
            vals = np.zeros((max(sizes) + 1,), np.int32)
            vals[:n] = range(nxt, nxt + n)
            nxt += n
            qi = jax.tree_util.tree_map(lambda x: x[i], qs)
            qi, _ = OPS.push(qi, jnp.asarray(vals), n)
            qs = jax.tree_util.tree_map(
                lambda full, one: full.at[i].set(one), qs, qi)
        return qs

    def totals(qs):
        out = []
        for i in range(qs.size.shape[0]):
            qi = jax.tree_util.tree_map(lambda x: np.asarray(x)[i], qs)
            qi = bulk_ops.QueueState(
                buf=jax.tree_util.tree_map(jnp.asarray, qi.buf),
                lo=jnp.asarray(qi.lo), size=jnp.asarray(qi.size))
            while int(qi.size) > 0:
                qi, item, valid = OPS.pop(qi)
                assert bool(valid)
                out.append(int(item))
        return sorted(out)

    def seed():
        return fill(make_sharded_queues(8, 128, SPEC), SIZES)

    def run_checks():
        assert jax.device_count() >= 8, jax.device_count()
        mesh = jax.make_mesh((8,), ("data",))

        for exchange in ("compact", "dense"):
            pol = StealPolicy(proportion=0.5, low_watermark=2,
                              high_watermark=8, max_steal=32,
                              exchange=exchange)
            ids_before = totals(seed())
            qs = seed()
            qs_v = seed()
            step = sharded_superstep(mesh, pol)
            step_v = vmapped_superstep(pol)
            first = None
            for _ in range(3):
                qs, stats = step(qs)
                qs_v, stats_v = step_v(qs_v)
                first = first if first is not None else stats
            # full stats, not just sizes_after (round 1 surely steals)
            assert isinstance(stats, RebalanceStats), type(stats)
            assert int(np.asarray(first.n_steals)[0]) >= 1
            exp = 32 * 4 * (8 if exchange == "dense" else 1)
            assert int(np.asarray(first.bytes_moved)[0]) == exp
            assert int(np.asarray(stats.bytes_moved)[0]) in (0, exp)
            # shard_map == vmap, lane for lane (sizes AND stats)
            np.testing.assert_array_equal(np.asarray(qs.size),
                                          np.asarray(qs_v.size))
            np.testing.assert_array_equal(
                np.asarray(stats.sizes_after).reshape(-1),
                np.asarray(stats_v.sizes_after)[0])
            assert (int(np.asarray(stats.n_transferred)[0])
                    == int(np.asarray(stats_v.n_transferred)[0]))
            # conservation through the shard_map path
            assert totals(qs) == ids_before, exchange

        # explicit ops= pinning selects the same implementation
        pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                          max_steal=32)
        qs_a = seed()
        qs_b = seed()
        qs_a, _ = sharded_superstep(mesh, pol)(qs_a)
        qs_b, _ = sharded_superstep(mesh, pol, ops=OPS)(qs_b)
        np.testing.assert_array_equal(np.asarray(qs_a.size),
                                      np.asarray(qs_b.size))

        # hierarchical over a (2 pods x 4 workers) mesh
        mesh2 = jax.make_mesh((2, 4), ("pods", "data"))
        ids_before = totals(seed())
        qs = seed()
        step_h = sharded_superstep(mesh2, pol, worker_axis="data",
                                   pod_axis="pods")
        for _ in range(3):
            qs, stats = step_h(qs)
        assert totals(qs) == ids_before
        assert int(np.asarray(qs.size).sum()) == sum(SIZES)
        # hierarchical stats expose the xpod fields (pod-level view)
        assert np.asarray(stats.n_steals_xpod).shape == (1,)
        assert np.asarray(stats.bytes_moved_xpod).shape == (1,)
        print("SHARDED-SUPERSTEP-OK")
""")


@pytest.mark.skipif(not _HAVE_8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 before jax init (CI lane)")
def test_sharded_superstep_inprocess():
    ns = {}
    exec(compile(_CHECKS, "<sharded-superstep-checks>", "exec"), ns)
    ns["run_checks"]()


@pytest.mark.skipif(_HAVE_8, reason="in-process variant runs instead")
def test_sharded_superstep_subprocess():
    script = ('import os\n'
              'os.environ["XLA_FLAGS"] = '
              '"--xla_force_host_platform_device_count=8"\n'
              + _CHECKS + "\nrun_checks()\n")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED-SUPERSTEP-OK" in out.stdout, out.stderr[-2000:]
