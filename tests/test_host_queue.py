"""Tests for the faithful host-level port (Listings 1-4), the baselines,
and the unifying HostQueue protocol."""

import threading

import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.core.host_queue import (
    HostQueue,
    LinkedWSQueue,
    PerItemDequeQueue,
    ResizingArrayQueue,
    llist_from_iter,
)


def collect(begin, count=None):
    out, node = [], begin
    while node is not None:
        out.append(node.payload)
        node = node.next
    if count is not None:
        assert len(out) == count
    return out


def test_push_is_head_splice():
    q = LinkedWSQueue()
    q.push(llist_from_iter([1, 2, 3]))  # 1 is head-most of this batch
    q.push(llist_from_iter([4, 5]))
    # Owner pops at head: most recent batch first, in batch order.
    assert q.pop() == 4
    assert q.pop() == 5
    assert q.pop() == 1
    assert len(q) == 2


def test_pop_empty_returns_none():
    q = LinkedWSQueue()
    assert q.pop() is None


def test_steal_takes_tail_suffix():
    q = LinkedWSQueue()
    q.push(llist_from_iter(list(range(10))))  # head=0 ... tail=9
    begin, end, count = q.steal(0.3)
    # Listing 4 faithfully: n_skip = floor(10*0.7) = 7, the traversal lands
    # ON node 7 and the cut severs AFTER it (begin = start->next), so the
    # cut node stays with the owner: stolen suffix is {8, 9}, count = 2
    # ("approximately the specified fraction" per the paper's own wording —
    # the ring-buffer port in core/queue.py has no cut node and steals an
    # exact 3; see test_queue.py).
    assert count == 2
    assert collect(begin, count) == [8, 9]
    assert len(q) == 8


def test_steal_aborts_below_limit():
    q = LinkedWSQueue(queue_limit=4)
    q.push(llist_from_iter([1, 2, 3]))
    assert q.steal(0.5) == (None, None, 0)
    assert len(q) == 3


def test_steal_optimized_matches_plain():
    for p in (0.1, 0.25, 0.5, 0.75):
        q1, q2 = LinkedWSQueue(), LinkedWSQueue()
        items = list(range(100))
        q1.push(llist_from_iter(items))
        q2.push(llist_from_iter(items))
        b1, _, c1 = q1.steal(p)
        b2, _, c2 = q2.steal_optimized(p)
        assert c1 == c2
        assert collect(b1, c1) == collect(b2, c2)
        assert len(q1) == len(q2)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.one_of(
            st.tuples(st.just("push"), st.integers(1, 20)),
            st.tuples(st.just("pop"), st.just(0)),
            st.tuples(st.just("steal"), st.floats(0.05, 0.95)),
        ),
        min_size=1,
        max_size=30,
    )
)
def test_host_queue_conserves_tasks(ops):
    q = LinkedWSQueue()
    nxt = 0
    produced, removed = set(), []
    for op, arg in ops:
        if op == "push":
            vals = list(range(nxt, nxt + arg))
            nxt += arg
            q.push(llist_from_iter(vals))
            produced.update(vals)
        elif op == "pop":
            v = q.pop()
            if v is not None:
                removed.append(v)
        else:
            begin, _, count = q.steal(arg)
            removed.extend(collect(begin, count))
    remaining = q.drain()
    assert len(removed) == len(set(removed))
    assert set(removed) | set(remaining) == produced
    assert len(removed) + len(remaining) == len(produced)


def test_threaded_owner_single_stealer_no_loss():
    """The paper's concurrency model, for real: one owner thread doing bulk
    pushes/pops, one stealer thread doing proportional steals.  Afterwards
    every task is accounted for exactly once."""
    q = LinkedWSQueue()
    N_BATCHES, BATCH = 200, 50
    owner_got, stolen = [], []
    stop = threading.Event()

    def owner():
        nxt = 0
        for _ in range(N_BATCHES):
            q.push(llist_from_iter(range(nxt, nxt + BATCH)))
            nxt += BATCH
            for _ in range(BATCH // 2):
                v = q.pop()
                if v is not None:
                    owner_got.append(v)
        stop.set()

    def stealer():
        # Run while the owner is live; after it stops, sweep until a steal
        # returns nothing (steal legitimately aborts with 0 for tiny queues
        # because the cut node stays with the owner — Listing 4 semantics).
        while not stop.is_set():
            begin, _, count = q.steal_optimized(0.5)
            if count:
                stolen.extend(collect(begin))
        while True:
            begin, _, count = q.steal_optimized(0.5)
            if not count:
                break
            stolen.extend(collect(begin))

    t1 = threading.Thread(target=owner)
    t2 = threading.Thread(target=stealer)
    t1.start(); t2.start()
    t1.join(); t2.join()
    remaining = q.drain()
    total = owner_got + stolen + remaining
    assert len(total) == N_BATCHES * BATCH
    assert len(set(total)) == len(total)  # no duplication
    assert set(total) == set(range(N_BATCHES * BATCH))  # no loss


@pytest.mark.parametrize("cls", [PerItemDequeQueue, ResizingArrayQueue])
def test_baselines_semantics(cls):
    q = cls() if cls is PerItemDequeQueue else cls(capacity=4)
    q.push(range(10))
    assert q.pop() == 9
    stolen = q.steal(0.5)
    assert stolen == [0, 1, 2, 3]
    assert len(q) == 5


# ---------------------------------------------------------------------------
# The HostQueue protocol: every implementation through ONE surface
# ---------------------------------------------------------------------------


def _paged_queue():
    import jax
    import jax.numpy as jnp

    from repro.core.queue import PagedQueue

    return PagedQueue(16, jax.ShapeDtypeStruct((), jnp.int32))


PROTOCOL_IMPLS = [
    ("LinkedWSQueue", LinkedWSQueue),
    ("PerItemDequeQueue", PerItemDequeQueue),
    ("ResizingArrayQueue", lambda: ResizingArrayQueue(capacity=4)),
]


@pytest.mark.parametrize("name,factory", PROTOCOL_IMPLS)
def test_hostqueue_protocol_uniform_semantics(name, factory):
    """push_bulk / pop_item / steal_bulk / len behave identically across
    every host implementation: owner pops newest (deque convention:
    later pushed = newer), stealer takes the oldest side, conservation
    holds."""
    q = factory()
    assert isinstance(q, HostQueue)
    assert len(q) == 0 and q.pop_item() is None
    q.push_bulk(range(40))
    assert len(q) == 40
    assert q.pop_item() == 39  # owner pops newest
    stolen = q.steal_bulk(0.5)
    assert stolen  # something moved
    # stealer takes the oldest side: stolen ids all older than remaining
    drained = []
    while True:
        v = q.pop_item()
        if v is None:
            break
        drained.append(v)
    assert max(stolen) < min(drained)
    # conservation: every id accounted for exactly once
    total = sorted(stolen + drained + [39])
    assert total == list(range(40))


@pytest.mark.parametrize("name,factory", PROTOCOL_IMPLS)
def test_hostqueue_make_push_batch_roundtrip(name, factory):
    """The benchmark harness's two-phase push (prepare untimed, splice
    timed) moves the same multiset as plain push_bulk (intra-batch order
    is the implementation's native one)."""
    q = factory()
    q.push_batch(q.make_batch([1, 2, 3]))
    assert len(q) == 3
    got = {q.pop_item(), q.pop_item(), q.pop_item()}
    assert got == {1, 2, 3} and q.pop_item() is None


def test_paged_queue_satisfies_protocol_with_conservation():
    """PagedQueue speaks the same protocol through its device ring +
    host pages.  Paging makes global LIFO order and the steal side
    approximate (whole-page steals are the documented cheapest path), so
    the contract here is conformance + conservation."""
    q = _paged_queue()
    assert isinstance(q, HostQueue)
    assert q.pop_item() is None
    q.push_bulk(range(40))  # exceeds the 16-slot ring: exercises paging
    assert len(q) == 40
    first = q.pop_item()
    assert first is not None
    stolen = q.steal_bulk(0.5)
    assert stolen  # something moved in bulk
    drained = []
    while True:
        v = q.pop_item()
        if v is None:
            break
        drained.append(v)
    total = sorted(stolen + drained + [first])
    assert total == list(range(40))
    q.push_batch(q.make_batch([100, 101]))
    assert len(q) == 2
    assert sorted([q.pop_item(), q.pop_item()]) == [100, 101]
