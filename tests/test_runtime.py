"""The unified runtime: executor conservation across adaptive rounds
(per-round, fused, and early-exit fused), backend-dispatch parity
(pallas-routed vs reference BulkOps for steal/push/pop on dynamic
cursors straddling block boundaries), and donate= vs pure equivalence.
Executor tests are parametrized over ``backend in ("reference", "auto",
"relaxed")`` — the oracle, the geometry-resolved routing and the
fence-free relaxed backend must be observationally identical."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops as bulk_ops
from repro.core.policy import StealPolicy
from repro.kernels.queue_push.kernel import ring_scatter, ring_slice
from repro.kernels.queue_push.ref import ring_scatter_ref, ring_slice_ref
from repro.kernels.queue_steal.kernel import DEFAULT_BLOCK
from repro.kernels.queue_steal.ops import steal_gather
from repro.kernels.queue_steal.ref import ring_gather_ref
from repro.runtime import AdaptiveConfig, StealRuntime

SPEC = jax.ShapeDtypeStruct((), jnp.int32)
BACKENDS = ("reference", "auto", "relaxed")
REF = bulk_ops.make_ops("reference")
PALLAS = bulk_ops.make_ops("pallas")


def _seed(rt, sizes):
    """Fill lane i with ``sizes[i]`` distinct ids; returns the id set."""
    nxt = 1
    for i, n in enumerate(sizes):
        if n:
            rt.push(i, jnp.arange(nxt, nxt + n, dtype=jnp.int32), n)
            nxt += n
    return set(range(1, nxt))


def _drained_ids(rt):
    return sorted(int(x) for lane in rt.drain() for x in lane)


# ------------------------------------------------------------- conservation


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sizes,rounds", [
    ([40, 0, 0, 0], 5),
    ([0, 17, 3, 25, 0, 9], 4),
    ([100, 0, 0, 0, 0, 0, 0, 0], 8),
])
def test_executor_conserves_tasks_across_adaptive_rounds(sizes, rounds,
                                                         backend):
    """No task lost or duplicated while the controller re-tunes the
    proportion every round (traced scalar => same compiled round) — for
    every backend."""
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt = StealRuntime(len(sizes), 128, SPEC, policy=pol, adaptive=True,
                      backend=backend)
    ids = _seed(rt, sizes)
    props = set()
    for _ in range(rounds):
        props.add(rt.proportion)
        rt.round()
    assert _drained_ids(rt) == sorted(ids)
    # the controller actually moved (imbalanced seed => feedback signal)
    assert len(rt.controller.history) == rounds + 1
    assert rt.telemetry.summary()["rounds"] == rounds


@pytest.mark.parametrize("backend", BACKENDS)
def test_executor_backends_agree(backend):
    """The full executor trajectory (sizes, telemetry, drained ids) is
    identical across backends — the cross-implementation contract."""
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt_ref = StealRuntime(4, 128, SPEC, policy=pol, backend="reference")
    rt_b = StealRuntime(4, 128, SPEC, policy=pol, backend=backend)
    ids = _seed(rt_ref, [40, 0, 3, 0])
    _seed(rt_b, [40, 0, 3, 0])
    for _ in range(5):
        rt_ref.round()
        rt_b.round()
    np.testing.assert_array_equal(rt_ref.sizes(), rt_b.sizes())
    assert rt_ref.telemetry.summary() == rt_b.telemetry.summary()
    assert _drained_ids(rt_ref) == _drained_ids(rt_b) == sorted(ids)


def test_executor_conserves_with_worker_body():
    """Conservation holds when a worker body pops/pushes between steals
    (ids are consumed exactly once across lanes)."""
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6,
                      max_steal=16)
    W = 4
    rt = StealRuntime(W, 128, SPEC, policy=pol)
    ids = _seed(rt, [30, 0, 0, 0])
    ops = rt.ops

    def body(q, carry):
        q, item, valid = ops.pop(q)
        carry = carry + jnp.where(valid, item, 0)
        return q, carry

    carry = jnp.zeros((W,), jnp.int32)
    for _ in range(60):
        carry, _ = rt.round(body, carry)
        if rt.total_size() == 0:
            break
    assert rt.total_size() == 0
    # sum of consumed ids == sum of produced ids (nothing lost/dup'd)
    assert int(jnp.sum(carry)) == sum(ids)


def test_executor_hierarchical_conserves():
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt = StealRuntime(8, 128, SPEC, policy=pol, pod_size=4)
    ids = _seed(rt, [50, 0, 0, 0, 0, 12, 0, 0])
    for _ in range(5):
        rt.round()
    assert _drained_ids(rt) == sorted(ids)


def test_executor_reports_exchange_payload():
    """bytes_moved telemetry: compact rounds that transfer report one
    max_steal window per lane; skipped rounds report zero; the dense
    exchange reports the W x payload every round — through both
    .round() and .run_fused()."""
    W, max_steal, item_bytes = 4, 32, 4  # SPEC is one int32 per item
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=max_steal)
    rt = StealRuntime(W, 128, SPEC, policy=pol, adaptive=False)
    _seed(rt, [40, 0, 0, 0])
    rt.round()
    assert rt.telemetry.rounds[-1].bytes_moved == max_steal * item_bytes
    rt.run_fused(3)
    active = [r.bytes_moved for r in rt.telemetry.rounds
              if r.n_transferred > 0]
    idle = [r.bytes_moved for r in rt.telemetry.rounds
            if r.n_transferred == 0]
    assert all(b == max_steal * item_bytes for b in active)
    assert all(b == 0 for b in idle)  # the lax.cond fast path
    assert rt.telemetry.summary()["bytes_moved"] == sum(
        r.bytes_moved for r in rt.telemetry.rounds)

    pol_d = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                        max_steal=max_steal, exchange="dense")
    rt_d = StealRuntime(W, 128, SPEC, policy=pol_d, adaptive=False)
    _seed(rt_d, [40, 0, 0, 0])
    rt_d.round()
    assert (rt_d.telemetry.rounds[-1].bytes_moved
            == W * max_steal * item_bytes)


def test_executor_exchange_payload_stays_per_lane_hierarchically():
    """Hierarchical bytes_moved is the busiest LANE's injection (intra +
    xpod), not a cluster sum: one compact window per level at most."""
    max_steal, item_bytes = 32, 4
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=max_steal)
    rt = StealRuntime(8, 128, SPEC, policy=pol, adaptive=False, pod_size=4)
    _seed(rt, [50, 0, 0, 0, 0, 12, 0, 0])  # both pods rebalance intra
    rt.round()
    window = max_steal * item_bytes
    # at most one window per level for the busiest lane, never 2 pods' sum
    assert 0 < rt.telemetry.rounds[-1].bytes_moved <= 2 * window


def test_executor_spreads_load():
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=64)
    rt = StealRuntime(8, 256, SPEC, policy=pol,
                      adaptive_config=AdaptiveConfig(gain=1.0))
    _seed(rt, [100, 0, 0, 0, 0, 0, 0, 0])
    for _ in range(6):
        rt.round()
    s = rt.sizes()
    assert s.sum() == 100
    assert (s > 0).sum() >= 4
    assert rt.telemetry.total_transferred > 0


# ----------------------------------------------- kernel path: block straddle


STRADDLE_CASES = [
    # (cap, width, max_steal, lo, n) — lo chosen to straddle the
    # DEFAULT_BLOCK-aligned DMA windows of the Pallas kernel
    (512, 8, 256, DEFAULT_BLOCK - 1, 200),
    (512, 8, 256, DEFAULT_BLOCK + 1, 256),
    (512, 8, 512, 2 * DEFAULT_BLOCK - 7, 300),   # wraps past cap
    (256, 4, 256, 255, 129),                      # full wrap from last row
    (1024, 16, 256, 3 * DEFAULT_BLOCK + 63, 255),
]


@pytest.mark.parametrize("case", STRADDLE_CASES)
def test_ring_gather_interpret_parity_straddling_blocks(case):
    cap, width, max_steal, lo, n = case
    buf = jax.random.normal(jax.random.PRNGKey(7), (cap, width), jnp.float32)
    out_k = steal_gather(buf, jnp.int32(lo), jnp.int32(n),
                         max_steal=max_steal, use_pallas=True,
                         interpret=True)
    out_r = ring_gather_ref(buf, lo, n, max_steal)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("lo,n", [(120, 60), (250, 200), (0, 0)])
def test_steal_exact_pallas_backend_matches_reference(lo, n):
    """The pallas-routed backend == the reference backend for dynamic lo
    (the dispatcher picks the kernel oracle on CPU, Pallas on TPU)."""
    cap, max_steal = 256, 128
    q = bulk_ops.QueueState(
        buf={"a": jnp.arange(cap, dtype=jnp.int32),
             "b": jnp.arange(cap * 2, dtype=jnp.float32).reshape(cap, 2)},
        lo=jnp.int32(lo), size=jnp.int32(min(cap, 220)))
    q1, b1, n1 = REF.steal_exact(q, jnp.int32(n), max_steal=max_steal)
    q2, b2, n2 = PALLAS.steal_exact(q, jnp.int32(n), max_steal=max_steal)
    assert int(n1) == int(n2)
    assert int(q1.lo) == int(q2.lo) and int(q1.size) == int(q2.size)
    for k in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))


def test_kernel_steal_available_geometry():
    assert bulk_ops.kernel_steal_available(512, 256)
    assert bulk_ops.kernel_steal_available(256, 128)
    assert bulk_ops.kernel_steal_available(64, 32)       # block shrinks to 32
    assert not bulk_ops.kernel_steal_available(500, 256)  # cap not aligned
    assert not bulk_ops.kernel_steal_available(512, 200)  # max_steal unaligned


# ------------------------------------- push/pop kernels: wraparound parity


SCATTER_CASES = [
    # (cap, width, max_push, start, n) — start chosen to straddle the
    # DEFAULT_BLOCK-aligned splice windows / wrap past the ring end
    (512, 8, 256, DEFAULT_BLOCK - 1, 200),
    (512, 8, 256, DEFAULT_BLOCK + 1, 256),
    (512, 4, 256, 512 - 7, 256),                 # wraps past cap
    (512, 8, 256, 0, 0),                          # n = 0: pure pass-through
    (256, 4, 128, 255, 128),                      # full wrap from last row
    (1024, 16, 512, 3 * DEFAULT_BLOCK + 63, 511),
    (64, 3, 32, 33, 32),                          # shrunken block (32)
]


@pytest.mark.parametrize("case", SCATTER_CASES)
def test_ring_scatter_interpret_parity_straddling_blocks(case):
    cap, width, max_push, start, n = case
    key = jax.random.PRNGKey(3)
    buf = jax.random.normal(key, (cap, width), jnp.float32)
    batch = jax.random.normal(jax.random.fold_in(key, 1),
                              (max_push, width), jnp.float32)
    out_k = ring_scatter(buf, batch, jnp.int32(start), jnp.int32(n),
                         interpret=True)
    out_r = ring_scatter_ref(buf, batch, start, n)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    # Untouched ring rows must be preserved bit-exactly.
    offs = (np.arange(cap) - start) % cap
    keep = offs >= n
    np.testing.assert_array_equal(np.asarray(out_k)[keep],
                                  np.asarray(buf)[keep])


SLICE_CASES = [
    # (cap, width, max_n, lo, size, n)
    (512, 8, 256, DEFAULT_BLOCK - 1, 300, 200),
    (512, 8, 512, 2 * DEFAULT_BLOCK - 7, 512, 512),   # n = capacity
    (512, 8, 256, 17, 40, 0),                          # n = 0
    (256, 4, 256, 255, 200, 129),                      # wraps from last row
    (1024, 16, 256, 3 * DEFAULT_BLOCK + 63, 900, 255),
    (64, 3, 32, 61, 40, 32),                           # shrunken block
]


@pytest.mark.parametrize("case", SLICE_CASES)
def test_ring_slice_interpret_parity_straddling_blocks(case):
    cap, width, max_n, lo, size, n = case
    buf = jax.random.normal(jax.random.PRNGKey(5), (cap, width), jnp.float32)
    out_k = ring_slice(buf, jnp.int32(lo), jnp.int32(size), jnp.int32(n),
                       max_n, interpret=True)
    out_r = ring_slice_ref(buf, lo, size, n, max_n)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("lo,size,n_push,n_pop", [
    (0, 0, 10, 4), (120, 60, 16, 16), (250, 4, 8, 12), (100, 200, 0, 0),
])
def test_push_pop_pallas_backend_matches_reference(lo, size, n_push, n_pop):
    """The pallas-routed backend == the reference backend for push and
    bulk pop on dynamic cursors (the dispatcher picks the kernel oracle
    on CPU, Pallas on TPU)."""
    cap, max_n = 256, 16
    q = bulk_ops.QueueState(
        buf={"a": jnp.arange(cap, dtype=jnp.int32),
             "b": jnp.arange(cap * 2, dtype=jnp.float32).reshape(cap, 2)},
        lo=jnp.int32(lo), size=jnp.int32(size))
    batch = {"a": jnp.arange(1, max_n + 1, dtype=jnp.int32),
             "b": jnp.ones((max_n, 2), jnp.float32)}
    q1, p1 = REF.push(q, batch, jnp.int32(n_push))
    q2, p2 = PALLAS.push(q, batch, jnp.int32(n_push))
    assert int(p1) == int(p2)
    for k in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(q1.buf[k]),
                                      np.asarray(q2.buf[k]))
    q1, b1, n1 = REF.pop_bulk(q1, max_n, jnp.int32(n_pop))
    q2, b2, n2 = PALLAS.pop_bulk(q2, max_n, jnp.int32(n_pop))
    assert int(n1) == int(n2)
    assert int(q1.size) == int(q2.size)
    for k in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))


def test_kernel_push_pop_available_geometry():
    assert bulk_ops.kernel_push_available(512, 256)
    assert bulk_ops.kernel_push_available(4096, 1024)
    assert not bulk_ops.kernel_push_available(500, 256)   # cap unaligned
    # splice span (max_push + one straddle block) must not lap the ring
    assert not bulk_ops.kernel_push_available(256, 256)
    assert bulk_ops.kernel_pop_available(512, 512)
    assert bulk_ops.kernel_pop_available(64, 32)
    assert not bulk_ops.kernel_pop_available(512, 200)    # max_n unaligned


# ------------------------------------------------------- fused supersteps


@pytest.mark.parametrize("sizes,k", [
    ([40, 0, 0, 0], 5),
    ([0, 17, 3, 25, 0, 9], 4),
])
def test_run_fused_conserves_and_matches_sequential_rounds(sizes, k):
    """ONE run_fused(k) dispatch conserves every task and follows the
    exact trajectory of k sequential round() calls — the on-device
    adaptive update is the same float32 computation the host controller
    runs, so sizes, telemetry and proportion history all agree."""
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt_seq = StealRuntime(len(sizes), 128, SPEC, policy=pol, adaptive=True)
    rt_fus = StealRuntime(len(sizes), 128, SPEC, policy=pol, adaptive=True)
    ids = _seed(rt_seq, sizes)
    _seed(rt_fus, sizes)
    for _ in range(k):
        rt_seq.round()
    rt_fus.run_fused(k)
    assert rt_fus.rounds_run == rt_seq.rounds_run == k
    np.testing.assert_array_equal(rt_fus.sizes(), rt_seq.sizes())
    assert rt_fus.controller.history == rt_seq.controller.history
    assert rt_fus.telemetry.summary() == rt_seq.telemetry.summary()
    assert _drained_ids(rt_fus) == sorted(ids)
    assert _drained_ids(rt_seq) == sorted(ids)


def test_run_fused_with_worker_body_conserves():
    """Fused rounds interleaving a pop/consume body with backend-routed
    rebalancing consume every id exactly once."""
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6,
                      max_steal=16)
    W = 4
    rt = StealRuntime(W, 128, SPEC, policy=pol, backend="pallas")
    ids = _seed(rt, [30, 0, 0, 0])
    ops = rt.ops

    def body(q, carry):
        q, item, valid = ops.pop(q)
        carry = carry + jnp.where(valid, item, 0)
        return q, carry

    carry = jnp.zeros((W,), jnp.int32)
    for _ in range(15):
        carry, _ = rt.run_fused(5, body, carry)
        if rt.total_size() == 0:
            break
    assert rt.total_size() == 0
    assert int(jnp.sum(carry)) == sum(ids)


# ------------------------------------------- early-exit fused (while_loop)


@pytest.mark.parametrize("sizes,k", [
    ([40, 0, 0, 0], 5),
    ([0, 17, 3, 25, 0, 9], 4),
])
def test_until_drained_matches_scan_when_not_draining(sizes, k):
    """With work left after k rounds, until_drained executes exactly k
    rounds with the identical trajectory as the scan path."""
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt_scan = StealRuntime(len(sizes), 128, SPEC, policy=pol)
    rt_wl = StealRuntime(len(sizes), 128, SPEC, policy=pol)
    ids = _seed(rt_scan, sizes)
    _seed(rt_wl, sizes)
    _, stats_scan = rt_scan.run_fused(k)
    _, stats_wl, rounds = rt_wl.run_fused(k, until_drained=True)
    assert rounds == k  # nothing drained: full block
    assert rt_wl.rounds_run == rt_scan.rounds_run == k
    np.testing.assert_array_equal(rt_wl.sizes(), rt_scan.sizes())
    assert rt_wl.controller.history == rt_scan.controller.history
    assert rt_wl.telemetry.summary() == rt_scan.telemetry.summary()
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        stats_wl, stats_scan)
    assert _drained_ids(rt_wl) == sorted(ids)


def test_until_drained_early_exits_and_reports_rounds():
    """A consuming worker body drains the queues mid-block: the
    while_loop stops early, reports the executed count, and telemetry /
    rounds_run see only executed rounds."""
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6,
                      max_steal=16)
    W = 4
    rt = StealRuntime(W, 128, SPEC, policy=pol)
    ids = _seed(rt, [6, 0, 0, 0])
    ops = rt.ops

    def body(q, carry):
        q, item, valid = ops.pop(q)
        carry = carry + jnp.where(valid, item, 0)
        return q, carry

    carry = jnp.zeros((W,), jnp.int32)
    carry, stats, rounds = rt.run_fused(50, body, carry,
                                        until_drained=True)
    assert rounds < 50
    assert rt.total_size() == 0
    assert rt.rounds_run == rounds
    assert rt.telemetry.summary()["rounds"] == rounds
    assert np.asarray(stats.n_transferred).shape[0] == rounds
    assert int(jnp.sum(carry)) == sum(ids)
    # already drained: zero rounds execute, state untouched
    carry2, stats2, rounds2 = rt.run_fused(5, body, jnp.zeros((W,), jnp.int32),
                                           until_drained=True)
    assert rounds2 == 0
    assert rt.rounds_run == rounds
    assert int(jnp.sum(carry2)) == 0


def test_hierarchical_accounting_is_exact_not_replicated():
    """Per-level counters: seed so that NO intra-pod transfer is possible
    (lanes within each pod are balanced) and exactly one cross-pod steal
    happens.  Exact accounting reports that steal once; the former
    upper-bound accounting replicated the cross-pod share per pod and
    would have doubled it."""
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt = StealRuntime(8, 128, SPEC, policy=pol, pod_size=4, adaptive=False)
    ids = _seed(rt, [20, 20, 20, 20, 0, 0, 0, 0])
    rt.round()
    # Cross-pod: rep sizes (20, 0) -> one steal of floor(20 * 0.5) = 10.
    assert rt.telemetry.total_steals == 1
    assert rt.telemetry.total_transferred == 10
    # And the fused path reduces identically.
    rt2 = StealRuntime(8, 128, SPEC, policy=pol, pod_size=4, adaptive=False)
    _seed(rt2, [20, 20, 20, 20, 0, 0, 0, 0])
    rt2.run_fused(1)
    assert rt2.telemetry.summary() == rt.telemetry.summary()
    np.testing.assert_array_equal(rt2.sizes(), rt.sizes())
    for r in (rt, rt2):
        for _ in range(4):
            r.run_fused(2)
    assert _drained_ids(rt) == sorted(ids)


def test_run_fused_stacks_telemetry_rounds():
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt = StealRuntime(4, 128, SPEC, policy=pol)
    _seed(rt, [40, 0, 0, 0])
    _, stats = rt.run_fused(3)
    # Stacked (k, ...) leaves, one telemetry record per fused round.
    assert np.asarray(stats.n_transferred).shape[0] == 3
    assert rt.telemetry.summary()["rounds"] == 3
    assert len(rt.controller.history) == 4


# ----------------------------------------------------------- adaptive servo


def test_adaptive_controller_tracks_imbalance():
    from repro.runtime.adaptive import AdaptiveController

    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=8)
    ctl = AdaptiveController(pol, AdaptiveConfig(gain=1.0))
    # many idle, one busy -> target rises toward max
    p_hungry = ctl.update(np.array([100, 0, 0, 0, 0, 0, 0, 0]))
    assert p_hungry > 0.5
    # one idle of many busy -> steal only a small slice per round
    p_calm = ctl.update(np.array([30, 30, 30, 30, 30, 30, 30, 0]))
    assert p_calm < p_hungry
    # balanced above watermarks -> no possible transfer -> hold
    held = ctl.update(np.array([10, 10, 10, 10, 10, 10, 10, 10]))
    assert held == p_calm
    # nothing stealable -> hold
    held2 = ctl.update(np.array([2, 2, 2, 2, 2, 2, 2, 2]))
    assert held2 == p_calm
