"""The unified runtime: executor conservation across adaptive rounds,
kernel-path parity (dynamic ``lo`` straddling block boundaries), and
in-place vs. functional queue-op equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import queue as q_ops
from repro.core.policy import StealPolicy
from repro.kernels.queue_steal.kernel import DEFAULT_BLOCK
from repro.kernels.queue_steal.ops import steal_gather
from repro.kernels.queue_steal.ref import ring_gather_ref
from repro.runtime import AdaptiveConfig, StealRuntime

SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _seed(rt, sizes):
    """Fill lane i with ``sizes[i]`` distinct ids; returns the id set."""
    nxt = 1
    for i, n in enumerate(sizes):
        if n:
            rt.push(i, jnp.arange(nxt, nxt + n, dtype=jnp.int32), n)
            nxt += n
    return set(range(1, nxt))


def _drained_ids(rt):
    return sorted(int(x) for lane in rt.drain() for x in lane)


# ------------------------------------------------------------- conservation


@pytest.mark.parametrize("sizes,rounds", [
    ([40, 0, 0, 0], 5),
    ([0, 17, 3, 25, 0, 9], 4),
    ([100, 0, 0, 0, 0, 0, 0, 0], 8),
])
def test_executor_conserves_tasks_across_adaptive_rounds(sizes, rounds):
    """No task lost or duplicated while the controller re-tunes the
    proportion every round (traced scalar => same compiled round)."""
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt = StealRuntime(len(sizes), 128, SPEC, policy=pol, adaptive=True)
    ids = _seed(rt, sizes)
    props = set()
    for _ in range(rounds):
        props.add(rt.proportion)
        rt.round()
    assert _drained_ids(rt) == sorted(ids)
    # the controller actually moved (imbalanced seed => feedback signal)
    assert len(rt.controller.history) == rounds + 1
    assert rt.telemetry.summary()["rounds"] == rounds


def test_executor_conserves_with_worker_body():
    """Conservation holds when a worker body pops/pushes between steals
    (ids are consumed exactly once across lanes)."""
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=6,
                      max_steal=16)
    W = 4
    rt = StealRuntime(W, 128, SPEC, policy=pol)
    ids = _seed(rt, [30, 0, 0, 0])

    def body(q, carry):
        q, item, valid = q_ops.pop(q)
        carry = carry + jnp.where(valid, item, 0)
        return q, carry

    carry = jnp.zeros((W,), jnp.int32)
    for _ in range(60):
        carry, _ = rt.round(body, carry)
        if rt.total_size() == 0:
            break
    assert rt.total_size() == 0
    # sum of consumed ids == sum of produced ids (nothing lost/dup'd)
    assert int(jnp.sum(carry)) == sum(ids)


def test_executor_hierarchical_conserves():
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    rt = StealRuntime(8, 128, SPEC, policy=pol, pod_size=4)
    ids = _seed(rt, [50, 0, 0, 0, 0, 12, 0, 0])
    for _ in range(5):
        rt.round()
    assert _drained_ids(rt) == sorted(ids)


def test_executor_spreads_load():
    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=64)
    rt = StealRuntime(8, 256, SPEC, policy=pol,
                      adaptive_config=AdaptiveConfig(gain=1.0))
    _seed(rt, [100, 0, 0, 0, 0, 0, 0, 0])
    for _ in range(6):
        rt.round()
    s = rt.sizes()
    assert s.sum() == 100
    assert (s > 0).sum() >= 4
    assert rt.telemetry.total_transferred > 0


# ----------------------------------------------- kernel path: block straddle


STRADDLE_CASES = [
    # (cap, width, max_steal, lo, n) — lo chosen to straddle the
    # DEFAULT_BLOCK-aligned DMA windows of the Pallas kernel
    (512, 8, 256, DEFAULT_BLOCK - 1, 200),
    (512, 8, 256, DEFAULT_BLOCK + 1, 256),
    (512, 8, 512, 2 * DEFAULT_BLOCK - 7, 300),   # wraps past cap
    (256, 4, 256, 255, 129),                      # full wrap from last row
    (1024, 16, 256, 3 * DEFAULT_BLOCK + 63, 255),
]


@pytest.mark.parametrize("case", STRADDLE_CASES)
def test_ring_gather_interpret_parity_straddling_blocks(case):
    cap, width, max_steal, lo, n = case
    buf = jax.random.normal(jax.random.PRNGKey(7), (cap, width), jnp.float32)
    out_k = steal_gather(buf, jnp.int32(lo), jnp.int32(n),
                         max_steal=max_steal, use_pallas=True,
                         interpret=True)
    out_r = ring_gather_ref(buf, lo, n, max_steal)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("lo,n", [(120, 60), (250, 200), (0, 0)])
def test_steal_exact_kernel_route_matches_plain(lo, n):
    """core.queue.steal_exact(use_kernel=True) == the plain gather for
    dynamic lo (the dispatcher picks ref on CPU, Pallas on TPU)."""
    cap, max_steal = 256, 128
    q = q_ops.QueueState(
        buf={"a": jnp.arange(cap, dtype=jnp.int32),
             "b": jnp.arange(cap * 2, dtype=jnp.float32).reshape(cap, 2)},
        lo=jnp.int32(lo), size=jnp.int32(min(cap, 220)))
    q1, b1, n1 = q_ops.steal_exact(q, jnp.int32(n), max_steal=max_steal)
    q2, b2, n2 = q_ops.steal_exact(q, jnp.int32(n), max_steal=max_steal,
                                   use_kernel=True)
    assert int(n1) == int(n2)
    assert int(q1.lo) == int(q2.lo) and int(q1.size) == int(q2.size)
    for k in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))


def test_kernel_steal_available_geometry():
    assert q_ops.kernel_steal_available(512, 256)
    assert q_ops.kernel_steal_available(256, 128)
    assert q_ops.kernel_steal_available(64, 32)       # block shrinks to 32
    assert not q_ops.kernel_steal_available(500, 256)  # cap not block-aligned
    assert not q_ops.kernel_steal_available(512, 200)  # max_steal unaligned


# ------------------------------------------- in-place vs functional parity


def test_inplace_ops_match_functional():
    b = jnp.arange(1, 17, dtype=jnp.int32)
    q_f = q_ops.make_queue(64, SPEC)
    q_i = q_ops.make_queue(64, SPEC)

    q_f, n_f = q_ops.push(q_f, b, jnp.int32(10))
    q_i, n_i = q_ops.push_inplace(q_i, b, jnp.int32(10))
    assert int(n_f) == int(n_i) == 10

    q_f, blk_f, p_f = q_ops.pop_bulk(q_f, 8, jnp.int32(3))
    q_i, blk_i, p_i = q_ops.pop_bulk_inplace(q_i, 8, jnp.int32(3))
    assert int(p_f) == int(p_i)
    np.testing.assert_array_equal(np.asarray(blk_f), np.asarray(blk_i))

    q_f, s_f, ns_f = q_ops.steal_exact(q_f, jnp.int32(4), max_steal=8)
    q_i, s_i, ns_i = q_ops.steal_exact_inplace(q_i, jnp.int32(4),
                                               max_steal=8)
    assert int(ns_f) == int(ns_i)
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_i))
    assert int(q_f.lo) == int(q_i.lo) and int(q_f.size) == int(q_i.size)
    np.testing.assert_array_equal(np.asarray(q_f.buf), np.asarray(q_i.buf))


# ----------------------------------------------------------- adaptive servo


def test_adaptive_controller_tracks_imbalance():
    from repro.runtime.adaptive import AdaptiveController

    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=8)
    ctl = AdaptiveController(pol, AdaptiveConfig(gain=1.0))
    # many idle, one busy -> target rises toward max
    p_hungry = ctl.update(np.array([100, 0, 0, 0, 0, 0, 0, 0]))
    assert p_hungry > 0.5
    # one idle of many busy -> steal only a small slice per round
    p_calm = ctl.update(np.array([30, 30, 30, 30, 30, 30, 30, 0]))
    assert p_calm < p_hungry
    # balanced above watermarks -> no possible transfer -> hold
    held = ctl.update(np.array([10, 10, 10, 10, 10, 10, 10, 10]))
    assert held == p_calm
    # nothing stealable -> hold
    held2 = ctl.update(np.array([2, 2, 2, 2, 2, 2, 2, 2]))
    assert held2 == p_calm
