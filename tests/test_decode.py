"""repro.serve.decode: continuous-batching decode on the steal runtime.

The load-bearing assertion is schedule invariance: per-request greedy
tokens depend only on (params, prompt, budget) — slot assignment,
stalls, steals and migrations change WHEN a token is produced, never
its value — so every scheduling configuration must serve exactly the
tokens a direct prefill-free decode loop produces.  On top of that:
continuous batching mechanics (same-round slot/page reuse), page-
pressure back-pressure (no deadlock, ever), both steal policies, the
SLO telemetry stream, and the straggler escalation satellites.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.runtime.telemetry import RequestRecord, Telemetry, WaveRecord
from repro.serve.decode import (DecodeCluster, DecodePolicy, encode_requests,
                                request_spec)
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def model_params():
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _reference(model, params, prompt, max_new):
    """Greedy decode, one token at a time, no paging, no batching."""
    cache = model.make_cache(1, len(prompt) + max_new)
    cur = None
    for t in prompt:
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[t]], jnp.int32))
        cur = int(jnp.argmax(logits[0, 0]))
    out = [cur]
    for _ in range(max_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def _mix(n, seed=0, max_prompt=8, max_new=6):
    rng = np.random.default_rng(seed)
    return [(list(rng.integers(1, 100, size=int(rng.integers(1, max_prompt)))),
             int(rng.integers(1, max_new))) for _ in range(n)]


POL = DecodePolicy(n_slots=3, max_prompt=8, max_new=6, page_size=4)


def test_decode_matches_reference(model_params):
    model, params = model_params
    data = _mix(8, seed=1)
    cluster = DecodeCluster(model, params, policy=POL, n_lanes=2,
                            capacity=16, execution="vmap")
    reqs = [Request(prompt=p, max_new=mn) for p, mn in data]
    cluster.submit(reqs)
    done = cluster.run_until_drained(max_steps=200)
    assert len(done) == len(data)
    by_rid = {r.rid: r.output for r in done}
    for r, (p, mn) in zip(reqs, data):
        assert by_rid[r.rid] == _reference(model, params, p, mn), r.rid


def test_host_execution_and_host_stealing(model_params):
    model, params = model_params
    data = _mix(10, seed=2)
    c = DecodeCluster(model, params, policy=POL, n_lanes=4, capacity=16,
                      execution="host", admission="rr")
    # imbalance the admission so the host master has something to steal
    c.admission = "load"
    c._loads[:] = [0, 10**6, 10**6, 10**6]   # all to lane 0
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    done = c.run_until_drained(max_steps=200)
    assert len(done) == 10
    assert c.stolen > 0                       # host plan moved queued work
    multis = sorted(tuple(r.output) for r in done)
    ref = sorted(tuple(_reference(model, params, p, mn)) for p, mn in data)
    assert multis == ref


def test_continuous_batching_reuses_slots_same_round(model_params):
    """More requests than total slots drain anyway: finished sequences
    free their slot and pages in the same round new work is seated."""
    model, params = model_params
    data = _mix(12, seed=3)
    c = DecodeCluster(model, params, policy=POL, n_lanes=2, capacity=32,
                      execution="vmap", balance=False, admission="rr")
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    assert 12 > 2 * POL.n_slots               # oversubscribed by design
    done = c.run_until_drained(max_steps=300)
    assert len(done) == 12
    # every page returned: pool empty, zero held KV tokens
    st = c.stats()
    assert all(k == 0 for k in st["kv_tokens"])
    assert not np.asarray(c.carry["active"]).any()
    assert int(np.asarray(c.carry["n_alloc"]).sum()) == 0


def test_page_pressure_backpressures_but_drains(model_params):
    """A pool smaller than the slots' worst case admits fewer sequences
    at a time (reservation back-pressure), counts stalls, and still
    drains — the reservation invariant forbids deadlock."""
    model, params = model_params
    pol = dataclasses.replace(POL, n_pages=4)  # 1 sequence's worth
    data = _mix(10, seed=4)
    c = DecodeCluster(model, params, policy=pol, n_lanes=2, capacity=32,
                      execution="vmap", admission="rr")
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    done = c.run_until_drained(max_steps=1000)
    assert len(done) == 10
    assert c.stats()["stalls"] > 0
    multis = sorted(tuple(r.output) for r in done)
    ref = sorted(tuple(_reference(model, params, p, mn)) for p, mn in data)
    assert multis == ref                      # pressure never alters tokens


def test_migrate_steals_inflight_with_pages(model_params):
    model, params = model_params
    pol = dataclasses.replace(POL, steal="migrate", migrate_threshold=1.2)
    data = _mix(10, seed=5)
    c = DecodeCluster(model, params, policy=pol, n_lanes=2, capacity=32,
                      execution="vmap", admission="load")
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    done = c.run_until_drained(max_steps=300)
    assert len(done) == 10
    assert c.migrated > 0                     # the expensive path ran
    multis = sorted(tuple(r.output) for r in done)
    ref = sorted(tuple(_reference(model, params, p, mn)) for p, mn in data)
    assert multis == ref                      # pages moved bitwise
    waves = c.telemetry.waves
    assert sum(w.migrated for w in waves) == c.migrated


def test_static_baseline_never_steals(model_params):
    model, params = model_params
    c = DecodeCluster(model, params, policy=POL, n_lanes=2, capacity=16,
                      execution="vmap", balance=False, admission="rr")
    data = _mix(8, seed=6)
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    c.run_until_drained(max_steps=200)
    assert c.stolen == 0 and c.migrated == 0
    assert c.controller is None


def test_slo_stream_and_token_loads(model_params):
    model, params = model_params
    c = DecodeCluster(model, params, policy=POL, n_lanes=2, capacity=16,
                      execution="vmap")
    data = _mix(6, seed=7)
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    # submit-time load estimate is true token cost, not request count
    assert c._loads.sum() == sum(len(p) + mn for p, mn in data)
    c.run_until_drained(max_steps=200)
    tele = c.telemetry
    assert len(tele.requests) == 6
    for r in tele.requests:
        assert 0 <= r.admit <= r.first <= r.finish
        assert r.ttft == r.first - r.admit
        assert r.latency == r.finish - r.admit
        assert r.tokens >= 1
    # generated-token accounting matches the request records
    assert tele.total_tokens == sum(r.tokens for r in tele.requests)
    summ = tele.summary()
    for k in ("ttft_p50", "ttft_p95", "ttft_p99", "latency_p50",
              "latency_p95", "latency_p99"):
        assert k in summ
    assert summ["ttft_p50"] <= summ["ttft_p99"] <= summ["latency_p99"]


def test_wave_record_percentiles():
    """WaveRecord carries cumulative SLO percentiles once requests
    exist (unit-level, no model)."""
    t = Telemetry()
    w0 = t.record_wave(loads=[1, 2], served=0)
    assert w0.ttft_p99 == 0.0                 # no requests yet
    for i in range(10):
        t.record_request(rid=i, admit=0, first=i + 1, finish=2 * i + 2,
                         tokens=i + 1)
    w1 = t.record_wave(loads=[1, 2], served=10, tokens=55, migrated=3)
    ttfts = np.array([i + 1 for i in range(10)], float)
    lats = np.array([2 * i + 2 for i in range(10)], float)
    assert w1.ttft_p50 == np.percentile(ttfts, 50)
    assert w1.ttft_p95 == np.percentile(ttfts, 95)
    assert w1.ttft_p99 == np.percentile(ttfts, 99)
    assert w1.latency_p50 == np.percentile(lats, 50)
    assert w1.latency_p99 == np.percentile(lats, 99)
    assert w1.migrated == 3
    summ = t.summary()
    assert summ["requests"] == 10
    assert summ["ttft_p99"] == w1.ttft_p99
    assert summ["migrated"] == 3
    rec = RequestRecord(rid=0, admit=2, first=5, finish=9, tokens=4)
    assert rec.ttft == 3 and rec.latency == 7
    assert isinstance(w1, WaveRecord)


def test_encode_requests_validates():
    pol = DecodePolicy(n_slots=2, max_prompt=4, max_new=4)
    with pytest.raises(ValueError, match="prompt length"):
        encode_requests([Request(prompt=[1] * 5, max_new=2)], pol, 0)
    with pytest.raises(ValueError, match="max_new"):
        encode_requests([Request(prompt=[1], max_new=9)], pol, 0)
    batch = encode_requests([Request(prompt=[1, 2], max_new=3)], pol, 7)
    assert int(batch["plen"][0]) == 2 and int(batch["admit"][0]) == 7
    spec = request_spec(pol)
    assert batch["prompt"].shape == (1,) + spec["prompt"].shape


def test_decode_straggler_wiring(model_params):
    """A flagged slow step feeds telemetry AND boosts the steal
    proportion through the token-load controller."""
    model, params = model_params
    c = DecodeCluster(model, params, policy=POL, n_lanes=2, capacity=16,
                      execution="vmap")
    base = c.controller.effective_proportion
    c.note_straggler(rounds=3, factor=2.0)
    assert c.telemetry.straggler_steps == 1
    assert c.controller.effective_proportion > base
    data = _mix(4, seed=8)
    c.submit([Request(prompt=p, max_new=mn) for p, mn in data])
    done = c.run_until_drained(max_steps=100)
    assert len(done) == 4                     # boost decays, serving fine


def test_auto_evict_after_straggler_streak(model_params):
    """ServeCluster escalation: a replica flagged N waves in a row is
    evicted (ring drained onto the others) and counted in telemetry."""
    from repro.serve.engine import Replica, ServeCluster

    model, params = model_params
    reps = [Replica(model, params, wave_size=2, max_seq=32)
            for _ in range(2)]
    cluster = ServeCluster(reps, rebalance_rounds=2,
                           straggler_threshold=1.05,
                           auto_evict_after=2)
    # make replica 0 pathologically slow so the wall-clock monitor flags
    # it every wave
    slow = reps[0].run_wave

    def laggy(wave):
        import time
        if wave:
            time.sleep(0.05)
        return slow(wave)

    reps[0].run_wave = laggy
    reqs = [Request(prompt=[1, 2, 3], max_new=2) for _ in range(16)]
    cluster.submit(reqs)
    done = cluster.run_until_drained(max_steps=60)
    assert len(done) == 16
    tele = cluster.telemetry.summary()
    if tele.get("faults", {}).get("auto_evict", 0):
        assert cluster.master.replicas[0].evicted
        assert tele["faults"]["evict"] >= 1
    # streak reset on a clean wave: monitor may not flag every time on a
    # busy box, but the drain must always complete either way.
