"""Hierarchical fault recovery, failure detection, live no-rebuild resize.

Same dual execution shape as ``tests/test_resilience.py``: with >= 8
devices (the CI ``chaos`` lane) the checks run in-process; otherwise a
subprocess sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
and ``REPRO_CHECK=1`` before jax initializes and runs the identical
checks.

The checks:

* **Hierarchical replay parity, kill mid-drain** — the Fig. 9 DAG on a
  2x4 ``(pod, worker)`` grid under a seeded ``FaultPlan``: vmap and mesh
  execute the identical failure and recovery bit-for-bit, every node is
  explored exactly once, for BOTH a dead-lane plan (intra-pod recovery)
  and a dead-pod plan (cross-pod escalation).
* **Detector conversion** — an injected delay schedule is converted by
  the ``FailureDetector`` into real kills at the same rounds in both
  execution modes, with zero item loss (the conservation sanitizer is
  armed in the chaos lane).
* **Live resize** — ``padded_runtime`` at ``W_max`` with live
  shrink/grow performs ZERO recompiles (asserted via the jit cache
  population) while preserving the exact item multiset.
* **Cross-topology restore with faults** — an 8-lane FLAT checkpoint
  taken mid-fault-plan restores bit-identically into a 2x4 hierarchical
  mesh, which then finishes the drain with the exact item multiset.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

_HAVE_8 = jax.device_count() >= 8

_CHECKS = textwrap.dedent("""
    import tempfile

    import jax, jax.numpy as jnp
    import numpy as np
    from jax import lax

    from repro.core.policy import StealPolicy
    from repro.distributed import MeshStealRuntime, launch_runtime
    from repro.distributed import elastic
    from repro.launch.mesh import make_worker_mesh
    from repro.runtime import DetectorPolicy, FaultPlan, StealRuntime

    SPEC = jax.ShapeDtypeStruct((), jnp.int32)
    DSPEC = {"x": SPEC}

    def tree_eq(a, b):
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                       np.asarray(y)), a, b)

    def items_of(rt):
        q = jax.tree_util.tree_map(np.asarray, rt.queues)
        leaf = q.buf["x"] if isinstance(q.buf, dict) else q.buf
        cap = leaf.shape[1]
        out = []
        for i in range(rt.n_workers):
            lo, sz = int(q.lo[i]), int(q.size[i])
            out += [int(leaf[i][(lo + j) % cap]) for j in range(sz)]
        return sorted(out)

    # -- hierarchical replay parity: fig9 DAG, 2x4 grid, kill mid-drain -----

    N_NODES, BATCH, FANOUT = 2000, 16, 4

    def dag_body(ops):
        def body(q, carry):
            q, nodes, n_popped = ops.pop_bulk(q, BATCH, jnp.int32(BATCH))
            valid = jnp.arange(BATCH, dtype=jnp.int32) < n_popped
            kids = (nodes[:, None] * FANOUT + 1
                    + jnp.arange(FANOUT, dtype=jnp.int32)[None, :])
            live = valid[:, None] & (kids < N_NODES)
            flat, flive = kids.reshape(-1), live.reshape(-1)
            order = jnp.argsort(~flive, stable=True)
            flat = jnp.where(flive[order], flat[order], 0)
            q, _ = ops.push(q, flat, jnp.sum(flive.astype(jnp.int32)))
            peak = lax.pmax(carry, "workers")
            return q, carry + jnp.sum(valid.astype(jnp.int32)) + 0 * peak
        return body

    def hier_replay_checks():
        pol = StealPolicy(proportion=0.5, low_watermark=4,
                          high_watermark=32, max_steal=64)
        plans = {
            # lane 3 (pod 0) dies mid-drain -> intra-pod recovery; lane 5
            # straggles; one exchange dropped.
            "dead-lane": FaultPlan(kills=((3, 6),), delays=((5, 4, 2),),
                                   drops=(8,)),
            # ALL of pod 1 (lanes 4..7) dies -> cross-pod escalation.
            "dead-pod": FaultPlan(kills=((4, 5), (5, 5), (6, 6), (7, 6)),
                                  delays=((1, 3, 2),), drops=(9,)),
        }
        for name, plan in plans.items():
            results = {}
            for mode in ("vmap", "mesh"):
                rt = launch_runtime(8, 1024, SPEC, execution=mode,
                                    policy=pol, pod_size=4, max_pop=BATCH,
                                    fault_plan=plan)
                rt.push(0, jnp.zeros((1,), jnp.int32), 1)
                body = dag_body(rt.ops)
                carry = jnp.zeros((8,), jnp.int32)
                rounds = 0
                while rt.total_size() > 0 and rounds < 500:
                    carry, _, r = rt.run_fused(16, body, carry,
                                               until_drained=True)
                    rounds += r
                assert (rt.sizes()[rt.dead_lanes()] == 0).all()
                results[mode] = (int(jnp.sum(carry)),
                                 np.asarray(carry).tolist(), rounds,
                                 rt.telemetry.summary(),
                                 rt.controller.history,
                                 np.asarray(rt.sizes()).tolist())
            v, m = results["vmap"], results["mesh"]
            # every node explored exactly once, despite the kills
            assert v[0] == m[0] == N_NODES, (name, v[0], m[0])
            assert v[1] == m[1], name   # per-lane carries bit-identical
            assert v[2] == m[2], name   # rounds to drain
            assert v[3] == m[3], name   # telemetry summary
            assert v[4] == m[4], name   # adaptive trajectory
            assert v[5] == m[5], name   # final sizes
        print("HIER-REPLAY-OK")

    # -- detector: delay schedule -> suspicion -> real kills, no loss -------

    def detector_conversion_checks():
        pol = StealPolicy(backend="reference", low_watermark=4,
                          high_watermark=16, max_steal=64)
        dpol = DetectorPolicy(suspect_after=2, dead_after=4)
        results = {}
        for mode in ("vmap", "mesh"):
            for pod_size in (None, 4):
                rt = launch_runtime(8, 256, DSPEC, execution=mode,
                                    policy=pol, pod_size=pod_size,
                                    fault_plan=FaultPlan(
                                        delays=((2, 1, 10), (6, 3, 10))))
                det = rt.attach_detector(dpol)
                rng = np.random.default_rng(7)
                for w in range(8):
                    n = int(rng.integers(10, 40))
                    rt.push(w, {"x": jnp.arange(w * 100, w * 100 + n,
                                                dtype=jnp.int32)}, n)
                before = items_of(rt)
                for _ in range(14):
                    rt.round()
                # both delayed lanes crossed dead_after and were killed
                assert det.state(2) == "dead" and det.state(6) == "dead"
                assert rt.dead_lanes()[2] and rt.dead_lanes()[6]
                assert rt.telemetry.fault_events["auto_kill"] == 2
                # their rings drained through recovery; nothing lost
                assert rt.sizes()[2] == 0 and rt.sizes()[6] == 0
                assert items_of(rt) == before
                results[(mode, pod_size)] = (
                    np.asarray(rt.fault.kill_round).tolist(),
                    det.states())
        # same schedule -> same kill rounds in every mode/topology
        assert len(set(map(str, results.values()))) == 1, results
        print("DETECTOR-CONVERSION-OK")

    # -- live resize: fixed W_max, zero recompiles ---------------------------

    def live_resize_checks():
        pol = StealPolicy(backend="reference", low_watermark=2,
                          high_watermark=8, max_steal=64)
        for mode in ("vmap", "mesh"):
            rt = elastic.padded_runtime(4, 128, DSPEC, w_max=8,
                                        execution=mode, policy=pol)
            assert elastic.n_live(rt) == 4
            assert (rt.sizes() == 0).all()
            rt.push(0, {"x": jnp.arange(96, dtype=jnp.int32)}, 96)
            before = items_of(rt)
            for _ in range(3):
                rt.round()
            c0 = elastic.compile_count(rt)
            assert c0 >= 1

            lanes = elastic.live_grow(rt, 3)
            assert lanes == [4, 5, 6] and elastic.n_live(rt) == 7
            for _ in range(4):
                rt.round()
            assert rt.sizes()[lanes].sum() > 0     # newcomers fed
            assert items_of(rt) == before

            rounds = elastic.live_shrink(rt, [0, 4])
            assert rounds >= 1 and elastic.n_live(rt) == 5
            assert rt.sizes()[[0, 4]].sum() == 0
            assert items_of(rt) == before

            # headroom exhausted -> explicit error, not a rebuild
            try:
                elastic.live_grow(rt, 4)
            except ValueError as e:
                assert "headroom" in str(e)
            else:
                raise AssertionError("over-grow accepted")

            # the whole resize dance compiled NOTHING new
            assert elastic.compile_count(rt) == c0
            # fused dispatch after resize reuses its own single entry
            rt.run_fused(4)
            c1 = elastic.compile_count(rt)
            elastic.live_grow(rt, 1)
            elastic.live_shrink(rt, [1])
            rt.run_fused(4)
            assert elastic.compile_count(rt) == c1
            assert items_of(rt) == before
        print("LIVE-RESIZE-OK")

    # -- flat checkpoint -> 2x4 hierarchical mesh, mid-fault-plan ------------

    def flat_to_hier_restore_checks():
        pol = StealPolicy(backend="reference", low_watermark=4,
                          high_watermark=16, max_steal=64)
        plan = FaultPlan(kills=((3, 6), (5, 7)), delays=((1, 2, 3),))
        flat = StealRuntime(8, 128, DSPEC, policy=pol, fault_plan=plan)
        rng = np.random.default_rng(13)
        for w in range(8):
            n = int(rng.integers(10, 40))
            flat.push(w, {"x": jnp.arange(w * 100, w * 100 + n,
                                          dtype=jnp.int32)}, n)
        before = items_of(flat)
        for _ in range(4):      # mid-plan: kills at 6/7 still pending
            flat.round()
        d = tempfile.mkdtemp()
        flat.save_state(d)

        hier = MeshStealRuntime(make_worker_mesh(8, pod_size=4), 128,
                                DSPEC, policy=pol, fault_plan=FaultPlan())
        step = hier.restore_state(d)
        assert step == 4
        # bit-identical restore: queues AND the pending fault schedule
        tree_eq(jax.tree_util.tree_map(np.asarray, flat.queues),
                jax.tree_util.tree_map(np.asarray, hier.queues))
        assert np.asarray(hier.fault.kill_round).tolist() == \\
               np.asarray(flat.fault.kill_round).tolist()
        assert items_of(hier) == before

        # the hierarchical mesh executes the pending kills and finishes
        # the drain: dead rings empty, exact multiset preserved.
        for _ in range(10):
            hier.round()
        assert hier.dead_lanes()[3] and hier.dead_lanes()[5]
        assert hier.sizes()[3] == 0 and hier.sizes()[5] == 0
        assert items_of(hier) == before
        print("FLAT-TO-HIER-OK")

    def run_checks():
        assert jax.device_count() >= 8, jax.device_count()
        hier_replay_checks()
        detector_conversion_checks()
        live_resize_checks()
        flat_to_hier_restore_checks()
        print("HIER-FAULT-OK")
""")


@pytest.mark.skipif(not _HAVE_8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 before jax init (CI chaos lane)")
def test_hierarchical_fault_inprocess():
    ns = {}
    exec(compile(_CHECKS, "<hier-fault-checks>", "exec"), ns)
    ns["run_checks"]()


@pytest.mark.skipif(_HAVE_8, reason="in-process variant runs instead")
def test_hierarchical_fault_subprocess():
    script = ('import os\n'
              'os.environ["XLA_FLAGS"] = '
              '"--xla_force_host_platform_device_count=8"\n'
              'os.environ["REPRO_CHECK"] = "1"\n'
              + _CHECKS + "\nrun_checks()\n")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "HIER-FAULT-OK" in out.stdout, \
        out.stdout[-2000:] + "\n" + out.stderr[-3000:]
