"""DD solver: bound sandwich properties (hypothesis), B&B vs DP oracle,
parallel == sequential."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.core.dd.bnb import solve
from repro.core.dd.diagram import build_bounds
from repro.core.dd.knapsack import Knapsack, dp_solve, paper_example, random_instance
from repro.core.dd.parallel import parallel_solve


def test_paper_example_figures():
    """Fig. 2: exact optimum 15.  Fig. 3/4: restricted 13 <= 15 <= relaxed 19
    at max-width 3 (the paper's figures use width 3)."""
    inst = paper_example()
    assert dp_solve(inst) == 15
    primal, dual = build_bounds(
        jnp.int32(inst.capacity), jnp.int32(0), jnp.int32(0),
        jnp.asarray(inst.weights, jnp.int32),
        jnp.asarray(inst.profits, jnp.int32), width=3, n_vars=inst.n)
    assert int(primal) <= 15 <= int(dual)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 12), st.integers(2, 8))
def test_bound_sandwich(seed, n, width):
    """restricted <= exact <= relaxed for any instance and width."""
    inst = random_instance(n, seed=seed)
    opt = dp_solve(inst)
    primal, dual = build_bounds(
        jnp.int32(inst.capacity), jnp.int32(0), jnp.int32(0),
        jnp.asarray(inst.weights, jnp.int32),
        jnp.asarray(inst.profits, jnp.int32), width=width, n_vars=inst.n)
    assert int(primal) <= opt <= int(dual)


@pytest.mark.parametrize("seed", range(4))
def test_bnb_matches_dp(seed):
    inst = random_instance(12, seed=seed)
    got, _ = solve(inst, width=8)
    assert got == dp_solve(inst)


@pytest.mark.parametrize("seed", range(3))
def test_parallel_matches_sequential(seed):
    inst = random_instance(12, seed=seed)
    expect = dp_solve(inst)
    got, stats = parallel_solve(inst, n_workers=4, explore_width=8, batch=4)
    assert got == expect
    assert stats["explored"] >= 1


def test_parallel_balances_load():
    """The master's bulk steal spreads exploration across workers."""
    inst = random_instance(16, seed=1)
    _, stats = parallel_solve(inst, n_workers=8, explore_width=8, batch=4)
    per = stats["per_worker_explored"]
    assert stats["transferred"] > 0          # steals happened
    assert sum(1 for x in per if x > 0) >= 4  # work reached >= half the pool
