"""Dependency-free stand-in for the slice of the ``hypothesis`` API this
suite uses, so the property tests still *run* (not just skip) on minimal
environments without network access.

Covered: ``given``, ``settings(max_examples=..., deadline=...)`` and the
strategies ``integers, floats, booleans, just, sampled_from, one_of,
lists, tuples``.  Not covered (by design): shrinking, the example
database, ``assume``, stateful testing.  Examples are drawn from an RNG
seeded by the test's qualified name, so runs are deterministic and a
failure reproduces; the falsifying example is appended to the raised
error.

``install()`` registers this module as ``hypothesis`` /
``hypothesis.strategies`` in ``sys.modules``; ``tests/conftest.py`` calls
it only when the real package is missing, so a real hypothesis install
always wins.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 100


class Strategy:
    """A strategy is just a draw function ``Random -> value``."""

    def __init__(self, draw, label: str = "strategy"):
        self._draw = draw
        self._label = label

    def __repr__(self):
        return f"<mini {self._label}>"


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda r: r.randint(min_value, max_value),
                    f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float, **_kw) -> Strategy:
    return Strategy(lambda r: r.uniform(min_value, max_value),
                    f"floats({min_value}, {max_value})")


def booleans() -> Strategy:
    return Strategy(lambda r: bool(r.getrandbits(1)), "booleans()")


def just(value) -> Strategy:
    return Strategy(lambda r: value, f"just({value!r})")


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda r: r.choice(seq), f"sampled_from({seq!r})")


def one_of(*strategies) -> Strategy:
    if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
        strategies = tuple(strategies[0])
    return Strategy(lambda r: r.choice(strategies)._draw(r), "one_of(...)")


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10,
          **_kw) -> Strategy:
    return Strategy(
        lambda r: [elements._draw(r)
                   for _ in range(r.randint(min_size, max_size))],
        "lists(...)")


def tuples(*elements: Strategy) -> Strategy:
    return Strategy(lambda r: tuple(e._draw(r) for e in elements),
                    "tuples(...)")


class settings:
    """Decorator recording run options; composes with ``given`` in either
    order (it only sets an attribute the ``given`` wrapper reads)."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._mh_settings = self
        return fn


def given(*strategies: Strategy, **kw_strategies: Strategy):
    def decorate(fn):
        # Like hypothesis, positional strategies fill the RIGHTMOST
        # parameters; bind them by name so pytest fixtures occupying the
        # left positions can't collide with drawn examples.
        param_names = list(inspect.signature(fn).parameters)
        strat_names = (param_names[len(param_names) - len(strategies):]
                       if strategies else [])

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            opts = (getattr(wrapper, "_mh_settings", None)
                    or getattr(fn, "_mh_settings", None))
            n = opts.max_examples if opts else DEFAULT_MAX_EXAMPLES
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                example = {name: s._draw(rng)
                           for name, s in zip(strat_names, strategies)}
                example.update({k: s._draw(rng)
                                for k, s in kw_strategies.items()})
                try:
                    fn(*args, **kwargs, **example)
                except Exception as e:
                    msg = (f"[minihypothesis] falsifying example "
                           f"(#{i + 1}/{n}): {example!r}")
                    e.args = ((f"{e.args[0]}\n{msg}" if e.args else msg),
                              *e.args[1:])
                    raise

        # Hide the strategy-bound parameters from pytest's fixture
        # resolution: like hypothesis, positional strategies fill the
        # RIGHTMOST parameters; anything left is a fixture.
        params = list(inspect.signature(fn).parameters.values())
        if strategies:
            params = params[:-len(strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return decorate


def install() -> None:
    """Register as ``hypothesis`` + ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__version__ = "0.0-mini"
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "one_of", "lists", "tuples"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
