"""serve.paged_kv + the serve.kv_cache helpers it builds on.

Covers the previously-untested ``pad_cache`` / ``cache_tokens`` helpers
directly (growable-path detection, padding round-trip, token
accounting) and the paged pool's own invariants: allocation rank-
matching, back-pressure instead of over-allocation, same-call
free-then-reuse conservation, and the gather/scatter round-trip that
decode_step sits between.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import paged_kv
from repro.serve.kv_cache import cache_tokens, pad_cache


@pytest.fixture(scope="module")
def model():
    return build_model(configs.reduced(configs.get("llama3.2-1b")))


# -- kv_cache helpers (the dead-code satellite) -----------------------------


def test_pad_cache_grows_only_growable_leaves(model):
    cache = model.make_cache(2, 8)
    padded = pad_cache(cache, 12)
    assert int(padded["pos"]) == int(cache["pos"])
    for g in cache:
        if g == "pos":
            continue
        for kv in ("k", "v"):
            assert padded[g][kv].shape[2] == 12
            assert cache[g][kv].shape[2] == 8


def test_pad_cache_skips_cross_attention_paths():
    x = jnp.ones((1, 2, 4, 2, 3))
    cache = {"pos": jnp.int32(0),
             "g0": {"k": x, "v": x},
             "cross": {"k": x, "v": x}}
    padded = pad_cache(cache, 6)
    assert padded["g0"]["k"].shape[2] == 6
    assert padded["cross"]["k"].shape[2] == 4  # not growable


def test_pad_cache_round_trip_preserves_contents(model):
    _, cache = model.prefill(
        model.init(jax.random.PRNGKey(0)),
        jnp.arange(1, 5, dtype=jnp.int32)[None, :])   # cache C = 4
    padded = pad_cache(cache, 16)
    for g in cache:
        if g == "pos":
            continue
        for kv in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(padded[g][kv])[:, :, :4], np.asarray(cache[g][kv]))
            assert not np.asarray(padded[g][kv])[:, :, 4:].any()


def test_cache_tokens_accounting(model):
    c8 = model.make_cache(2, 8)
    c16 = model.make_cache(2, 16)
    assert cache_tokens(c16) == 2 * cache_tokens(c8)
    assert cache_tokens(c8) > 0
    # per definition: sum of batch*seq_len over growable leaves, /2 (k+v)
    n_groups = len([g for g in c8 if g != "pos"])
    assert cache_tokens(c8) == n_groups * 2 * 8


# -- the paged pool ----------------------------------------------------------


def test_pages_for():
    assert paged_kv.pages_for(1, 4) == 1
    assert paged_kv.pages_for(4, 4) == 1
    assert paged_kv.pages_for(5, 4) == 2


def test_make_pool_shapes(model):
    pool = paged_kv.make_pool(model, n_slots=3, n_pages=6, page_size=4,
                              pages_per_seq=2)
    assert pool["table"].shape == (3, 2)
    assert (np.asarray(pool["table"]) == 6).all()      # all rows -> trash
    assert (np.asarray(pool["owner"]) == -1).all()     # all pages free
    for g, kv in pool["pages"].items():
        for leaf in kv.values():
            assert leaf.shape[0] == 6 + 1              # +1 trash page
            assert leaf.shape[2] == 4                  # page_size rows


def test_alloc_grants_and_back_pressures(model):
    pool = paged_kv.make_pool(model, n_slots=3, n_pages=2, page_size=4,
                              pages_per_seq=2)
    table, owner, n_alloc = pool["table"], pool["owner"], jnp.zeros(
        (3,), jnp.int32)
    need = jnp.array([True, True, True])
    page_idx = jnp.zeros((3,), jnp.int32)
    table, owner, n_alloc = paged_kv.alloc_pages(table, owner, n_alloc,
                                                 need, page_idx)
    # only 2 pages: exactly 2 slots granted, 1 back-pressured
    assert int(n_alloc.sum()) == 2
    assert int((np.asarray(owner) >= 0).sum()) == 2
    granted = np.where(np.asarray(n_alloc) == 1)[0]
    for s in granted:
        p = int(np.asarray(table)[s, 0])
        assert p < 2 and int(np.asarray(owner)[p]) == s


def test_free_then_realloc_conserves(model):
    pool = paged_kv.make_pool(model, n_slots=2, n_pages=2, page_size=4,
                              pages_per_seq=1)
    table, owner = pool["table"], pool["owner"]
    n_alloc = jnp.zeros((2,), jnp.int32)
    both = jnp.array([True, True])
    table, owner, n_alloc = paged_kv.alloc_pages(
        table, owner, n_alloc, both, jnp.zeros((2,), jnp.int32))
    assert int(n_alloc.sum()) == 2
    table, owner, n_alloc = paged_kv.free_pages(
        table, owner, n_alloc, jnp.array([True, False]))
    assert int(n_alloc[0]) == 0 and int(n_alloc[1]) == 1
    assert int((np.asarray(owner) >= 0).sum()) == 1
    assert (np.asarray(table)[0] == 2).all()           # slot 0 -> trash
    # the freed page is immediately re-allocatable
    table, owner, n_alloc = paged_kv.alloc_pages(
        table, owner, n_alloc, jnp.array([True, False]),
        jnp.zeros((2,), jnp.int32))
    assert int(n_alloc.sum()) == 2
    assert int((np.asarray(owner) >= 0).sum()) == 2


def test_gather_scatter_round_trip(model):
    """cache -> pages -> gather == original (rows below pos), and a
    scatter of modified caches lands back in the right pages."""
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.arange(1, 7, dtype=jnp.int32)[None, :]
    _, cache = model.prefill(params, prompt)           # pos = 6
    paged = paged_kv.cache_to_pages(cache, page_size=4)

    pool = paged_kv.make_pool(model, n_slots=2, n_pages=4, page_size=4,
                              pages_per_seq=2)
    table, owner, n_alloc = pool["table"], pool["owner"], jnp.zeros(
        (2,), jnp.int32)
    for pi in range(2):                                 # 8 rows = 2 pages
        need = jnp.array([True, False])
        table, owner, n_alloc = paged_kv.alloc_pages(
            table, owner, n_alloc, need, jnp.full((2,), pi, jnp.int32))
    pages = pool["pages"]
    for g in paged:
        for kv in ("k", "v"):
            for j in range(2):
                pages[g][kv] = pages[g][kv].at[
                    np.asarray(table)[0, j]].set(paged[g][kv][j])

    got = paged_kv.gather_slot_caches(pages, table,
                                      jnp.array([6, 0], jnp.int32))
    assert int(got["pos"][0]) == 6
    padded_ref = pad_cache(cache, 8)                   # (NG, 1, 8, K, hd)
    for g in cache:
        if g == "pos":
            continue
        for kv in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(got[g][kv])[0],
                                          np.asarray(padded_ref[g][kv]))
    # slot 1 holds no pages: its gathered cache must be all zeros
    for g in got:
        if g == "pos":
            continue
        assert not np.asarray(got[g]["k"])[1].any()

    # scatter a recognizable update back and re-gather it
    marked = {g: jax.tree_util.tree_map(lambda x: x + 1.0, got[g])
              for g in got if g != "pos"}
    pages2 = paged_kv.scatter_slot_caches(
        pages, table, {g: got[g] for g in marked}, marked,
        jnp.array([True, False]))
    got2 = paged_kv.gather_slot_caches(pages2, table,
                                       jnp.array([6, 0], jnp.int32))
    for g in marked:
        np.testing.assert_array_equal(
            np.asarray(got2[g]["k"], np.float32)[0, :, :, :6],
            np.asarray(marked[g]["k"], np.float32)[0, :, :, :6])


def test_pool_token_count(model):
    pool = paged_kv.make_pool(model, n_slots=2, n_pages=4, page_size=4,
                              pages_per_seq=2)
    assert paged_kv.pool_token_count(pool["pages"],
                                     np.asarray(pool["owner"]), 4) == 0
    table, owner, n_alloc = paged_kv.alloc_pages(
        pool["table"], pool["owner"], jnp.zeros((2,), jnp.int32),
        jnp.array([True, True]), jnp.zeros((2,), jnp.int32))
    held = paged_kv.pool_token_count(pool["pages"], np.asarray(owner), 4)
    # 2 pages x 4 rows, counted once per group (cache_tokens semantics)
    n_groups = len(pool["pages"])
    assert held == 2 * 4 * n_groups


def test_windowed_models_rejected():
    cfg = configs.reduced(configs.get("gemma2-9b"))    # sliding window 16
    model = build_model(cfg)
    if all(k == "full" for k in getattr(model, "layer_kinds", ["full"])):
        pytest.skip("reduced config has no windowed layers")
    # sequences shorter than the window never wrap the ring: pageable
    pool = paged_kv.make_pool(model, n_slots=2, n_pages=4, page_size=4,
                              pages_per_seq=2)         # 8 rows <= window
    assert pool["table"].shape == (2, 2)
    # sequences longer than the window would wrap: rejected
    with pytest.raises(paged_kv.PagedKVError):
        paged_kv.make_pool(model, n_slots=2, n_pages=16, page_size=4,
                           pages_per_seq=8)            # 32 rows > window

