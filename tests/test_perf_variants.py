"""§Perf optimization variants must preserve semantics:

* MoE ep_shardmap == gspmd dispatch (same math, different collectives),
  checked on an 8-device host mesh in a subprocess.
* master-weights mixed precision trains and tracks fp32 loss closely.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step

_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.models import moe as moe_mod
    from repro.models.layers import ShardPlan

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = ShardPlan(dp=("data",), tp="model", fsdp="data")
    E, D, F, k = 8, 64, 128, 2
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], (D, E)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) * 0.05,
        "w_up": jax.random.normal(ks[2], (E, D, F)) * 0.05,
        "w_down": jax.random.normal(ks[3], (E, F, D)) * 0.05,
    }
    x = jax.random.normal(ks[4], (4, 16, D))
    kw = dict(top_k=k, n_experts=E, capacity_factor=2.0, sh=sh,
              compute_dtype=jnp.float32, bulk_steal=True)
    with mesh:
        base = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, impl="gspmd", **kw))(p, x)
        opt = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, impl="ep_shardmap", **kw))(p, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               atol=2e-5, rtol=2e-4)
    print("EP-PARITY-OK")
""")


_FD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.configs.base import ParallelConfig
    from repro.models import build_model
    import repro.models.transformer as tmod

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    par = ParallelConfig()
    base_cfg = configs.reduced(configs.get("llama3.2-1b"))
    tmod._SEQ_SHARD_MIN = 16   # force the seq-sharded decode path

    outs = {}
    for impl in ("gspmd", "flash_shardmap"):
        cfg = dataclasses.replace(base_cfg, decode_impl=impl)
        model = build_model(cfg, par)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (16, 32), 1,
                                  cfg.vocab_size, jnp.int32)
        with mesh:
            logits, cache = jax.jit(model.prefill)(params, toks)
            cache = model.grow_cache(cache, 40)
            lg, cache = jax.jit(model.decode_step)(params, cache,
                                                   toks[:, :1])
            lg2, _ = jax.jit(model.decode_step)(params, cache, toks[:, 1:2])
        outs[impl] = (np.asarray(lg), np.asarray(lg2))
    np.testing.assert_allclose(outs["gspmd"][0], outs["flash_shardmap"][0],
                               atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(outs["gspmd"][1], outs["flash_shardmap"][1],
                               atol=3e-2, rtol=3e-2)
    print("FLASH-DECODE-PARITY-OK")
""")


def test_flash_decode_matches_gspmd():
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", _FD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "FLASH-DECODE-PARITY-OK" in out.stdout, out.stderr[-2000:]


def test_ep_shardmap_matches_gspmd():
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", _EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "EP-PARITY-OK" in out.stdout, out.stderr[-2000:]


def test_master_weights_training_tracks_fp32():
    import dataclasses

    cfg32 = configs.reduced(configs.get("llama3.2-1b"))
    cfg16 = dataclasses.replace(cfg32, param_dtype="bfloat16")
    from repro.data.synthetic import synth_batch

    losses = {}
    for name, cfg, mw in (("fp32", cfg32, False), ("bf16", cfg16, True)):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, master_weights=mw)
        ocfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20,
                           master_weights=mw)
        step = jax.jit(make_train_step(model, ocfg))
        for i in range(20):
            raw = synth_batch(0, 0, i, 8, 32, cfg.vocab_size)
            batch = {"tokens": jnp.asarray(raw["tokens"]),
                     "labels": jnp.asarray(raw["labels"])}
            params, opt, m = step(params, opt, batch)
        losses[name] = float(m["loss"])
    assert np.isfinite(losses["bf16"])
    # bf16-with-master must land within 5% of the fp32 loss
    assert abs(losses["bf16"] - losses["fp32"]) / losses["fp32"] < 0.05, losses
