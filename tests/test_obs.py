"""Observability package coverage (ISSUE 10): phase-probe contracts
(bit-identity, compile-identity when off), trace export + validation,
metrics registry / exposition / collectors, the resilient-run textfile,
wall-clock failure detection, PagedQueue spill accounting, and the
perf-trend gate.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import trend
from repro.core.policy import StealPolicy
from repro.core.queue import PagedQueue
from repro.distributed.elastic import compile_count
from repro.obs.metrics import MetricsRegistry, write_textfile
from repro.obs.trace import export_trace, validate_trace
from repro.runtime import FaultPlan, StealRuntime
from repro.runtime.detector import DetectorPolicy, FailureDetector

SPEC = {"x": jax.ShapeDtypeStruct((), jnp.int32)}


def _make_rt(**kw):
    kw.setdefault("policy", StealPolicy(low_watermark=1, high_watermark=8))
    return StealRuntime(4, 64, SPEC, max_pop=4, **kw)


def _seed(rt, n=48):
    rt.push(0, {"x": jnp.arange(n, dtype=jnp.int32)}, n)


def _body(ops):
    def body(q, carry):
        q, _batch, n = ops.pop_bulk(q, 4, jnp.int32(2))
        return q, carry + n

    return body


def _drive(rt, *, rounds=5, fused=2):
    carry = jnp.zeros((rt.n_workers,), jnp.int32)
    body = _body(rt.ops)
    for _ in range(rounds):
        carry, _ = rt.round(body, carry)
    carry, _ = rt.run_fused(fused, body, carry)
    return carry


def _state(rt, carry):
    return [np.asarray(x)
            for x in jax.tree_util.tree_leaves((rt.queues, carry))]


# -- phase probe contracts ---------------------------------------------------


def test_probed_run_bit_identical_to_unprobed():
    ref = _make_rt()
    _seed(ref)
    ref_carry = _drive(ref)

    probed = _make_rt()
    _seed(probed)
    probed.attach_phase_probe(calibrate_every=4)
    probed_carry = _drive(probed)

    for a, b in zip(_state(ref, ref_carry), _state(probed, probed_carry)):
        np.testing.assert_array_equal(a, b)
    assert ref.telemetry.summary() == probed.telemetry.summary()
    ps = probed.telemetry.phase_summary()
    assert ps["timed_rounds"] == len(probed.telemetry.rounds)
    assert ps["estimated_rounds"] == 2        # the fused block's rounds
    assert ps["wall_s"] > 0.0
    # Phases partition the attributed wall.
    fr = sum(p["fraction"] for p in ps["phases"].values())
    assert fr == pytest.approx(1.0)


def test_disabled_probe_compiles_nothing_extra():
    ref = _make_rt()
    _seed(ref)
    ref_carry = _drive(ref)

    off = _make_rt()
    _seed(off)
    off.attach_phase_probe().enabled = False
    off_carry = _drive(off)

    assert compile_count(off) == compile_count(ref)
    assert len(off._probe_compiled) == 0
    for a, b in zip(_state(ref, ref_carry), _state(off, off_carry)):
        np.testing.assert_array_equal(a, b)
    assert off.telemetry.phase_summary() == {"timed_rounds": 0}


def test_estimated_sample_counts_all_fused_rounds():
    rt = _make_rt()
    _seed(rt)
    probe = rt.attach_phase_probe(calibrate_every=1000)
    _drive(rt, rounds=2, fused=3)
    assert probe.rounds_attributed == 5  # 2 direct + 3 estimated
    assert probe.calibrations == 1       # the first fused block


# -- trace export ------------------------------------------------------------


def _traced_telemetry():
    rt = _make_rt(fault_plan=FaultPlan(kills=((3, 4),)))
    rt.attach_detector(DetectorPolicy(suspect_after=2, dead_after=None))
    _seed(rt)
    rt.attach_phase_probe(calibrate_every=4)
    carry = jnp.zeros((rt.n_workers,), jnp.int32)
    body = _body(rt.ops)
    for tick in range(5):
        carry, _ = rt.round(body, carry)
        rt.telemetry.record_request(rid=tick, admit=tick, first=tick + 1,
                                    finish=tick + 2, tokens=4)
        rt.telemetry.record_wave(loads=np.asarray(rt.sizes()), served=1,
                                 tokens=4)
    return rt.telemetry


def test_trace_export_loads_and_validates(tmp_path):
    tele = _traced_telemetry()
    path = tmp_path / "trace.json"
    trace = export_trace(tele, str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["displayTimeUnit"] == "ms"
    counts = validate_trace(on_disk)
    assert counts["round"] == len(tele.rounds)
    assert counts["wave"] == len(tele.waves)
    assert counts["request"] == 3 * len(tele.requests)  # b/n/e per request
    assert counts["fault"] == len(tele.fault_log) >= 1  # the planned kill
    assert counts["phase"] > 0
    assert validate_trace(trace) == counts


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X", "pid": 0, "ts": 0.0,
                                         "name": "no-dur"}]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "b", "pid": 0, "ts": 0.0,
                                         "name": "unmatched", "id": 7,
                                         "cat": "request"}]})


# -- metrics -----------------------------------------------------------------


def test_registry_exposition_and_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "a counter")
    c.inc(2, lane=0)
    c.inc(3, lane=1)
    reg.gauge("t_gauge", "a gauge").set(1.5)
    h = reg.histogram("t_hist", "a histogram", buckets=(1, 2, 4))
    for v in (0.5, 3, 100):
        h.observe(v)
    text = reg.to_prometheus()
    assert '# TYPE t_total counter' in text
    assert 't_total{lane="1"} 3' in text
    assert 't_hist_bucket{le="+Inf"} 3' in text
    assert "t_hist_count 3" in text
    snap = reg.snapshot()
    assert snap["t_gauge"]["values"] == 1.5
    assert snap["t_hist"]["values"]["count"] == 3
    with pytest.raises(ValueError):
        reg.gauge("t_total", "type clash")


def test_runtime_metrics_cover_rounds_phases_and_detector():
    rt = _make_rt(fault_plan=FaultPlan())
    rt.attach_detector(DetectorPolicy(suspect_after=2))
    _seed(rt)
    rt.attach_phase_probe()
    _drive(rt, rounds=3, fused=2)
    snap = rt.metrics().snapshot()
    assert snap["repro_rounds_total"]["values"] == 5
    assert "repro_phase_seconds_total" in snap
    healthy = snap["repro_detector_lanes"]["values"]['{state="healthy"}']
    assert healthy == rt.n_workers
    assert snap["repro_queue_items"]["values"] == int(rt.sizes().sum())
    assert snap["repro_compiled_programs"]["values"] == len(rt._compiled)


def test_write_textfile_atomic(tmp_path):
    reg = MetricsRegistry()
    reg.counter("t_total", "c").inc()
    path = tmp_path / "metrics" / "repro.prom"
    write_textfile(reg, str(path))
    assert path.read_text().rstrip().endswith("t_total 1")
    assert list(path.parent.iterdir()) == [path]  # no tmp litter


def test_run_resilient_writes_metrics_textfile(tmp_path):
    from repro.launch.resilient import run_resilient

    def make_runtime():
        rt = _make_rt()
        _seed(rt, 32)
        return rt

    def drive(rt, should_stop):
        body = _body(rt.ops)
        while rt.total_size() > 0 and not should_stop():
            rt.round(body)
        return rt.rounds_run

    path = tmp_path / "live.prom"
    rounds = run_resilient(make_runtime, drive,
                           snapshot_dir=str(tmp_path / "snap"),
                           metrics_path=str(path), metrics_every_s=0.0)
    assert rounds > 0
    text = path.read_text()
    assert f"repro_rounds_total {rounds}" in text


# -- wall-clock failure detection --------------------------------------------


def test_observe_wall_suspects_but_never_kills_by_default():
    det = FailureDetector(2, DetectorPolicy(
        wall_clock=True, wall_slow_factor=2.0, wall_window=8,
        suspect_after=1, dead_after=2))
    for _ in range(8):
        assert det.observe_wall(0, 1.0) == "healthy"
    assert det.observe_wall(0, 10.0) == "suspected"
    assert det.observe_wall(0, 10.0) == "suspected"  # capped: no kill
    assert det.state(1) == "healthy"                 # per-lane isolation
    det.revive(0)
    assert det.observe_wall(0, 10.0) == "healthy"    # history cleared too


def test_observe_wall_kill_opt_in():
    det = FailureDetector(1, DetectorPolicy(
        wall_clock=True, wall_kill=True, wall_window=8,
        suspect_after=1, dead_after=2))
    killed = []
    det.on_dead = killed.append
    for _ in range(8):
        det.observe_wall(0, 1.0)
    det.observe_wall(0, 10.0)
    assert det.observe_wall(0, 10.0) == "dead"
    assert killed == [0]


def test_runtime_feeds_wall_clock_detector():
    rt = _make_rt(fault_plan=FaultPlan())
    det = rt.attach_detector(DetectorPolicy(wall_clock=True, wall_window=4))
    _seed(rt)
    _drive(rt, rounds=6, fused=2)
    assert all(len(det._wall_hist[w]) > 0 for w in range(rt.n_workers))


# -- PagedQueue spill/refill counters ----------------------------------------


def test_paged_queue_spill_counters():
    spec = jax.ShapeDtypeStruct((), jnp.int32)
    pq = PagedQueue(8, spec, low_watermark=2)
    assert (pq.spills, pq.spilled_items, pq.refills, pq.refilled_items) \
        == (0, 0, 0, 0)
    for base in range(0, 24, 4):
        pq.push(jnp.arange(base, base + 4, dtype=jnp.int32), 4)
    assert pq.spills > 0
    assert pq.spilled_items == sum(n for _, n in pq.pages)
    popped = 0
    while True:
        _, valid = pq.pop()
        if not valid:
            break
        popped += 1
    assert popped == 24
    assert pq.refills > 0 and pq.refilled_items == pq.spilled_items


def test_paged_queue_metrics_collector():
    from repro.obs.metrics import collect_paged_queue

    spec = jax.ShapeDtypeStruct((), jnp.int32)
    pq = PagedQueue(8, spec, low_watermark=2)
    for base in range(0, 16, 4):
        pq.push(jnp.arange(base, base + 4, dtype=jnp.int32), 4)
    snap = collect_paged_queue(MetricsRegistry(), pq).snapshot()
    assert snap["repro_paged_total_items"]["values"] == pq.total_size()
    assert snap["repro_paged_spilled_items_total"]["values"] \
        == pq.spilled_items


# -- trend gating ------------------------------------------------------------


def _bench(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_trend_passes_within_tolerance(tmp_path):
    _bench(tmp_path, "BENCH_PR2.json",
           {"meta": {"bench": "BENCH_PR2"},
            "fig9_device_fused": {"fused_speedup": 5.0}})
    cur = _bench(tmp_path, "BENCH_NEW.json",
                 {"meta": {"bench": "BENCH_PR11"},
                  "fig9_device_fused": {"fused_speedup": 4.5}})
    assert trend.main(["--dir", str(tmp_path), "--current", cur]) == 0


def test_trend_exits_nonzero_on_regression(tmp_path):
    _bench(tmp_path, "BENCH_PR2.json",
           {"meta": {"bench": "BENCH_PR2"},
            "fig9_device_fused": {"fused_speedup": 5.0}})
    cur = _bench(tmp_path, "BENCH_NEW.json",
                 {"meta": {"bench": "BENCH_PR11"},
                  "fig9_device_fused": {"fused_speedup": 1.2}})
    assert trend.main(["--dir", str(tmp_path), "--current", cur]) == 1


def test_trend_bool_gate_and_ceiling(tmp_path):
    bad = _bench(tmp_path, "BENCH_PR10.json",
                 {"meta": {"bench": "BENCH_PR10"},
                  "obs_overhead": {"probe_overhead": 1.2,
                                   "gates_ok": False}})
    assert trend.main(["--dir", str(tmp_path)]) == 1
    os.unlink(bad)
    _bench(tmp_path, "BENCH_PR10.json",
           {"meta": {"bench": "BENCH_PR10"},
            "obs_overhead": {"probe_overhead": 1.01, "gates_ok": True}})
    assert trend.main(["--dir", str(tmp_path)]) == 0


def test_trend_report_artifact(tmp_path):
    _bench(tmp_path, "BENCH_PR5.json",
           {"meta": {"bench": "BENCH_PR5"},
            "fig11_mesh": {"mesh_matches_vmap": True}})
    report = tmp_path / "report.json"
    assert trend.main(["--dir", str(tmp_path),
                       "--report", str(report)]) == 0
    data = json.loads(report.read_text())
    assert data["ok"] is True
    assert data["series"]["mesh_matches_vmap"] == [["BENCH_PR5.json", True]]
