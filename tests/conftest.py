"""Test-suite bootstrap.

Prefers a real ``hypothesis`` install (see requirements-dev.txt); on
minimal / offline environments, falls back to the deterministic shim in
``_minihypothesis`` so the property tests still execute instead of
erroring at collection.
"""

try:
    import hypothesis  # noqa: F401  (real install wins)
except ImportError:
    import _minihypothesis

    _minihypothesis.install()
