"""The ``"relaxed"`` fence-free multiplicity-tolerant backend
(Castañeda & Piña, see ``core/relaxed.py``): registry drop-in, geometry
predicate + fenced fallback, bounded over-report always reconciled, and
steal-path equivalence to the fenced reference oracle from arbitrary
states (the broader behavioural sweep lives in the
backend-parametrized test_queue / test_runtime / test_master suites)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # real install or conftest's mini-shim
from hypothesis import given, settings, strategies as st

from repro.core import ops as bulk_ops
from repro.core.relaxed import (RelaxedBulkOps, _optimistic_window,
                                relaxed_supported)

CAP = 64
SPEC = jax.ShapeDtypeStruct((), jnp.int32)
REF = bulk_ops.make_ops("reference")


def _seeded(values, cap=CAP):
    q = bulk_ops.make_queue(cap, SPEC)
    buf = np.zeros((max(len(values), 1),), np.int32)
    buf[: len(values)] = values
    q, _ = REF.push(q, jnp.asarray(buf), len(values))
    return q


def test_registry_and_predicate():
    assert "relaxed" in bulk_ops.available_backends()
    assert relaxed_supported(64, 32)
    assert relaxed_supported(64, 64)
    assert not relaxed_supported(64, 128)   # window larger than the ring
    assert not relaxed_supported(None, 32)  # unknown geometry
    assert not relaxed_supported(64, None)
    ok = bulk_ops.make_ops("relaxed", capacity=64, max_steal=32, check=False)
    assert isinstance(ok, RelaxedBulkOps)
    assert ok.name == ok.resolved == "relaxed"
    assert ok.multiplicity_bound(32) == 32
    # predicate-gated fallback: same name, fenced reference routing
    fb = bulk_ops.make_ops("relaxed", capacity=64, max_steal=128, check=False)
    assert not isinstance(fb, RelaxedBulkOps)
    assert fb.name == "relaxed" and fb.resolved == "reference"
    assert bulk_ops.make_ops("relaxed").resolved == "reference"


def test_optimistic_window_is_unmasked_overreport():
    """The fence-free read really does claim the whole multiplicity
    window — rows past ``size`` carry live ring bytes, not zeros."""
    q = _seeded([1, 2, 3])
    window = _optimistic_window(q, 8)
    np.testing.assert_array_equal(np.asarray(window)[:3], [1, 2, 3])
    # over-reported rows read whatever the ring holds (zeros here is the
    # empty-ring payload, but the READ itself spans all 8 rows); after a
    # wrap, the over-report picks up stale live bytes:
    q2 = _seeded(list(range(1, 11)), cap=8)  # clamped to 8 pushed
    q2, _, _ = REF.steal_exact(q2, 5, max_steal=8)  # lo advances to 5
    w2 = _optimistic_window(q2, 8)
    assert np.asarray(w2).shape == (8,)
    assert (np.asarray(w2) != 0).sum() > int(q2.size)  # stale rows read


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=0, max_size=40),
       st.integers(0, 48), st.floats(0.05, 1.5))
def test_relaxed_reconcile_matches_fenced_reference(sizes, n_exact, prop):
    """From arbitrary fill levels, steal_exact and proportional steal
    settle to EXACTLY the fenced reference result: same count, same
    rows, same cursor, over-report fully withdrawn (dead rows zeroed)."""
    rel = bulk_ops.make_ops("relaxed", capacity=CAP, max_steal=32)
    assert rel.resolved == "relaxed"
    vals = list(range(1, len(sizes) + 1))
    q0 = _seeded(vals)

    a_q, a_b, a_n = rel.steal_exact(q0, jnp.int32(n_exact), max_steal=32)
    r_q, r_b, r_n = REF.steal_exact(q0, jnp.int32(n_exact), max_steal=32)
    assert int(a_n) == int(r_n)
    np.testing.assert_array_equal(np.asarray(a_b), np.asarray(r_b))
    assert int(a_q.lo) == int(r_q.lo) and int(a_q.size) == int(r_q.size)

    a_q, a_b, a_n = rel.steal(q0, prop, max_steal=32)
    r_q, r_b, r_n = REF.steal(q0, prop, max_steal=32)
    assert int(a_n) == int(r_n)
    np.testing.assert_array_equal(np.asarray(a_b), np.asarray(r_b))
    assert int(a_q.lo) == int(r_q.lo) and int(a_q.size) == int(r_q.size)


def test_relaxed_donate_matches_pure():
    rel = bulk_ops.make_ops("relaxed", capacity=CAP, max_steal=16)
    q0 = _seeded(list(range(1, 13)))
    q_p, b_p, n_p = rel.steal_exact(q0, jnp.int32(5), max_steal=16)
    q_d, b_d, n_d = rel.steal_exact(_seeded(list(range(1, 13))),
                                    jnp.int32(5), max_steal=16, donate=True)
    assert int(n_p) == int(n_d)
    np.testing.assert_array_equal(np.asarray(b_p), np.asarray(b_d))
    np.testing.assert_array_equal(np.asarray(q_p.buf), np.asarray(q_d.buf))


def test_relaxed_through_superstep_matches_reference():
    """The virtual master on the relaxed backend produces bit-identical
    queues to the reference backend (both exchanges)."""
    import dataclasses

    from repro.core.policy import StealPolicy
    from repro.core.sharded_queue import make_sharded_queues, vmapped_superstep

    pol = StealPolicy(proportion=0.5, low_watermark=2, high_watermark=8,
                      max_steal=32)
    sizes = [40, 0, 0, 0, 25, 0, 3, 0]

    def seed():
        qs = make_sharded_queues(8, 128, SPEC)
        nxt = 1
        for i, n in enumerate(sizes):
            vals = np.zeros((max(sizes),), np.int32)
            vals[:n] = range(nxt, nxt + n)
            nxt += n
            qi = jax.tree_util.tree_map(lambda x: x[i], qs)
            qi, _ = REF.push(qi, jnp.asarray(vals), n)
            qs = jax.tree_util.tree_map(
                lambda full, one: full.at[i].set(one), qs, qi)
        return qs

    for exchange in ("compact", "dense"):
        p = dataclasses.replace(pol, exchange=exchange)
        out = {}
        for backend in ("reference", "relaxed"):
            ops = bulk_ops.make_ops(backend, capacity=128, max_push=32,
                                    max_steal=32)
            qs = seed()
            step = vmapped_superstep(p, ops=ops)
            for _ in range(3):
                qs, stats = step(qs)
            out[backend] = qs
        np.testing.assert_array_equal(np.asarray(out["reference"].size),
                                      np.asarray(out["relaxed"].size))
        np.testing.assert_array_equal(np.asarray(out["reference"].buf),
                                      np.asarray(out["relaxed"].buf))


# ---------------------------------------------------------------------------
# Adversarial split-step property: the paper's informal "bounded
# multiplicity" claim, mechanized.  The optimistic read and the
# reconcile are driven as SEPARATE steps with arbitrary owner mutations
# in between (the schedules the fused steal can never expose).
# ---------------------------------------------------------------------------


def _apply_owner_ops(q, owner_ops, next_val, floor):
    """Drive fenced owner ops against q, maintaining the stable-prefix
    floor (min owner-visible size since the optimistic read)."""
    for kind, amount in owner_ops:
        if kind == 0:                                    # pop newest
            from repro.core.queue import pop as queue_pop
            q, _, _ = queue_pop(q)
        elif kind == 1:                                  # pop_bulk
            q, _, _ = REF.pop_bulk(q, 8, jnp.int32(amount))
        else:                                            # push fresh ids
            vals = np.arange(next_val, next_val + max(amount, 1),
                             dtype=np.int32)
            next_val += len(vals)
            q, _ = REF.push(q, jnp.asarray(vals), amount)
        floor = min(floor, int(q.size))
    return q, next_val, floor


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 16), st.integers(0, 24),
       st.lists(st.tuples(st.integers(0, 2), st.integers(0, 6)),
                min_size=0, max_size=4))
def test_adversarial_mutation_never_over_claims(n_seed, claim, owner_ops):
    """Owner mutations landed between the optimistic read and the
    reconcile: the settle count never exceeds multiplicity_bound
    (= max_steal), never exceeds the stable-prefix floor, and the
    settled rows + resulting state match the fenced oracle exactly."""
    from repro.core.relaxed import optimistic_read, reconcile

    MS = 8
    rel = bulk_ops.make_ops("relaxed", capacity=16, max_steal=MS)
    assert rel.resolved == "relaxed"
    q = _seeded(list(range(1, n_seed + 1)), cap=16)

    window = optimistic_read(q, MS)        # fence-free over-report
    floor = int(q.size)
    q, _, floor = _apply_owner_ops(q, owner_ops, 1000, floor)

    q2, batch, n = reconcile(q, window, jnp.int32(claim), MS, floor=floor)
    n = int(n)
    assert n <= rel.multiplicity_bound(MS)
    assert n <= max(floor, 0)              # stable prefix never over-claimed
    assert n <= int(q.size)

    # the settled block and state transition are EXACTLY the fenced steal
    r_q, r_b, r_n = REF.steal_exact(q, jnp.int32(n), max_steal=MS)
    assert int(r_n) == n
    np.testing.assert_array_equal(np.asarray(batch), np.asarray(r_b))
    assert int(q2.lo) == int(r_q.lo) and int(q2.size) == int(r_q.size)
    np.testing.assert_array_equal(np.asarray(q2.buf), np.asarray(r_q.buf))
    # over-reported rows fully withdrawn
    assert (np.asarray(batch)[n:] == 0).all()


def test_relaxed_fallback_warns_once():
    """The geometry fallback relaxed->fenced is observable: exactly one
    BackendFallbackWarning per distinct geometry, naming the reason."""
    bulk_ops.reset_fallback_warnings()
    import warnings as _w

    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        fb = bulk_ops.make_ops("relaxed", capacity=64, max_steal=128)
        assert fb.resolved == "reference"
        again = bulk_ops.make_ops("relaxed", capacity=64, max_steal=128)
        assert again.resolved == "reference"
    msgs = [str(r.message) for r in rec
            if issubclass(r.category, bulk_ops.BackendFallbackWarning)]
    assert len(msgs) == 1, msgs             # one-shot per geometry
    assert "relaxed" in msgs[0] and "fenced" in msgs[0]
    bulk_ops.reset_fallback_warnings()
