"""Fig. 6 — push latency vs batch size (1, 128, 512, 1024).

Paper claim: LF_Queue's bulk push is a single splice, so latency is flat
in batch size; the Taskflow-style baselines pay per-node costs that grow
sharply.  Columns:

  LF_Queue      — faithful host port (one splice of a pre-linked batch)
  TF_UB-style   — per-item deque ops under a lock (unbounded baseline)
  TF_BD-style   — resizing circular array (bounded baseline)
  LFQ-JAX(dev)  — this framework's device ring queue (jitted masked
                  scatter; one fused kernel regardless of batch size)
  LFQ-JAX(kern) — the same push routed through the queue_push
                  ring-scatter kernel path (Pallas on TPU — an in-place
                  aliased splice — the jnp oracle elsewhere)

The kernel column is the acceptance gate for the fused-superstep PR:
its latency must stay flat (<= 1.5x from batch 1 to 1024); ``run()``
returns the raw numbers so ``benchmarks/run.py --json`` can record the
ratio in BENCH_PR2.json.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import Table, time_ns
from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                   ResizingArrayQueue, llist_from_iter)
from repro.core import queue as q_ops

BATCHES = (1, 128, 512, 1024)
CAPACITY = 4096


def _bench_host(cls, batch: int, repeats: int = 200) -> float:
    payload = list(range(batch))

    if cls is LinkedWSQueue:
        def setup():
            return LinkedWSQueue(), llist_from_iter(payload)

        def op(st):
            q, ll = st
            q.push(ll)
    else:
        def setup():
            return cls() if cls is PerItemDequeQueue else cls(capacity=64)

        def op(q):
            q.push(payload)
    return time_ns(setup, op, repeats=repeats)


def _bench_jax(batch: int, use_kernel: bool = False,
               repeats: int = 100) -> float:
    spec = jnp.zeros((), jnp.int32)
    q0 = q_ops.make_queue(CAPACITY, spec)
    items = jnp.arange(batch, dtype=jnp.int32)
    fn = functools.partial(q_ops.push, use_kernel=use_kernel)
    push = jax.jit(fn).lower(q0, items, jnp.int32(batch)).compile()

    def setup():
        return q0

    def op(q):
        st, _ = push(q, items, jnp.int32(batch))
        jax.block_until_ready(st.size)

    return time_ns(setup, op, repeats=repeats)


def run(tiny: bool = False) -> Tuple[Table, Dict]:
    t = Table("Fig. 6: push latency (ns) vs batch size",
              "batch", ["LF_Queue", "TF_UB-style", "TF_BD-style",
                        "LFQ-JAX(dev)", "LFQ-JAX(kern)"])
    repeats = 20 if tiny else 200
    jrepeats = 20 if tiny else 100
    data: Dict = {"batches": list(BATCHES), "columns": {}}
    cols = {
        "LF_Queue": lambda b: _bench_host(LinkedWSQueue, b, repeats),
        "TF_UB-style": lambda b: _bench_host(PerItemDequeQueue, b, repeats),
        "TF_BD-style": lambda b: _bench_host(ResizingArrayQueue, b, repeats),
        "LFQ-JAX(dev)": lambda b: _bench_jax(b, repeats=jrepeats),
        "LFQ-JAX(kern)": lambda b: _bench_jax(b, use_kernel=True,
                                              repeats=jrepeats),
    }
    for name in cols:
        data["columns"][name] = []
    for b in BATCHES:
        row = []
        for name, bench in cols.items():
            ns = bench(b)
            data["columns"][name].append(ns)
            row.append(ns)
        t.add(b, row)
    kern = data["columns"]["LFQ-JAX(kern)"]
    data["kernel_flatness_1_to_1024"] = kern[-1] / max(kern[0], 1.0)
    # Off-TPU the kernel column measures the dispatcher's oracle path
    # (ring_scatter_ref — same structure, O(capacity) splice); record
    # which path produced the numbers so BENCH_PR2.json is unambiguous.
    data["kernel_column_path"] = ("pallas"
                                  if jax.default_backend() == "tpu"
                                  else "oracle")
    return t, data


if __name__ == "__main__":
    table, data = run()
    table.show()
    print(f"kernel flatness batch 1 -> {BATCHES[-1]}: "
          f"{data['kernel_flatness_1_to_1024']:.2f}x")
