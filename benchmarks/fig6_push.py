"""Fig. 6 — push latency vs batch size (1, 128, 512, 1024).

Paper claim: LF_Queue's bulk push is a single splice, so latency is flat
in batch size; the Taskflow-style baselines pay per-node costs that grow
sharply.  All columns come from the unified harness:

* host implementations swept through the ``HostQueue`` protocol
  (``benchmarks.common.host_queue_impls``): the faithful port and the
  two Taskflow-style baselines;
* device ring-queue backends swept through ``BulkOps``
  (``benchmarks.common.device_backends``): at least
  ``LFQ-JAX[reference]`` (jnp oracle) and ``LFQ-JAX[auto]``
  (geometry-resolved kernel routing — the Pallas in-place aliased
  splice on TPU, the kernel module's jnp oracle elsewhere) — the
  paper's cross-implementation comparison for the same contract.

The resolved-kernel column is the acceptance gate for the fused-superstep
work: its latency must stay flat (<= 1.5x from batch 1 to 1024);
``run()`` returns the raw numbers so ``benchmarks/run.py --json`` can
record the ratio.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import (Table, bench_push, device_backends,
                               host_queue_impls, time_ns)
from repro.core import ops as bulk_ops

BATCHES = (1, 128, 512, 1024)
CAPACITY = 4096


def _bench_device(backend: str, batch: int, repeats: int = 100) -> float:
    """ns per device bulk push through a BulkOps backend.  The pure
    (donate=False) path is timed — the same queue state is reused every
    iteration, which donation would invalidate — matching the
    methodology of the earlier BENCH numbers; on-TPU in-place behaviour
    of the kernel is a separate open validation item (ROADMAP)."""
    ops = bulk_ops.make_ops(backend, capacity=CAPACITY, max_push=batch)
    spec = jnp.zeros((), jnp.int32)
    q0 = bulk_ops.make_queue(CAPACITY, spec)
    items = jnp.arange(batch, dtype=jnp.int32)
    n = jnp.int32(batch)
    push = jax.jit(lambda q: ops.push(q, items, n)).lower(q0).compile()

    def op(q):
        st, _ = push(q)
        jax.block_until_ready(st.size)

    return time_ns(lambda: q0, op, repeats=repeats)


def run(tiny: bool = False) -> Tuple[Table, Dict]:
    repeats = 20 if tiny else 200
    jrepeats = 20 if tiny else 100

    cols: Dict[str, object] = {}
    for name, factory in host_queue_impls().items():
        cols[name] = (lambda b, f=factory: bench_push(f, b, repeats))
    dev_names = device_backends()
    for backend in dev_names:
        cols[f"LFQ-JAX[{backend}]"] = (
            lambda b, be=backend: _bench_device(be, b, jrepeats))

    t = Table("Fig. 6: push latency (ns) vs batch size",
              "batch", list(cols))
    data: Dict = {"batches": list(BATCHES), "columns": {n: [] for n in cols},
                  "device_backends": list(dev_names)}
    for b in BATCHES:
        row = []
        for name, bench in cols.items():
            ns = bench(b)
            data["columns"][name].append(ns)
            row.append(ns)
        t.add(b, row)
    kern = data["columns"]["LFQ-JAX[auto]"]
    data["kernel_flatness_1_to_1024"] = kern[-1] / max(kern[0], 1.0)
    # Off-TPU the auto column's kernel-routed ops measure the dispatcher's
    # oracle path (ring_scatter_ref — same structure, O(capacity)
    # splice); record which path produced the numbers so the JSON is
    # unambiguous.
    data["kernel_column_path"] = ("pallas"
                                  if jax.default_backend() == "tpu"
                                  else "oracle")
    return t, data


if __name__ == "__main__":
    table, data = run()
    table.show()
    print(f"resolved-backend flatness batch 1 -> {BATCHES[-1]}: "
          f"{data['kernel_flatness_1_to_1024']:.2f}x")
