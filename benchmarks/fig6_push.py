"""Fig. 6 — push latency vs batch size (1, 128, 512, 1024).

Paper claim: LF_Queue's bulk push is a single splice, so latency is flat
in batch size; the Taskflow-style baselines pay per-node costs that grow
sharply.  Columns:

  LF_Queue      — faithful host port (one splice of a pre-linked batch)
  TF_UB-style   — per-item deque ops under a lock (unbounded baseline)
  TF_BD-style   — resizing circular array (bounded baseline)
  LFQ-JAX(dev)  — this framework's device ring queue (jitted masked
                  scatter; one fused kernel regardless of batch size)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import Table, time_ns
from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                   ResizingArrayQueue, llist_from_iter)
from repro.core import queue as q_ops

BATCHES = (1, 128, 512, 1024)


def _bench_host(cls, batch: int) -> float:
    payload = list(range(batch))

    if cls is LinkedWSQueue:
        def setup():
            return LinkedWSQueue(), llist_from_iter(payload)

        def op(st):
            q, ll = st
            q.push(ll)
    else:
        def setup():
            return cls() if cls is PerItemDequeQueue else cls(capacity=64)

        def op(q):
            q.push(payload)
    return time_ns(setup, op)


def _bench_jax(batch: int) -> float:
    spec = jnp.zeros((), jnp.int32)
    q0 = q_ops.make_queue(4096, spec)
    items = jnp.arange(batch, dtype=jnp.int32)
    push = jax.jit(q_ops.push).lower(q0, items, jnp.int32(batch)).compile()

    def setup():
        return q0

    def op(q):
        st, _ = push(q, items, jnp.int32(batch))
        jax.block_until_ready(st.size)

    return time_ns(setup, op, repeats=100)


def run() -> Table:
    t = Table("Fig. 6: push latency (ns) vs batch size",
              "batch", ["LF_Queue", "TF_UB-style", "TF_BD-style",
                        "LFQ-JAX(dev)"])
    for b in BATCHES:
        t.add(b, [
            _bench_host(LinkedWSQueue, b),
            _bench_host(PerItemDequeQueue, b),
            _bench_host(ResizingArrayQueue, b),
            _bench_jax(b),
        ])
    return t


if __name__ == "__main__":
    run().show()
