"""Perf-trend gating over the checked-in ``BENCH_*.json`` history.

Every PR's benchmark lane writes a ``BENCH_PR<n>.json`` with its own
schema (figure tables, sweep cells, gate booleans).  This module is the
one place that knows how to read ALL of them: an extractor registry maps
each canonical metric to the JSON path that carries it, normalizing the
per-PR schemas into one series per metric ordered by PR.  The gate then
compares the newest point of each series against the median of its
history:

* **numeric** metrics regress when the newest point is worse than the
  median baseline by more than the metric's tolerance (direction-aware:
  ``fused_speedup`` must not drop, overhead ratios must not climb) or
  breaches the metric's absolute ceiling (e.g. the phase probe's
  hard < 1.05x budget);
* **boolean** gates (exchange-payload flatness, vmap/mesh parity, serve
  parity, the observability gate bundle) must simply be true in the
  newest file that reports them.

A metric with fewer than two points has no trend to judge — it reports
``n/a`` and only its ceiling (if any) applies.  Missing history files
are skipped silently: the registry deliberately tolerates partial
checkouts (CI-artifact-only benches like BENCH_PR9 are judged only on
runs that produce them).

CLI::

  PYTHONPATH=src python -m benchmarks.trend [--dir .] \
      [--current BENCH_PR10.json ...] [--report trend_report.json]

``--current`` appends freshly-produced files as the newest points (the
CI obs lane passes the run's own output); without it, the newest
checked-in file per metric is judged.  Exits 1 on any regression —
this is the perf gate, wired into CI next to the test lanes.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import re
import statistics
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["METRICS", "MetricSpec", "load_series", "evaluate", "main"]


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One canonical metric and how to judge it.

    Attributes:
      path: key path into a BENCH file's JSON (tuple of dict keys).
      kind: ``"higher"`` / ``"lower"`` (numeric, direction of good) or
        ``"bool"`` (must be true).
      tolerance: allowed relative slack vs the median baseline before a
        numeric point counts as a regression (0.25 = 25 %).
      ceiling: optional absolute bound a "lower" metric must stay under
        (checked even with no history).
      floor: optional absolute bound a "higher" metric must stay over.
    """

    path: Tuple[str, ...]
    kind: str = "higher"
    tolerance: float = 0.25
    ceiling: Optional[float] = None
    floor: Optional[float] = None


# One entry per metric the repo's history carries; the BENCH schemas are
# per-PR, so the paths below are the single normalization point.
METRICS: Dict[str, MetricSpec] = {
    # BENCH_PR2: Fig. 9 fused-dispatch speedup and Fig. 6 kernel latency
    # flatness (1 -> 1024 batch growth factor; flat = close to 1).
    "fused_speedup": MetricSpec(("fig9_device_fused", "fused_speedup"),
                                kind="higher", tolerance=0.35, floor=1.0),
    "push_flatness": MetricSpec(("fig6_push", "kernel_flatness_1_to_1024"),
                                kind="lower", tolerance=0.75),
    # BENCH_PR4 / PR5 / PR8: structural gates.
    "payload_ratio_equals_w": MetricSpec(
        ("fig10_scaling", "payload_ratio_equals_w"), kind="bool"),
    "mesh_matches_vmap": MetricSpec(("fig11_mesh", "mesh_matches_vmap"),
                                    kind="bool"),
    "serve_parity": MetricSpec(("serve_decode", "parity", "parity_ok"),
                               kind="bool"),
    "balanced_beats_rr": MetricSpec(("serve_decode", "balanced_beats_rr"),
                                    kind="bool"),
    # BENCH_PR9 (CI-artifact-only): armed-idle fault-layer overhead.
    "chaos_armed_overhead": MetricSpec(
        ("chaos_recovery", "armed_overhead", "armed flat", "overhead"),
        kind="lower", tolerance=0.5, ceiling=2.0),
    # BENCH_PR10: the phase probe's hard overhead budget + gate bundle.
    "obs_probe_overhead": MetricSpec(("obs_overhead", "probe_overhead"),
                                     kind="lower", tolerance=0.5,
                                     ceiling=1.05),
    "obs_gates_ok": MetricSpec(("obs_overhead", "gates_ok"), kind="bool"),
}


def _dig(data: Dict, path: Tuple[str, ...]) -> Any:
    cur: Any = data
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return cur


def _pr_order(path: str, data: Dict) -> Tuple[int, str]:
    name = str(data.get("meta", {}).get("bench", os.path.basename(path)))
    m = re.search(r"PR(\d+)", name)
    return (int(m.group(1)) if m else 10**6, os.path.basename(path))


def load_series(history: Sequence[str], current: Sequence[str] = ()
                ) -> Dict[str, List[Tuple[str, Any]]]:
    """Normalize BENCH files into ``{metric: [(source, value), ...]}``,
    history ordered by PR number, then the ``current`` files (in the
    given order) as the newest points.  Unreadable files are skipped
    with a warning on stderr; files that don't carry a metric simply
    contribute no point to it."""
    loaded: List[Tuple[str, Dict]] = []
    for path in history:
        try:
            with open(path) as f:
                loaded.append((path, json.load(f)))
        except (OSError, json.JSONDecodeError) as e:
            print(f"[trend] skipping unreadable {path}: {e}",
                  file=sys.stderr)
    loaded.sort(key=lambda pd: _pr_order(*pd))
    for path in current:  # newest points, caller-given order preserved
        with open(path) as f:
            loaded.append((path, json.load(f)))
    series: Dict[str, List[Tuple[str, Any]]] = {m: [] for m in METRICS}
    for path, data in loaded:
        for name, spec in METRICS.items():
            value = _dig(data, spec.path)
            if value is not None:
                series[name].append((os.path.basename(path), value))
    return {m: pts for m, pts in series.items() if pts}


def evaluate(series: Dict[str, List[Tuple[str, Any]]]
             ) -> List[Dict[str, Any]]:
    """Judge every metric's newest point; returns one verdict row per
    metric (``ok`` bool + human-readable ``detail``)."""
    rows: List[Dict[str, Any]] = []
    for name, points in series.items():
        spec = METRICS[name]
        source, value = points[-1]
        row: Dict[str, Any] = {"metric": name, "source": source,
                               "value": value, "n_points": len(points),
                               "kind": spec.kind}
        if spec.kind == "bool":
            row["ok"] = bool(value)
            row["detail"] = "true" if value else "GATE FALSE"
            rows.append(row)
            continue
        value = float(value)
        ok, details = True, []
        if spec.ceiling is not None and value > spec.ceiling:
            ok = False
            details.append(f"{value:.3f} > ceiling {spec.ceiling:g}")
        if spec.floor is not None and value < spec.floor:
            ok = False
            details.append(f"{value:.3f} < floor {spec.floor:g}")
        history = [float(v) for _, v in points[:-1]]
        if history:
            baseline = statistics.median(history)
            row["baseline"] = baseline
            if spec.kind == "higher":
                limit = baseline * (1.0 - spec.tolerance)
                if value < limit:
                    ok = False
                    details.append(
                        f"{value:.3f} < {limit:.3f} "
                        f"(median {baseline:.3f} - {spec.tolerance:.0%})")
            else:
                limit = baseline * (1.0 + spec.tolerance)
                if value > limit:
                    ok = False
                    details.append(
                        f"{value:.3f} > {limit:.3f} "
                        f"(median {baseline:.3f} + {spec.tolerance:.0%})")
        else:
            details.append("no history (first point)")
        row["ok"] = ok
        row["detail"] = "; ".join(details) if details else "within tolerance"
        rows.append(row)
    return rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate benchmark trends over the BENCH_*.json history")
    ap.add_argument("--dir", default=".",
                    help="directory holding the checked-in BENCH_*.json "
                         "history (default: cwd)")
    ap.add_argument("--current", nargs="*", default=[],
                    help="freshly-produced BENCH files to judge as the "
                         "newest points (appended after the history)")
    ap.add_argument("--report", default=None,
                    help="write the normalized series + verdicts here "
                         "as JSON (the CI artifact)")
    args = ap.parse_args(argv)

    current = [os.path.abspath(p) for p in args.current]
    history = sorted(
        p for p in glob.glob(os.path.join(args.dir, "BENCH_*.json"))
        if os.path.abspath(p) not in current)
    if not history and not current:
        print(f"[trend] no BENCH_*.json under {args.dir!r} and no "
              f"--current files; nothing to gate", file=sys.stderr)
        return 2
    series = load_series(history, current)
    rows = evaluate(series)

    width = max(len(r["metric"]) for r in rows)
    regressed = [r for r in rows if not r["ok"]]
    for r in rows:
        mark = "ok " if r["ok"] else "REG"
        val = f"{r['value']:.3f}" if r["kind"] != "bool" else str(r["value"])
        print(f"[trend] {mark} {r['metric']:<{width}} {val:>8} "
              f"({r['n_points']} pts, {r['source']}) — {r['detail']}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump({"series": {m: [[s, v] for s, v in pts]
                                  for m, pts in series.items()},
                       "verdicts": rows,
                       "ok": not regressed}, f, indent=1)
        print(f"[trend] report -> {args.report}")
    if regressed:
        print(f"[trend] {len(regressed)} metric(s) regressed: "
              + ", ".join(r["metric"] for r in regressed), file=sys.stderr)
        return 1
    print(f"[trend] all {len(rows)} gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
