"""Continuous-batching decode serving — steal-balanced vs static round-robin.

The paper's closing claim is that bulk stealing wins hardest when
per-item cost is irregular; LLM decode is the canonical such workload
(mixed prompt lengths, geometric output lengths — no two requests cost
the same).  This benchmark drains one seeded irregular request mix
through :class:`repro.serve.decode.DecodeCluster` and reports, per cell:

* ``tokens/s`` — generated-token throughput over the drain;
* ``ttft_p99`` / ``latency_p99`` — SLO percentiles in LOGICAL rounds
  (the deterministic clock, so the numbers are machine-independent);
* ``load spread`` — mean over waves of (max - min) per-lane token load
  normalized by the mean (0 = perfectly balanced);

for W ∈ {4, 8} lanes under steal-balanced admission (least token-load
routing + superstep rebalancing + the token-load proportion servo)
versus static round-robin (even request COUNTS, no rebalancing — the
scheduler every serving stack starts with), plus a ``migrate`` cell
showing the expensive steal path (in-flight sequences move with their
KV pages).

Before any timing, the PARITY GATE: the same mix must drain on host,
vmap and mesh execution with identical served-token multisets — the
acceptance bar that decode results are execution-mode-invariant.  The
mesh cells need one fake host device per lane (``run.py --serve`` sets
``xla_force_host_platform_device_count`` before jax loads, as does
running this module directly).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

WORKERS = (4, 8)
N_REQUESTS = 96
TINY_REQUESTS = 28


def force_host_devices(n: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


if __name__ == "__main__":  # direct run: claim devices before jax loads
    force_host_devices(max(WORKERS))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import Table  # noqa: E402
from repro import configs  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve.decode import DecodeCluster, DecodePolicy  # noqa: E402
from repro.serve.scheduler import Request  # noqa: E402

MAX_PROMPT = 8
MAX_NEW = 8


def _request_mix(n: int, seed: int = 0) -> List[Tuple[List[int], int]]:
    """Mixed prompt lengths (uniform) x geometric output lengths — the
    irregular per-item cost profile."""
    rng = np.random.default_rng(seed)
    mix = []
    for _ in range(n):
        plen = int(rng.integers(1, MAX_PROMPT + 1))
        out = int(min(1 + rng.geometric(0.35), MAX_NEW))
        mix.append((list(rng.integers(1, 500, size=plen)), out))
    return mix


def _cluster(model, params, w: int, mode: str, execution: str = "vmap"
             ) -> DecodeCluster:
    steal = "migrate" if mode == "migrate" else "queue"
    pol = DecodePolicy(n_slots=4, max_prompt=MAX_PROMPT, max_new=MAX_NEW,
                       page_size=4, steal=steal)
    balanced = mode in ("balanced", "migrate")
    return DecodeCluster(
        model, params, policy=pol, n_lanes=w, capacity=128,
        execution=execution, balance=balanced,
        admission="load" if balanced else "rr")


def _drain(cluster: DecodeCluster, mix, arrival: int) -> Dict:
    """Submit the mix in arrival-sized chunks (one per step) and drain;
    returns the cell's metrics.  Wall excludes compile (one warm step
    runs before the clock starts)."""
    reqs = [Request(prompt=p, max_new=mn) for p, mn in mix]
    cluster.submit(reqs[:arrival])
    cluster.step()                      # compile warm-up, inside the run
    t0 = time.time()
    i = arrival
    while i < len(reqs):
        cluster.submit(reqs[i: i + arrival])
        i += arrival
        cluster.step()
    cluster.run_until_drained(max_steps=5000)
    wall = time.time() - t0
    assert len(cluster.done) == len(reqs), (
        f"drained {len(cluster.done)}/{len(reqs)}")
    tele = cluster.telemetry
    spreads = [(max(wv.loads) - min(wv.loads)) / max(np.mean(wv.loads), 1.0)
               for wv in tele.waves if max(wv.loads) > 0]
    summ = tele.summary()
    return {
        "tokens": summ["tokens"],
        "tokens_per_s": summ["tokens"] / max(wall, 1e-9),
        "ttft_p50": summ["ttft_p50"], "ttft_p99": summ["ttft_p99"],
        "latency_p99": summ["latency_p99"],
        "load_spread": float(np.mean(spreads)) if spreads else 0.0,
        "rounds": cluster.rounds,
        "stolen": cluster.stolen,
        "migrated": cluster.migrated,
        "stalls": cluster.stats()["stalls"],
        "wall_s": wall,
        "multiset": sorted(tuple(r.output) for r in cluster.done),
    }


def parity_gate(model, params, mix, w: int = 4) -> Dict:
    """Drain the same mix on host / vmap / mesh; the served-token
    multisets must be identical."""
    out = {}
    modes = ["host", "vmap"]
    if jax.device_count() >= w:
        modes.append("mesh")
    for ex in modes:
        c = _cluster(model, params, w, "balanced", execution=ex)
        out[ex] = _drain(c, mix, arrival=len(mix))["multiset"]
    ok = all(out[m] == out[modes[0]] for m in modes)
    assert ok, f"served-token multisets diverge across {modes}"
    return {"modes": modes, "parity_ok": ok}


def run(tiny: bool = False) -> Tuple[Table, Dict]:
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = TINY_REQUESTS if tiny else N_REQUESTS
    mix = _request_mix(n)

    gate = parity_gate(model, params, mix[: max(n // 2, 8)])

    tb = Table("serve.decode — steal-balanced vs static round-robin "
               f"({n} requests, irregular mix)",
               "W / scheduler",
               ["tokens/s", "ttft p99 (rounds)", "latency p99",
                "load spread", "stolen", "migrated"])
    cells = []
    wins = []
    for w in WORKERS:
        row = {}
        for mode in ("rr", "balanced", "migrate"):
            if mode == "migrate" and w != WORKERS[0]:
                continue
            arrival = max(n // 4, 1)
            m = _drain(_cluster(model, params, w, mode), mix, arrival)
            m.pop("multiset")
            m.update(w=w, mode=mode)
            cells.append(m)
            row[mode] = m
            label = {"rr": "static rr", "balanced": "steal-balanced",
                     "migrate": "steal+migrate"}[mode]
            tb.add(f"W={w} {label}",
                   [f"{m['tokens_per_s']:.0f}", f"{m['ttft_p99']:.1f}",
                    f"{m['latency_p99']:.1f}", f"{m['load_spread']:.2f}",
                    m["stolen"], m["migrated"]])
        wins.append(
            row["balanced"]["ttft_p99"] < row["rr"]["ttft_p99"]
            or row["balanced"]["load_spread"] < row["rr"]["load_spread"])
    data = {
        "parity": gate,
        "cells": cells,
        "balanced_beats_rr": bool(any(wins)),
        "win_per_w": {str(w): bool(v) for w, v in zip(WORKERS, wins)},
    }
    return tb, data


if __name__ == "__main__":
    table, data = run(tiny=True)
    table.show()
    print("parity:", data["parity"], "balanced beats rr:",
          data["balanced_beats_rr"])
