"""Benchmark entrypoint: one table per paper figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick]
  PYTHONPATH=src python -m benchmarks.run --json [--tiny] [--out BENCH_PR2.json]
  PYTHONPATH=src python -m benchmarks.run --sweep-adaptive [--tiny] \
      [--out BENCH_PR3.json]
  PYTHONPATH=src python -m benchmarks.run --scaling [--tiny] \
      [--out BENCH_PR4.json]
  PYTHONPATH=src python -m benchmarks.run --mesh [--tiny] \
      [--out BENCH_PR5.json]
  PYTHONPATH=src python -m benchmarks.run --serve [--tiny] \
      [--out BENCH_PR8.json]
  PYTHONPATH=src python -m benchmarks.run --chaos [--tiny] \
      [--out BENCH_PR9.json]
  PYTHONPATH=src python -m benchmarks.run --obs [--tiny] \
      [--out BENCH_PR10.json]
  PYTHONPATH=src python -m benchmarks.run --check

``--json`` runs the figures that seed the repo's perf trajectory (Fig. 6
push latency incl. the backend sweep, Fig. 7 steal latency, the Fig. 9
device workload's fused-vs-per-round supersteps, and the Fig. 10
dense-vs-compact exchange columns) and writes the raw numbers to a JSON
file; ``--tiny`` shrinks repeats/sizes so the whole sweep fits a CPU CI
smoke job.  ``--sweep-adaptive`` runs the steal-proportion autotuning
sweep (AdaptiveConfig gain/clamp vs static proportions on the Fig. 9
DAG workload) and records the winner in BENCH_PR3.json.  ``--scaling``
runs the full Fig. 10 worker-count scaling sweep (W x max_steal x
{dense, compact}: wall per round + exchange payload) into
BENCH_PR4.json.  ``--mesh`` runs the Fig. 11 vmap-lane vs shard_map
executor comparison (W fake host devices are claimed BEFORE jax
initializes, so run it as its own process) into BENCH_PR5.json.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def run_json(out: str, tiny: bool) -> int:
    import jax

    from benchmarks import fig6_push, fig7_steal, fig9_dag, fig10_scaling

    t0 = time.time()
    results = {
        "meta": {
            "bench": "BENCH_PR2",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
        }
    }
    t6, d6 = fig6_push.run(tiny=tiny)
    t6.show()
    results["fig6_push"] = d6
    t7, d7 = fig7_steal.run(tiny=tiny)
    t7.show()
    results["fig7_steal"] = d7
    t9, d9 = fig9_dag.device_run(tiny=tiny)
    t9.show()
    results["fig9_device_fused"] = d9
    t10, d10 = fig10_scaling.run(tiny=tiny)
    t10.show()
    results["fig10_scaling"] = d10
    results["meta"]["wall_s"] = time.time() - t0
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[benchmarks] wrote {out} "
          f"(kernel push flatness {d6['kernel_flatness_1_to_1024']:.2f}x, "
          f"fused speedup {d9['fused_speedup']:.2f}x, "
          f"fig10 payload ratio==W {d10['payload_ratio_equals_w']}, "
          f"{results['meta']['wall_s']:.1f}s)")
    return 0


def run_scaling(out: str, tiny: bool) -> int:
    import jax

    from benchmarks import fig10_scaling

    t0 = time.time()
    table, data = fig10_scaling.run(tiny=tiny)
    table.show()
    results = {
        "meta": {
            "bench": "BENCH_PR4",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
            "wall_s": time.time() - t0,
        },
        "fig10_scaling": data,
    }
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[benchmarks] wrote {out} "
          f"(payload ratio==W {data['payload_ratio_equals_w']}, "
          f"{results['meta']['wall_s']:.1f}s)")
    return 0


def run_mesh(out: str, tiny: bool) -> int:
    # Claim the fake host devices BEFORE anything imports jax (importing
    # benchmarks.fig11_mesh already pulls jax in, so the env var is set
    # here, inline) — the worker mesh needs one device per lane.  8/64
    # mirror max(fig11_mesh.TINY_WORKERS / WORKERS).
    import os

    n = 8 if tiny else 64
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()

    import jax

    from benchmarks import fig11_mesh

    t0 = time.time()
    table, data = fig11_mesh.run(tiny=tiny)
    table.show()
    results = {
        "meta": {
            "bench": "BENCH_PR5",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
            "wall_s": time.time() - t0,
        },
        "fig11_mesh": data,
    }
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[benchmarks] wrote {out} "
          f"(mesh matches vmap: {data['mesh_matches_vmap']}, "
          f"{results['meta']['wall_s']:.1f}s)")
    return 0


def run_serve(out: str, tiny: bool) -> int:
    # Mesh parity cells need one fake host device per lane; claim them
    # inline BEFORE jax initializes (the run_mesh discipline).
    import os

    from benchmarks import serve_decode

    serve_decode.force_host_devices(max(serve_decode.WORKERS))

    import jax

    t0 = time.time()
    table, data = serve_decode.run(tiny=tiny)
    table.show()
    results = {
        "meta": {
            "bench": "BENCH_PR8",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
            "repro_check": os.environ.get("REPRO_CHECK", ""),
            "wall_s": time.time() - t0,
        },
        "serve_decode": data,
    }
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[benchmarks] wrote {out} "
          f"(parity {data['parity']['modes']} ok, balanced beats rr: "
          f"{data['balanced_beats_rr']}, "
          f"{results['meta']['wall_s']:.1f}s)")
    return 0


def run_chaos(out: str, tiny: bool) -> int:
    import os

    import jax

    from benchmarks import chaos_recovery

    t0 = time.time()
    table, data = chaos_recovery.run(tiny=tiny)
    table.show()
    results = {
        "meta": {
            "bench": "BENCH_PR9",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
            "repro_check": os.environ.get("REPRO_CHECK", ""),
            "wall_s": time.time() - t0,
        },
        "chaos_recovery": data,
    }
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    recompiles = data["live_resize"]["recompiles_during_resize"]
    print(f"[benchmarks] wrote {out} "
          f"(armed-idle overhead flat "
          f"{data['armed_overhead']['armed flat']['overhead']:.2f}x / 2x4 "
          f"{data['armed_overhead']['armed 2x4']['overhead']:.2f}x, "
          f"auto-kills {data['detector']['auto_kills']}, "
          f"resize recompiles {recompiles}, "
          f"{results['meta']['wall_s']:.1f}s)")
    return 0


def run_obs(out: str, tiny: bool) -> int:
    # The mesh phase-breakdown mode needs one fake host device per lane;
    # claim them inline BEFORE jax initializes (the run_mesh discipline).
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

    import jax

    from benchmarks import obs_overhead

    t0 = time.time()
    table, data = obs_overhead.run(tiny=tiny)
    table.show()
    bt, breakdown = obs_overhead.phase_breakdown(tiny=tiny)
    bt.show()
    data["phase_breakdown"] = breakdown
    results = {
        "meta": {
            "bench": "BENCH_PR10",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
            "wall_s": time.time() - t0,
        },
        "obs_overhead": data,
    }
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[benchmarks] wrote {out} "
          f"(probe overhead {data['probe_overhead']:.3f}x "
          f"< {data['overhead_limit']:g}x, gates_ok {data['gates_ok']}, "
          f"modes {sorted(breakdown)}, "
          f"{results['meta']['wall_s']:.1f}s)")
    if not data["gates_ok"]:
        failed = [g for g, ok in data["gates"].items() if not ok]
        print(f"[benchmarks] FAILED obs gates: {failed}", file=sys.stderr)
        return 1
    return 0


def run_adaptive_sweep(out: str, tiny: bool) -> int:
    import jax

    from benchmarks import fig9_dag

    t0 = time.time()
    table, data = fig9_dag.adaptive_sweep(tiny=tiny)
    table.show()
    results = {
        "meta": {
            "bench": "BENCH_PR3",
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "tiny": tiny,
            "wall_s": time.time() - t0,
        },
        "adaptive_sweep": data,
    }
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"[benchmarks] wrote {out} (winner: {data['winner']}, "
          f"{results['meta']['wall_s']:.1f}s)")
    return 0


def run_check() -> int:
    """Tiny Fig. 9 smoke under the conservation sanitizer: the same
    device workload runs unchecked (baseline wall) and with REPRO_CHECK=1
    (every BulkOps call validated, superstep conservation callbacks on),
    asserts zero violations, and reports the sanitizer overhead."""
    import os

    from benchmarks import fig9_dag
    from repro.analysis import sanitize

    had = os.environ.pop("REPRO_CHECK", None)
    try:
        t0 = time.time()
        _, base = fig9_dag.device_run(tiny=True)
        plain_s = time.time() - t0

        os.environ["REPRO_CHECK"] = "1"
        sanitize.reset_violations()
        t0 = time.time()
        _, checked = fig9_dag.device_run(tiny=True)
        checked_s = time.time() - t0
        sanitize.assert_clean()
    finally:
        if had is not None:
            os.environ["REPRO_CHECK"] = had
        else:
            os.environ.pop("REPRO_CHECK", None)
    print(f"[benchmarks] --check: 0 violations "
          f"(fused speedup {base['fused_speedup']:.2f}x unchecked / "
          f"{checked['fused_speedup']:.2f}x checked; sanitizer overhead "
          f"{checked_s / max(plain_s, 1e-9):.1f}x wall, "
          f"{plain_s:.1f}s -> {checked_s:.1f}s)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the DAG workload (slowest)")
    ap.add_argument("--json", action="store_true",
                    help="write machine-readable results to --out")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (implies --json)")
    ap.add_argument("--sweep-adaptive", action="store_true",
                    help="AdaptiveConfig gain/clamp vs static proportions "
                         "on the Fig. 9 DAG workload -> BENCH_PR3.json")
    ap.add_argument("--scaling", action="store_true",
                    help="Fig. 10 worker-count scaling sweep (dense vs "
                         "compact exchange) -> BENCH_PR4.json")
    ap.add_argument("--mesh", action="store_true",
                    help="Fig. 11 vmap-lane vs shard_map executor "
                         "comparison (claims fake host devices; run as "
                         "its own process) -> BENCH_PR5.json")
    ap.add_argument("--serve", action="store_true",
                    help="continuous-batching decode serving: parity gate "
                         "(host/vmap/mesh multisets) + steal-balanced vs "
                         "static round-robin sweep -> BENCH_PR8.json")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-recovery chaos sweep: armed-idle overhead, "
                         "seeded kill/delay/drop drains (flat and 2x4 "
                         "pods), detector delay->kill conversion, live "
                         "no-rebuild resize -> BENCH_PR9.json")
    ap.add_argument("--obs", action="store_true",
                    help="observability gates: phase-probe overhead on the "
                         "fused Fig. 9 drain (< 5%%, bit-identical, zero "
                         "compiles when off) + host/vmap/mesh per-phase "
                         "wall split -> BENCH_PR10.json")
    ap.add_argument("--check", action="store_true",
                    help="tiny Fig. 9 smoke under the conservation "
                         "sanitizer (REPRO_CHECK=1); fails on any "
                         "invariant violation and reports the overhead")
    ap.add_argument("--out", default=None,
                    help="output path for --json / --sweep-adaptive / "
                         "--scaling")
    args = ap.parse_args()

    if args.check:
        return run_check()
    if args.obs:
        return run_obs(args.out or "BENCH_PR10.json", args.tiny)
    if args.chaos:
        return run_chaos(args.out or "BENCH_PR9.json", args.tiny)
    if args.serve:
        return run_serve(args.out or "BENCH_PR8.json", args.tiny)
    if args.mesh:
        return run_mesh(args.out or "BENCH_PR5.json", args.tiny)
    if args.scaling:
        return run_scaling(args.out or "BENCH_PR4.json", args.tiny)
    if args.sweep_adaptive:
        return run_adaptive_sweep(args.out or "BENCH_PR3.json", args.tiny)
    if args.json or args.tiny:
        return run_json(args.out or "BENCH_PR2.json", args.tiny)

    from benchmarks import (fig6_push, fig7_steal, fig8_optimized_steal,
                            pop_parity, fig9_dag, fig10_scaling,
                            roofline_report, moe_steal, solver_scale)

    t0 = time.time()
    fig6_push.run()[0].show()
    fig7_steal.run()[0].show()
    fig8_table, fig8b_table, _, _ = fig8_optimized_steal.run()
    fig8_table.show()
    fig8b_table.show()
    pop_parity.run().show()
    moe_steal.run().show()
    solver_scale.run().show()
    fig9_dag.device_run()[0].show()
    fig10_scaling.run()[0].show()
    if not args.quick:
        fig9_dag.run().show()
    tb = roofline_report.run()
    if tb:
        tb.show()
    print(f"[benchmarks] total {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
