"""Benchmark entrypoint: one table per paper figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the DAG workload (slowest)")
    args = ap.parse_args()

    from benchmarks import (fig6_push, fig7_steal, fig8_optimized_steal,
                            pop_parity, fig9_dag, roofline_report,
                            moe_steal, solver_scale)

    t0 = time.time()
    fig6_push.run().show()
    fig7_steal.run().show()
    fig8_table, fig8b_table, _, _ = fig8_optimized_steal.run()
    fig8_table.show()
    fig8b_table.show()
    pop_parity.run().show()
    moe_steal.run().show()
    solver_scale.run().show()
    if not args.quick:
        fig9_dag.run().show()
    tb = roofline_report.run()
    if tb:
        tb.show()
    print(f"[benchmarks] total {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
