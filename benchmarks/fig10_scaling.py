"""Fig. 10 — worker-count scaling of the superstep exchange.

The compact collective's claim (core/master.py): per-round exchange
payload is O(max_steal) per lane, independent of W, versus the dense
``all_to_all``'s O(W * max_steal) — and the wall clock should be no
worse at small W and better once W is large enough that the dense
outbox dominates the round.

The sweep runs W x max_steal x {dense, compact} through the SAME
vmapped superstep driver the rest of the suite uses (the plan, the
backend routing and the workload are identical across the two exchange
columns; only the collective differs).  The payload column
(``bytes_moved`` from ``RebalanceStats``) is machine-independent; wall
per round is the usual noisy-shared-runner caveat (min over repeats).

Workload: every 8th lane is seeded heavy (half the ring), and every
timed round starts from that SAME seeded state (the paper's
reset-between-iterations methodology, ``benchmarks/common.time_ns``) —
so every timed round is the identical round-1 and provably plans
transfers.  Letting the state evolve instead would converge to balance
within a few rounds and the compact column would start winning through
its zero-transfer fast path; that skip is real but is measured by the
unit tests (``test_compact_zero_transfer_fast_path``), not here — this
figure isolates the cost of a round that MOVES work.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import Table
from repro.core import ops as bulk_ops
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import vmapped_superstep

WORKERS = (8, 16, 64, 256)
MAX_STEALS = (64, 256)
TINY_WORKERS = (4, 8, 16)
TINY_MAX_STEALS = (32,)
ROUNDS = 4
SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _seeded(n_workers: int, capacity: int) -> bulk_ops.QueueState:
    """W stacked queues, every 8th lane holding half the ring (distinct
    int payloads), the rest empty — sustained transfers for ROUNDS."""
    heavy = capacity // 2
    lane = jnp.arange(n_workers, dtype=jnp.int32)[:, None]
    buf = lane * capacity + jnp.arange(capacity, dtype=jnp.int32)[None, :] + 1
    sizes = jnp.where(lane[:, 0] % 8 == 0, jnp.int32(heavy), jnp.int32(0))
    return bulk_ops.QueueState(
        buf=buf, lo=jnp.zeros((n_workers,), jnp.int32), size=sizes)


def _bench_cell(n_workers: int, max_steal: int, exchange: str,
                repeats: int) -> Dict:
    capacity = 4 * max_steal
    pol = StealPolicy(proportion=0.5, low_watermark=2,
                      high_watermark=max_steal // 2, max_steal=max_steal,
                      exchange=exchange)
    step = vmapped_superstep(pol)

    qs0 = _seeded(n_workers, capacity)
    # Warm pass: compiles, and yields the (deterministic) round counters.
    # Every timed round below replays this exact state, so these numbers
    # hold for every timed round, not just the first.
    qs, stats = step(qs0)
    bytes_rd = int(jax.device_get(stats.bytes_moved)[0])
    moved_rd = int(jax.device_get(stats.n_transferred)[0])
    assert moved_rd > 0, "fig10 workload must transfer every timed round"
    jax.block_until_ready(qs.size)

    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(ROUNDS):
            qs, stats = step(qs0)  # reset: identical transferring round
        jax.block_until_ready(qs.size)
        best = min(best, time.perf_counter() - t0)
    return {
        "workers": n_workers,
        "max_steal": max_steal,
        "capacity": capacity,
        "exchange": exchange,
        "rounds": ROUNDS,
        "wall_per_round_ms": best / ROUNDS * 1e3,
        "bytes_moved_per_round": bytes_rd,
        "items_moved_per_round": moved_rd,
    }


def run(tiny: bool = False, repeats: int | None = None
        ) -> Tuple[Table, Dict]:
    workers = TINY_WORKERS if tiny else WORKERS
    max_steals = TINY_MAX_STEALS if tiny else MAX_STEALS
    repeats = repeats or (2 if tiny else 3)

    rows: List[Dict] = []
    t = Table(f"Fig. 10: exchange scaling over worker count "
              f"({ROUNDS} reset transferring rounds/rep, min of {repeats})",
              "W x max_steal",
              ["dense ms/rd", "compact ms/rd", "speedup",
               "dense B/rd", "compact B/rd", "payload ratio"])
    for ms in max_steals:
        for w in workers:
            cell = {}
            for exchange in ("dense", "compact"):
                r = _bench_cell(w, ms, exchange, repeats)
                rows.append(r)
                cell[exchange] = r
            d, c = cell["dense"], cell["compact"]
            speedup = d["wall_per_round_ms"] / max(c["wall_per_round_ms"],
                                                   1e-9)
            ratio = (d["bytes_moved_per_round"]
                     / max(c["bytes_moved_per_round"], 1))
            t.add(f"{w} x {ms}",
                  [f"{d['wall_per_round_ms']:.2f}",
                   f"{c['wall_per_round_ms']:.2f}",
                   f"{speedup:.2f}x",
                   d["bytes_moved_per_round"],
                   c["bytes_moved_per_round"],
                   f"{ratio:.0f}x"])
    data = {
        "workers": list(workers),
        "max_steals": list(max_steals),
        "rounds": ROUNDS,
        "repeats": repeats,
        "cells": rows,
        # machine-independent acceptance: payload ratio == W per cell
        "payload_ratio_equals_w": all(
            a["bytes_moved_per_round"] == a["workers"]
            * b["bytes_moved_per_round"]
            for a, b in zip(rows[0::2], rows[1::2])),
    }
    return t, data


if __name__ == "__main__":
    run()[0].show()
