"""Shared benchmark utilities: ns-resolution latency measurement with the
paper's methodology (queue state reset between iterations; mean over
repeats after warmup)."""

from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List

__all__ = ["time_ns", "Table"]


def time_ns(setup: Callable[[], object], op: Callable[[object], None],
            repeats: int = 200, warmup: int = 20) -> float:
    """Mean ns per op; ``setup`` builds fresh state per iteration
    (the paper resets the queue every iteration).  For A/B comparisons on
    noisy shared machines use interleaved min-of-samples instead (see
    ``fig8_optimized_steal._ab_min``)."""
    for _ in range(warmup):
        st = setup()
        op(st)
    samples: List[float] = []
    for _ in range(repeats):
        st = setup()
        t0 = time.perf_counter_ns()
        op(st)
        samples.append(time.perf_counter_ns() - t0)
    return statistics.mean(samples)


class Table:
    def __init__(self, title: str, col0: str, columns: List[str]):
        self.title = title
        self.col0 = col0
        self.columns = columns
        self.rows: List[List[str]] = []

    def add(self, label, values):
        self.rows.append([str(label)] + [f"{v:,.0f}" if isinstance(v, (int, float))
                                         else str(v) for v in values])

    def render(self) -> str:
        head = [self.col0] + self.columns
        widths = [max(len(head[i]), *(len(r[i]) for r in self.rows))
                  for i in range(len(head))]
        def fmt(row):
            return " | ".join(c.rjust(w) for c, w in zip(row, widths))
        sep = "-+-".join("-" * w for w in widths)
        lines = [f"== {self.title} ==", fmt(head), sep]
        lines += [fmt(r) for r in self.rows]
        return "\n".join(lines)

    def show(self):
        print(self.render(), flush=True)
        print()
