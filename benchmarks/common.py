"""Shared benchmark harness.

Latency methodology follows the paper (queue state reset between
iterations; mean over repeats after warmup; for A/B comparisons on noisy
shared machines use interleaved min-of-samples — see
``fig8_optimized_steal._ab_min``).

Since the BulkOps redesign the harness sweeps BOTH queue dialects
through one surface:

* **host implementations** behind the
  :class:`repro.core.host_queue.HostQueue` protocol
  (:func:`host_queue_impls` — the faithful paper port and the two
  Taskflow-style baselines; :class:`repro.core.queue.PagedQueue`
  satisfies the same protocol and can be added to any sweep);
* **device backends** behind :class:`repro.core.ops.BulkOps`
  (:func:`device_backends` — at least ``"reference"`` and ``"auto"``,
  the paper's cross-implementation comparison for the ring queue).

``bench_push`` / ``bench_pop`` / ``bench_steal`` time any HostQueue;
the fig modules provide the matching BulkOps timers.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List, Tuple

__all__ = [
    "time_ns",
    "Table",
    "host_queue_impls",
    "device_backends",
    "bench_push",
    "bench_pop",
    "bench_steal",
]


def time_ns(setup: Callable[[], object], op: Callable[[object], None],
            repeats: int = 200, warmup: int = 20) -> float:
    """Mean ns per op; ``setup`` builds fresh state per iteration
    (the paper resets the queue every iteration)."""
    for _ in range(warmup):
        st = setup()
        op(st)
    samples: List[float] = []
    for _ in range(repeats):
        st = setup()
        t0 = time.perf_counter_ns()
        op(st)
        samples.append(time.perf_counter_ns() - t0)
    return statistics.mean(samples)


# ---------------------------------------------------------------------------
# The unified sweep surface
# ---------------------------------------------------------------------------


def host_queue_impls() -> Dict[str, Callable[[], object]]:
    """Named HostQueue factories every host-level sweep iterates:
    the paper's queue and the two Taskflow-style baselines."""
    from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                       ResizingArrayQueue)

    return {
        "LF_Queue": LinkedWSQueue,
        "TF_UB-style": PerItemDequeQueue,
        "TF_BD-style": lambda: ResizingArrayQueue(capacity=64),
    }


def device_backends() -> Tuple[str, ...]:
    """BulkOps backend names every device-level sweep iterates.  The
    ``reference`` / ``auto`` pair is the paper's cross-implementation
    comparison (oracle vs geometry-resolved kernels); on TPU the
    explicit ``pallas`` routing is added as a third column."""
    import jax

    names: Tuple[str, ...] = ("reference", "auto")
    if jax.default_backend() == "tpu":
        names = names + ("pallas",)
    return names


def bench_push(factory: Callable[[], object], batch: int,
               repeats: int = 200) -> float:
    """ns per bulk push of ``batch`` items through the HostQueue
    protocol.  Batch preparation (pre-linking / device transfer) happens
    in ``setup`` via ``make_batch`` — only the splice is timed, which is
    what the paper's Fig. 6 measures."""
    payload = list(range(batch))

    def setup():
        q = factory()
        return q, q.make_batch(payload)

    def op(st):
        q, prepared = st
        q.push_batch(prepared)

    return time_ns(setup, op, repeats=repeats)


def bench_pop(factory: Callable[[], object], initial: int,
              repeats: int = 300) -> float:
    """ns per single pop from a queue seeded with ``initial`` items."""
    items = list(range(initial))

    def setup():
        q = factory()
        q.push_bulk(items)
        return q

    def op(q):
        q.pop_item()

    return time_ns(setup, op, repeats=repeats, warmup=30)


def bench_steal(factory: Callable[[], object], proportion: float,
                initial: int, repeats: int = 60) -> float:
    """ns per proportional bulk steal from a queue of ``initial`` items."""
    items = list(range(initial))

    def setup():
        q = factory()
        q.push_bulk(items)
        return q

    def op(q):
        q.steal_bulk(proportion)

    return time_ns(setup, op, repeats=repeats, warmup=6)


class Table:
    def __init__(self, title: str, col0: str, columns: List[str]):
        self.title = title
        self.col0 = col0
        self.columns = columns
        self.rows: List[List[str]] = []

    def add(self, label, values):
        self.rows.append([str(label)] + [f"{v:,.0f}" if isinstance(v, (int, float))
                                         else str(v) for v in values])

    def render(self) -> str:
        head = [self.col0] + self.columns
        widths = [max(len(head[i]), *(len(r[i]) for r in self.rows))
                  for i in range(len(head))]
        def fmt(row):
            return " | ".join(c.rjust(w) for c, w in zip(row, widths))
        sep = "-+-".join("-" * w for w in widths)
        lines = [f"== {self.title} ==", fmt(head), sep]
        lines += [fmt(r) for r in self.rows]
        return "\n".join(lines)

    def show(self):
        print(self.render(), flush=True)
        print()
