"""Chaos benchmark: fault-recovery overhead, drain cost, detection, resize.

All cells run on vmap lanes only, so the whole sweep fits the 1-device
CPU CI container (the mesh side of every path is pinned bit-identical by
tests/test_hierarchical_fault.py; re-timing it here would only measure
shard_map dispatch, which ``--mesh`` already covers).

Four sections, one table:

* **armed idle overhead** — the Fig. 9 DAG with the fault layer OFF
  (plain superstep) vs ARMED with an empty :class:`FaultPlan` (masked
  plans + recovery plan compiled in, nothing ever dies) vs armed
  HIERARCHICAL (2x4 pods: 4-plan resilient round).  The gap is the
  steady-state price of resilience when nothing fails.
* **chaos drain** — seeded :meth:`FaultPlan.random` schedules (kills +
  delays + drops) at W=8 flat and 2x4 hierarchical: rounds to drain the
  DAG, items moved (normal + recovery steals), node conservation.
* **detector conversion** — an injected delay schedule converted by
  :class:`FailureDetector` into real kills (``auto_kill`` fault events),
  with the item multiset preserved across the kills.
* **live resize** — ``padded_runtime`` at ``W_max``: grow + shrink +
  redispatch with ZERO new compiles (jit cache population before ==
  after).
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table
from benchmarks.fig9_dag import _device_body
from repro.core.policy import StealPolicy
from repro.distributed import elastic
from repro.runtime import DetectorPolicy, FaultPlan, StealRuntime

WORKERS = 8
POD_SIZE = 4
BATCH = 64
CAPACITY = 4096
SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _policy() -> StealPolicy:
    return StealPolicy(proportion=0.5, low_watermark=BATCH // 2,
                       high_watermark=4 * BATCH, max_steal=1024)


def _runtime(fault_plan=None, pod_size=None) -> StealRuntime:
    return StealRuntime(WORKERS, CAPACITY, SPEC, policy=_policy(),
                        max_pop=BATCH, fault_plan=fault_plan,
                        pod_size=pod_size)


def _drain(rt: StealRuntime, n_nodes: int, k: int = 8,
           max_rounds: int = 500) -> Tuple[int, int, float]:
    """Drive the DAG to empty; returns (explored, rounds, wall_s)."""
    body = _device_body(n_nodes, BATCH, rt.ops)
    rt.push(0, jnp.zeros((1,), jnp.int32), 1)
    carry = jnp.zeros((WORKERS,), jnp.int32)
    rounds = 0
    t0 = time.perf_counter()
    while int(rt.total_size()) > 0 and rounds < max_rounds:
        carry, _, r = rt.run_fused(k, body, carry, until_drained=True)
        rounds += r
    jax.block_until_ready(rt.queues.size)
    return int(jnp.sum(carry)), rounds, time.perf_counter() - t0


def _items(rt: StealRuntime):
    q = jax.tree_util.tree_map(np.asarray, rt.queues)
    cap = q.buf.shape[1]
    out = []
    for i in range(rt.n_workers):
        lo, sz = int(q.lo[i]), int(q.size[i])
        out += [int(q.buf[i][(lo + j) % cap]) for j in range(sz)]
    return sorted(out)


# ---------------------------------------------------------------------------
# Section 1: armed-idle overhead
# ---------------------------------------------------------------------------


def armed_overhead(t: Table, tiny: bool) -> Dict:
    n_nodes = 20_000 if tiny else 100_000
    repeats = 2 if tiny else 5
    rows = [("unarmed", None, None),
            ("armed flat", FaultPlan(), None),
            ("armed 2x4", FaultPlan(), POD_SIZE)]
    out: Dict = {"n_nodes": n_nodes}
    walls = {}
    for label, plan, ps in rows:
        best = float("inf")
        explored = rounds = 0
        for _ in range(repeats):
            rt = _runtime(fault_plan=plan, pod_size=ps)
            explored, rounds, wall = _drain(rt, n_nodes)
            best = min(best, wall)
        assert explored == n_nodes, (label, explored)
        walls[label] = best
        over = best / max(walls["unarmed"], 1e-12)
        t.add(f"idle overhead: {label}",
              [f"{best * 1e3:.0f} ms", rounds, explored, f"{over:.2f}x"])
        out[label] = {"wall_s": best, "rounds": rounds,
                      "overhead": over}
    return out


# ---------------------------------------------------------------------------
# Section 2: chaos drain under seeded random schedules
# ---------------------------------------------------------------------------


def chaos_drain(t: Table, tiny: bool) -> Dict:
    n_nodes = 20_000 if tiny else 100_000
    seeds = (0, 1) if tiny else (0, 1, 2, 3)
    out: Dict = {"n_nodes": n_nodes, "runs": []}
    for pod_size, topo in ((None, "flat"), (POD_SIZE, "2x4")):
        for seed in seeds:
            plan = FaultPlan.random(WORKERS, seed=seed, n_kills=2,
                                    n_delays=2, n_drops=1, max_round=12)
            rt = _runtime(fault_plan=plan, pod_size=pod_size)
            explored, rounds, wall = _drain(rt, n_nodes)
            assert explored == n_nodes, (topo, seed, explored)
            assert (rt.sizes()[rt.dead_lanes()] == 0).all()
            moved = rt.telemetry.total_transferred
            t.add(f"chaos {topo} seed={seed}",
                  [f"{wall * 1e3:.0f} ms", rounds, explored,
                   f"{moved:,} moved"])
            out["runs"].append({
                "topology": topo, "seed": seed, "rounds": rounds,
                "wall_s": wall, "items_moved": int(moved),
                "kills": len(plan.kills), "conserved": True})
    return out


# ---------------------------------------------------------------------------
# Section 3: detector delay -> kill conversion
# ---------------------------------------------------------------------------


def detector_conversion(t: Table, tiny: bool) -> Dict:
    plan = FaultPlan(delays=((2, 1, 64), (6, 3, 64)))
    rt = StealRuntime(WORKERS, 256, SPEC,
                      policy=StealPolicy(backend="reference",
                                         low_watermark=4,
                                         high_watermark=16, max_steal=64),
                      fault_plan=plan)
    det = rt.attach_detector(DetectorPolicy(suspect_after=2, dead_after=4))
    rng = np.random.default_rng(42)
    for w in range(WORKERS):
        n = int(rng.integers(10, 40))
        rt.push(w, jnp.arange(w * 100, w * 100 + n, dtype=jnp.int32), n)
    before = _items(rt)
    t0 = time.perf_counter()
    rounds = 0
    while rt.telemetry.fault_events.get("auto_kill", 0) < 2 and rounds < 32:
        rt.round()
        rounds += 1
    wall = time.perf_counter() - t0
    kills = rt.telemetry.fault_events.get("auto_kill", 0)
    conserved = _items(rt) == before
    assert kills == 2 and conserved, (kills, conserved)
    assert det.state(2) == "dead" and det.state(6) == "dead"
    t.add("detector: 2 delayed lanes",
          [f"{wall * 1e3:.0f} ms", rounds, f"{kills} auto-kills",
           "conserved" if conserved else "LOST ITEMS"])
    return {"rounds_to_kill": rounds, "auto_kills": int(kills),
            "conserved": conserved,
            "dead_lanes": np.flatnonzero(np.asarray(rt.dead_lanes()))
            .tolist()}


# ---------------------------------------------------------------------------
# Section 4: live resize at fixed W_max — zero recompiles
# ---------------------------------------------------------------------------


def live_resize(t: Table, tiny: bool) -> Dict:
    rt = elastic.padded_runtime(
        4, 256, SPEC, w_max=WORKERS,
        policy=StealPolicy(backend="reference", low_watermark=2,
                           high_watermark=8, max_steal=64))
    rt.push(0, jnp.arange(96, dtype=jnp.int32), 96)
    before = _items(rt)
    # Warm BOTH dispatch shapes (per-round and fused) at the padded
    # width; every later resize must reuse these compiled entries.
    for _ in range(3):
        rt.round()
    rt.run_fused(4)
    c0 = elastic.compile_count(rt)
    t0 = time.perf_counter()
    grown = elastic.live_grow(rt, 3)
    for _ in range(3):
        rt.round()
    shrink_rounds = elastic.live_shrink(rt, grown[:1])
    rt.run_fused(4)
    elastic.live_grow(rt, 1)
    rt.round()
    wall = time.perf_counter() - t0
    delta = elastic.compile_count(rt) - c0
    conserved = _items(rt) == before
    assert delta == 0, delta
    assert conserved
    t.add(f"live resize 4->7->6->7 lanes (W_max={WORKERS})",
          [f"{wall * 1e3:.0f} ms", shrink_rounds,
           f"recompiles: {delta}", "conserved"])
    return {"w_max": WORKERS, "warmup_compiles": int(c0),
            "recompiles_during_resize": int(delta),
            "shrink_rounds": int(shrink_rounds), "conserved": conserved}


def run(tiny: bool = False) -> Tuple[Table, Dict]:
    t = Table(f"Chaos: fault recovery on {WORKERS} lanes "
              f"(flat and {WORKERS // POD_SIZE}x{POD_SIZE} pods, vmap)",
              "scenario", ["wall", "rounds", "outcome", "notes"])
    data = {
        "armed_overhead": armed_overhead(t, tiny),
        "chaos_drain": chaos_drain(t, tiny),
        "detector": detector_conversion(t, tiny),
        "live_resize": live_resize(t, tiny),
    }
    return t, data
