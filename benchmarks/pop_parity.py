"""Pop-latency parity (paper §IV: ~213-216 ns across implementations,
figure omitted in the paper; reproduced as a table)."""

from __future__ import annotations

from benchmarks.common import Table, time_ns
from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                   ResizingArrayQueue, llist_from_iter)

N = 1024


def _bench(cls) -> float:
    items = list(range(N))

    def setup():
        if cls is LinkedWSQueue:
            q = LinkedWSQueue()
            q.push(llist_from_iter(items))
        else:
            q = cls() if cls is PerItemDequeQueue else cls(capacity=64)
            q.push(items)
        return q

    def op(q):
        q.pop()

    return time_ns(setup, op, repeats=300, warmup=30)


def run() -> Table:
    t = Table("Pop parity (ns/op)", "impl", ["latency"])
    t.add("LF_Queue", [_bench(LinkedWSQueue)])
    t.add("TF_UB-style", [_bench(PerItemDequeQueue)])
    t.add("TF_BD-style", [_bench(ResizingArrayQueue)])
    return t


if __name__ == "__main__":
    run().show()
