"""Pop-latency parity (paper §IV: ~213-216 ns across implementations,
figure omitted in the paper; reproduced as a table).  Host
implementations are swept through the unified ``HostQueue`` harness."""

from __future__ import annotations

from benchmarks.common import Table, bench_pop, host_queue_impls

N = 1024


def run() -> Table:
    t = Table("Pop parity (ns/op)", "impl", ["latency"])
    for name, factory in host_queue_impls().items():
        t.add(name, [bench_pop(factory, N)])
    return t


if __name__ == "__main__":
    run().show()
