"""Fig. 8 — original (counted) vs optimized steal.

Paper claim: skipping the post-cut tail traversal when the owner made no
concurrent update cuts latency up to ~3x at large proportions.  The JAX
ring queue's count is ALWAYS cursor-derived (the optimized variant is
the TPU-native default); ``steal_counted`` reproduces the worst case
with an explicit sequential probe chain.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Table, time_ns
from repro.core.host_queue import LinkedWSQueue, llist_from_iter
from repro.core import queue as q_ops

PROPORTIONS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6)
INITIAL = 10_000


def _host(optimized: bool, p: float) -> float:
    items = list(range(INITIAL))

    def setup():
        q = LinkedWSQueue()
        q.push(llist_from_iter(items))
        return q

    def op(q):
        (q.steal_optimized if optimized else q.steal)(p)

    return time_ns(setup, op, repeats=60, warmup=6)


def _jax(counted: bool, p: float) -> float:
    spec = jnp.zeros((), jnp.int32)
    q0 = q_ops.make_queue(16_384, spec)
    items = jnp.arange(INITIAL, dtype=jnp.int32)
    q0, _ = jax.jit(q_ops.push)(q0, items, jnp.int32(INITIAL))
    jax.block_until_ready(q0.size)
    fn = q_ops.steal_counted if counted else q_ops.steal
    steal = jax.jit(lambda q: fn(q, p, max_steal=8192))

    def op(q):
        st, batch, n = steal(q)
        jax.block_until_ready(n)

    return time_ns(lambda: q0, op, repeats=40, warmup=6)


def run() -> Table:
    t = Table("Fig. 8: steal latency (ns) — counted vs optimized",
              "steal %", ["host counted", "host optimized",
                          "JAX counted", "JAX optimized", "host speedup"])
    for p in PROPORTIONS:
        hc = _host(False, p)
        ho = _host(True, p)
        jc = _jax(True, p)
        jo = _jax(False, p)
        t.add(f"{int(p*100)}%", [hc, ho, jc, jo, f"{hc / max(ho,1):.2f}x"])
    return t


if __name__ == "__main__":
    run().show()
