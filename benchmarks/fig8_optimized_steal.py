"""Fig. 8 — original (counted) vs optimized steal, and the kernel path.

Paper claim: skipping the post-cut tail traversal when the owner made no
concurrent update cuts latency up to ~3x at large proportions.  The JAX
ring queue's count is ALWAYS cursor-derived (the optimized variant is
the TPU-native default); ``steal_counted`` reproduces the worst case
with an explicit sequential probe chain.

This benchmark also exercises the production path end-to-end: the second
table drives full :class:`repro.runtime.StealRuntime` rebalancing rounds
(plan + backend-routed block detach + collective exchange + splice) and
compares
the ``"pallas"`` BulkOps backend (Pallas ring-gather on TPU, the kernel
module's jnp oracle elsewhere) against the ``"reference"`` backend at
every measured proportion.  The flat-latency claim holds iff the kernel
column is no slower than the reference one across the sweep
(``--check`` asserts it).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Table, time_ns
from repro.core.host_queue import LinkedWSQueue, llist_from_iter
from repro.core import ops as bulk_ops
from repro.core.policy import StealPolicy
from repro.runtime import StealRuntime

REFERENCE = bulk_ops.make_ops("reference")
PALLAS = bulk_ops.make_ops("pallas")

PROPORTIONS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6)
INITIAL = 10_000
CAPACITY = 16_384
MAX_STEAL = 8192
N_WORKERS = 8


def _host(optimized: bool, p: float) -> float:
    items = list(range(INITIAL))

    def setup():
        q = LinkedWSQueue()
        q.push(llist_from_iter(items))
        return q

    def op(q):
        (q.steal_optimized if optimized else q.steal)(p)

    return time_ns(setup, op, repeats=60, warmup=6)


def _seeded_queue():
    spec = jnp.zeros((), jnp.int32)
    q0 = bulk_ops.make_queue(CAPACITY, spec)
    items = jnp.arange(INITIAL, dtype=jnp.int32)
    q0, _ = REFERENCE.push(q0, items, jnp.int32(INITIAL))
    jax.block_until_ready(q0.size)
    return q0


def _jax_counted(p: float) -> float:
    q0 = _seeded_queue()
    steal = jax.jit(lambda q: bulk_ops.steal_counted(q, p,
                                                     max_steal=MAX_STEAL))

    def op(q):
        st, batch, n = steal(q)
        jax.block_until_ready(n)

    return time_ns(lambda: q0, op, repeats=40, warmup=6)


def _ab_min(setup, op_a, op_b, repeats: int, warmup: int):
    """Interleaved A/B timing: alternate the two variants sample by sample
    so machine-load drift hits both equally, and take the min (the robust
    estimate for an A/B comparison on shared/CI machines)."""
    import time as _time

    for _ in range(warmup):
        op_a(setup())
        op_b(setup())
    best_a = best_b = float("inf")
    for _ in range(repeats):
        st = setup()
        t0 = _time.perf_counter_ns()
        op_a(st)
        best_a = min(best_a, _time.perf_counter_ns() - t0)
        st = setup()
        t0 = _time.perf_counter_ns()
        op_b(st)
        best_b = min(best_b, _time.perf_counter_ns() - t0)
    return best_a, best_b


def _jax_func_vs_kernel(p: float):
    """(reference, pallas) backend steal latency, interleaved."""
    q0 = _seeded_queue()
    s_func = jax.jit(lambda q: REFERENCE.steal(q, p, max_steal=MAX_STEAL))
    s_kern = jax.jit(lambda q: PALLAS.steal(q, p, max_steal=MAX_STEAL))

    def run_with(fn):
        def op(q):
            st, batch, n = fn(q)
            jax.block_until_ready(n)
        return op

    return _ab_min(lambda: q0, run_with(s_func), run_with(s_kern),
                   repeats=100, warmup=6)


def _executor_rounds(p: float):
    """(reference, pallas) latency of one full rebalancing round through
    the unified executor — the replicated plan, the victim-side detach,
    the collective block exchange and the thief splice — interleaved."""
    spec = jnp.zeros((), jnp.int32)
    policy = StealPolicy(proportion=p, low_watermark=1, high_watermark=8,
                         max_steal=MAX_STEAL)
    runtimes = {}
    for backend in ("reference", "pallas"):
        rt = StealRuntime(N_WORKERS, CAPACITY, spec, policy=policy,
                          adaptive=False, backend=backend)
        rt.push(0, jnp.arange(INITIAL, dtype=jnp.int32), INITIAL)
        seeded = jax.tree_util.tree_map(lambda x: x.copy(), rt.queues)
        rt.round()  # compile once outside the timed region
        jax.block_until_ready(rt.queues.size)
        runtimes[backend] = (rt, seeded)

    def op_for(backend):
        rt, seeded = runtimes[backend]

        def op(_):
            # fresh copy per iteration (the round may donate its input)
            rt.queues = jax.tree_util.tree_map(lambda x: x.copy(), seeded)
            rt.round()
            jax.block_until_ready(rt.queues.size)
        return op

    return _ab_min(lambda: None, op_for("reference"), op_for("pallas"),
                   repeats=30, warmup=3)


def run():
    t = Table("Fig. 8: steal latency (ns) — counted vs optimized vs kernel",
              "steal %", ["host counted", "host optimized", "JAX counted",
                          "JAX reference", "JAX pallas", "host speedup",
                          "kernel/ref"])
    ratios = {}
    for p in PROPORTIONS:
        hc = _host(False, p)
        ho = _host(True, p)
        jc = _jax_counted(p)
        jf, jk = _jax_func_vs_kernel(p)
        ratios[p] = jk / max(jf, 1)
        t.add(f"{int(p*100)}%", [hc, ho, jc, jf, jk,
                                 f"{hc / max(ho,1):.2f}x",
                                 f"{ratios[p]:.2f}x"])

    t2 = Table("Fig. 8b: full executor round (ns) — pallas vs reference "
               f"backend ({N_WORKERS} lanes, {INITIAL} tasks on lane 0)",
               "steal %", ["reference", "pallas", "kernel/ref"])
    round_ratios = {}
    for p in PROPORTIONS:
        rf, rk = _executor_rounds(p)
        round_ratios[p] = rk / max(rf, 1)
        t2.add(f"{int(p*100)}%", [rf, rk, f"{round_ratios[p]:.2f}x"])
    return t, t2, ratios, round_ratios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the pallas backend is no slower than the "
                         "reference backend at every proportion")
    args = ap.parse_args()
    t, t2, ratios, round_ratios = run()
    t.show()
    t2.show()
    if args.check:
        # The production claim is about the executor round (the steal hot
        # path end-to-end); the bare-op column is a sanity bound with
        # looser slack — at ~100us/op the shared-machine noise floor is
        # larger than any real difference between two identical gathers.
        slack = {"round": 1.25, "op": 2.0}
        bad = {f"{kind}@{int(p*100)}%": f"{r:.2f}x"
               for kind, d in (("op", ratios), ("round", round_ratios))
               for p, r in d.items() if r > slack[kind]}
        assert not bad, f"pallas backend slower than reference: {bad}"
        print("CHECK OK: pallas-backend executor round within "
              f"{slack['round']}x of the reference backend at every "
              "proportion")


if __name__ == "__main__":
    main()
