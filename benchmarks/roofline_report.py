"""Roofline table from the dry-run JSON (§Roofline deliverable)."""

from __future__ import annotations

import json
import os

from benchmarks.common import Table

DEFAULT = "results/dryrun.json"
CANDIDATES = ("results/dryrun.json", "results/dryrun_v3.json",
              "results/dryrun_v2.json")


def load(path: str | None = None):
    if path is None:
        for c in CANDIDATES:
            if os.path.exists(c):
                path = c
                break
    if path is None or not os.path.exists(path):
        return None, path
    with open(path) as f:
        return json.load(f), path


def run() -> Table | None:
    results, path = load()
    t = Table(f"Roofline terms per (arch x shape), single-pod 16x16 "
              f"[{path}]", "arch x shape",
              ["compute ms", "memory ms", "collect ms", "bottleneck",
               "mem GiB", "fits", "useful"])
    if results is None:
        t.add("(no dry-run results found — run repro.launch.dryrun)",
              ["-"] * 7)
        return t
    for r in results:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        rt = r["roofline"]
        ma = r["memory_analysis"]
        t.add(f"{r['arch']} x {r['shape']}", [
            f"{rt['compute_s']*1e3:.1f}",
            f"{rt['memory_s']*1e3:.1f}",
            f"{rt['collective_s']*1e3:.1f}",
            r["bottleneck"],
            f"{ma['peak_bytes']/2**30:.2f}",
            "Y" if ma.get("fits_16g") else "N",
            f"{r['useful_ratio']:.3f}",
        ])
    errs = [r for r in results if r.get("status") != "ok"]
    for r in errs:
        t.add(f"{r['arch']} x {r['shape']} x {r['mesh']}",
              ["ERROR", "-", "-", "-", "-", "-", "-"])
    return t


if __name__ == "__main__":
    tb = run()
    if tb:
        tb.show()
