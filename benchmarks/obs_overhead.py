"""Observability overhead gates on the Fig. 9 device drain.

The phase probe's contract (DESIGN.md §11) has three measurable edges,
and this benchmark measures all three on the same tiny Fig. 9 DAG
workload the fused-speedup gate uses:

* **Overhead** — a probed fused drain must cost < 5 % extra wall over
  the identical unprobed drain (best-of-repeats both sides; the probe's
  steady-state cost is two clock reads per block plus four amortized
  calibration dispatches per ``calibrate_every`` rounds).
* **Bit-identity** — the probed drain's final queue state and carry are
  leaf-for-leaf identical to the unprobed drain's (prefix programs are
  pure and never donate, so they cannot perturb the committed rounds).
* **Compile-identity when off** — a runtime with the probe attached but
  DISABLED compiles exactly the same programs as a never-probed runtime
  (``elastic.compile_count`` equal, zero probe-cache entries) and
  produces the identical result: disarmed observability is free.

``run()`` returns the gate table + the dict ``benchmarks/run.py --obs``
writes into ``BENCH_PR10.json``; :func:`phase_breakdown` adds the
host-round / vmap-fused / mesh-fused per-phase splits.
"""

from __future__ import annotations

import statistics
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import fig9_dag
from benchmarks.common import Table
from repro.distributed.elastic import compile_count

OVERHEAD_LIMIT = 1.05


def _fingerprint(rt, carry) -> List[np.ndarray]:
    leaves = jax.tree_util.tree_leaves((rt.queues, carry))
    return [np.asarray(x) for x in leaves]


def _same(a: List[np.ndarray], b: List[np.ndarray]) -> bool:
    return (len(a) == len(b)
            and all(np.array_equal(x, y) for x, y in zip(a, b)))


def _prepare_case(probe: str, *, tiny: bool, blocks: int, k: int) -> Dict:
    """Build one configuration's drain: ``probe`` in
    ``{"none", "on", "off"}``.  Returns the runtime plus a ``timed()``
    closure that replays the identical seeded drain and returns its
    wall; the caller interleaves ``timed()`` calls across configs so
    machine drift lands on all of them equally."""
    n_nodes = 20_000 if tiny else 200_000
    rt = fig9_dag._make_runtime()
    if probe == "on":
        rt.attach_phase_probe(calibrate_every=512)
    elif probe == "off":
        rt.attach_phase_probe().enabled = False
    body = fig9_dag._device_body(n_nodes, fig9_dag.DEVICE_BATCH, rt.ops)
    rt.push(0, jnp.zeros((1,), jnp.int32), 1)
    carry0 = jnp.zeros((fig9_dag.DEVICE_WORKERS,), jnp.int32)
    for _ in range(6):
        carry0, _ = rt.round(body, carry0)
    seeded = jax.tree_util.tree_map(lambda x: x.copy(), rt.queues)
    p_seeded = rt.proportion
    # Warm outside timing: compiles the fused block, and (probe on) runs
    # the one-time calibration + prefix-program compilation.
    rt.run_fused(k, body, carry0)

    case: Dict = {"rt": rt, "fingerprint": None, "wall_s": float("inf")}
    state: Dict = {}

    def start() -> None:
        rt.queues = jax.tree_util.tree_map(lambda x: x.copy(), seeded)
        rt.controller.proportion = p_seeded
        state.update(carry=carry0, acc=0.0)

    def step() -> None:
        # Time ONE fused block; the caller rotates step() across configs
        # so every config samples the same machine phases (reps are paid
        # as a sum of individually-fenced blocks — run_fused syncs on
        # its telemetry read-back anyway, so the fence adds nothing).
        t0 = time.perf_counter()
        carry, _ = rt.run_fused(k, body, state["carry"])
        jax.block_until_ready((rt.queues.size, carry))
        state["acc"] += time.perf_counter() - t0
        state["carry"] = carry

    def finish() -> float:
        case["fingerprint"] = _fingerprint(rt, state["carry"])
        return state["acc"]

    case.update(start=start, step=step, finish=finish)
    return case


def run(tiny: bool = True) -> Tuple[Table, Dict]:
    # A long timed region (blocks x k rounds) keeps host-clock noise well
    # under the 5 % budget the gate adjudicates; the interleaving below
    # handles slow drift between repeats.
    blocks = 12
    k = fig9_dag.FUSED_K
    repeats = 16 if tiny else 24
    cases = {probe: _prepare_case(probe, tiny=tiny, blocks=blocks, k=k)
             for probe in ("none", "on", "off")}
    # Interleave at BLOCK granularity so slow machine phases (thermal,
    # noisy CI neighbors) hit every config equally within a repeat —
    # rep-level rotation still lets one config monopolize a fast window.
    # The gated overhead is the MEDIAN of per-rep PAIRED ratios: noise
    # spikes land on both configs of a rep, so the ratio stays honest
    # where best-of-walls across configs would compare different machine
    # phases.
    ratios = []
    for _ in range(repeats):
        for case in cases.values():
            case["start"]()
        for _ in range(blocks):
            for case in cases.values():
                case["step"]()
        rep = {name: case["finish"]() for name, case in cases.items()}
        for name, case in cases.items():
            case["wall_s"] = min(case["wall_s"], rep[name])
        ratios.append(rep["on"] / max(rep["none"], 1e-12))
    for case in cases.values():
        rt = case["rt"]
        case["compile_count"] = compile_count(rt)
        case["probe_programs"] = len(rt._probe_compiled)
        case["phase_summary"] = rt.telemetry.phase_summary()
    base, probed, off = cases["none"], cases["on"], cases["off"]

    overhead = statistics.median(ratios)
    identical_on = _same(base["fingerprint"], probed["fingerprint"])
    identical_off = _same(base["fingerprint"], off["fingerprint"])
    compiles_equal = off["compile_count"] == base["compile_count"]

    gates = {
        "overhead_lt_5pct": overhead < OVERHEAD_LIMIT,
        "probed_bit_identical": identical_on,
        "off_bit_identical": identical_off,
        "off_compile_count_equal": compiles_equal,
        "off_zero_probe_programs": off["probe_programs"] == 0,
        "probed_rounds_attributed":
            probed["phase_summary"]["timed_rounds"] > 0,
    }

    t = Table(f"Observability overhead: {blocks}x run_fused({k}) on the "
              f"{'tiny ' if tiny else ''}Fig. 9 drain",
              "config", ["wall ms", "jit programs", "probe programs",
                         "attributed rounds"])
    for label, case in (("no probe", base), ("probe on", probed),
                        ("attached, disabled", off)):
        t.add(label, [case["wall_s"] * 1e3, case["compile_count"],
                      case["probe_programs"],
                      case["phase_summary"]["timed_rounds"]])

    data = {
        "blocks": blocks, "k": k, "repeats": repeats,
        "baseline_wall_s": base["wall_s"],
        "probed_wall_s": probed["wall_s"],
        "off_wall_s": off["wall_s"],
        "probe_overhead": overhead,
        "probe_overhead_best": probed["wall_s"] / max(base["wall_s"], 1e-12),
        "paired_ratios": [round(r, 4) for r in ratios],
        "overhead_limit": OVERHEAD_LIMIT,
        "baseline_compile_count": base["compile_count"],
        "off_compile_count": off["compile_count"],
        "off_probe_programs": off["probe_programs"],
        "gates": gates,
        "gates_ok": all(gates.values()),
        "probed_phase_summary": probed["phase_summary"],
    }
    return t, data


# ---------------------------------------------------------------------------
# Per-phase breakdown across execution modes
# ---------------------------------------------------------------------------


def _summarize(rt) -> Dict:
    ps = rt.telemetry.phase_summary()
    out = {"timed_rounds": ps["timed_rounds"],
           "estimated_rounds": ps.get("estimated_rounds", 0),
           "wall_s": ps.get("wall_s", 0.0)}
    out["phases"] = {name: {"mean_s": agg["mean_s"],
                            "fraction": agg["fraction"]}
                     for name, agg in ps.get("phases", {}).items()}
    return out


def phase_breakdown(tiny: bool = True, *, with_mesh: bool = True
                    ) -> Tuple[Table, Dict]:
    """Per-phase wall-clock split of the Fig. 9 drain in three modes:
    unfused host-driven ``round()`` calls (direct fence-bounded
    measurement), fused vmap blocks (calibrated estimate), and — when
    enough devices are visible — fused blocks on a real device mesh.
    ``benchmarks/run.py --obs`` claims the fake host devices before jax
    initializes, exactly like ``--mesh``."""
    n_nodes = 20_000 if tiny else 200_000
    rounds = 12
    k = fig9_dag.FUSED_K
    data: Dict[str, Dict] = {}

    def seed_and_warm(rt, body):
        rt.push(0, jnp.zeros((1,), jnp.int32), 1)
        carry = jnp.zeros((rt.n_workers,), jnp.int32)
        for _ in range(6):
            carry, _ = rt.round(body, carry)
        return carry

    # host: per-round dispatches, direct measurement
    rt = fig9_dag._make_runtime()
    body = fig9_dag._device_body(n_nodes, fig9_dag.DEVICE_BATCH, rt.ops)
    carry = seed_and_warm(rt, body)
    rt.attach_phase_probe()
    for _ in range(rounds):
        carry, _ = rt.round(body, carry)
    data["host_round"] = _summarize(rt)

    # vmap fused: whole-block wall split by calibrated fractions
    rt = fig9_dag._make_runtime()
    body = fig9_dag._device_body(n_nodes, fig9_dag.DEVICE_BATCH, rt.ops)
    carry = seed_and_warm(rt, body)
    rt.attach_phase_probe(calibrate_every=512)
    for _ in range(max(rounds // k, 2)):
        carry, _ = rt.run_fused(k, body, carry)
    data["vmap_fused"] = _summarize(rt)

    # mesh fused: same drain, one lane per device under shard_map
    if with_mesh and len(jax.devices()) >= fig9_dag.DEVICE_WORKERS:
        from repro.distributed.launch import launch_runtime

        pol = fig9_dag._make_runtime().policy
        rt = launch_runtime(fig9_dag.DEVICE_WORKERS,
                            fig9_dag.DEVICE_CAPACITY, fig9_dag.SPEC,
                            execution="mesh", policy=pol,
                            max_pop=fig9_dag.DEVICE_BATCH)
        body = fig9_dag._device_body(n_nodes, fig9_dag.DEVICE_BATCH, rt.ops)
        carry = seed_and_warm(rt, body)
        rt.attach_phase_probe(calibrate_every=512)
        for _ in range(max(rounds // k, 2)):
            carry, _ = rt.run_fused(k, body, carry)
        data["mesh_fused"] = _summarize(rt)

    t = Table(f"Per-phase wall split ({n_nodes:,}-node drain)",
              "mode", ["rounds", "worker_body", "exchange", "splice",
                       "adaptive"])
    for mode, d in data.items():
        fr = d["phases"]
        t.add(mode, [d["timed_rounds"]]
              + [f"{fr[p]['fraction']:.0%}" if p in fr else "-"
                 for p in ("worker_body", "exchange", "splice",
                           "adaptive_update")])
    return t, data
