"""The paper's application end-to-end: master-worker parallel DD
branch-and-bound.  Reports supersteps / explored / transferred / balance
across worker counts (the vmapped SPMD run executes on one device here,
so the machine-independent metrics are the content — like Fig. 9)."""

from __future__ import annotations

import time

from benchmarks.common import Table
from repro.core.dd.knapsack import dp_solve, random_instance
from repro.core.dd.parallel import parallel_solve


def run() -> Table:
    t = Table("Parallel DD branch-and-bound (knapsack n=18)",
              "workers", ["opt ok", "supersteps", "explored", "transferred",
                          "balance min/max", "wall s"])
    inst = random_instance(18, seed=3)
    expect = dp_solve(inst)
    for w in (1, 2, 4, 8, 16):
        t0 = time.perf_counter()
        got, stats = parallel_solve(inst, n_workers=w, explore_width=8,
                                    batch=4)
        dt = time.perf_counter() - t0
        per = stats["per_worker_explored"]
        t.add(w, ["Y" if got == expect else "N", stats["supersteps"],
                  stats["explored"], stats["transferred"],
                  f"{min(per)}/{max(per)}", f"{dt:.1f}"])
    return t


if __name__ == "__main__":
    run().show()
