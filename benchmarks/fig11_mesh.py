"""Fig. 11 — vmap-lane vs shard_map (mesh) executor, wall per round.

PR 5's question: what does running the FULL fused round loop under
``shard_map`` (one queue lane per device, collectives on a real mesh
axis, the round loop device-resident) cost or save versus the vmapped
lane simulation on one device, at identical work?  Both executors come
from ``repro.distributed.launch_runtime`` and run the same round body,
so the gap is pure execution-mode overhead (per-device dispatch,
cross-device collective latency) — on this CPU container the "devices"
are fake host devices, so the absolute numbers are a smoke reading; the
machine-independent content is the parity column (the two modes must
report IDENTICAL transfer telemetry and final queue states, asserted
per cell).

Every timed block replays the same seeded transferring state (the
Fig. 10 reset methodology): every 8th lane holds half its ring, so each
``run_fused(ROUNDS)`` block plans real transfers.

NOTE: the worker-mesh needs one device per lane, so this benchmark must
force fake host devices BEFORE jax initializes — ``run.py --mesh`` does
that, as does running this module directly; importing it into an
already-initialized process skips the cells that don't fit.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

WORKERS = (8, 16, 64)
TINY_WORKERS = (4, 8)
ROUNDS = 4


def force_host_devices(n: int) -> None:
    """Best-effort: fake ``n`` host devices.  Only effective before jax
    initializes (call it before anything imports jax)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


if __name__ == "__main__":  # direct run: claim devices before jax loads
    force_host_devices(max(WORKERS))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import Table  # noqa: E402
from repro.core import ops as bulk_ops  # noqa: E402
from repro.core.policy import StealPolicy  # noqa: E402
from repro.distributed import launch_runtime  # noqa: E402

SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _seeded_state(n_workers: int, capacity: int) -> bulk_ops.QueueState:
    """Every 8th lane holds half its ring (distinct payloads), the rest
    empty — sustained transfers for ROUNDS (same as fig10)."""
    heavy = capacity // 2
    lane = jnp.arange(n_workers, dtype=jnp.int32)[:, None]
    buf = lane * capacity + jnp.arange(capacity, dtype=jnp.int32)[None, :] + 1
    sizes = jnp.where(lane[:, 0] % 8 == 0, jnp.int32(heavy), jnp.int32(0))
    return bulk_ops.QueueState(
        buf=buf, lo=jnp.zeros((n_workers,), jnp.int32), size=sizes)


def _bench_mode(mode: str, n_workers: int, max_steal: int,
                repeats: int) -> Dict:
    capacity = 4 * max_steal
    pol = StealPolicy(proportion=0.5, low_watermark=2,
                      high_watermark=max_steal // 2, max_steal=max_steal)
    rt = launch_runtime(n_workers, capacity, SPEC, execution=mode,
                        policy=pol, adaptive=False)
    seeded = _seeded_state(n_workers, capacity)
    if mode == "mesh":
        seeded = jax.device_put(seeded, rt.sharding)

    def reset():
        rt.queues = jax.tree_util.tree_map(lambda x: x.copy(), seeded)

    reset()
    rt.run_fused(ROUNDS)  # compile + counters outside timing
    transferred = sum(r.n_transferred for r in rt.telemetry.rounds)
    bytes_moved = sum(r.bytes_moved for r in rt.telemetry.rounds)
    assert transferred > 0, "fig11 workload must transfer every block"
    final_sizes = np.asarray(rt.queues.size).tolist()

    best = float("inf")
    for _ in range(repeats):
        reset()
        t0 = time.perf_counter()
        rt.run_fused(ROUNDS)
        jax.block_until_ready(rt.queues.size)
        best = min(best, time.perf_counter() - t0)
    return {
        "mode": mode,
        "workers": n_workers,
        "max_steal": max_steal,
        "rounds": ROUNDS,
        "wall_per_round_ms": best / ROUNDS * 1e3,
        "transferred_per_block": transferred,
        "bytes_moved_per_block": bytes_moved,
        "final_sizes": final_sizes,
    }


def run(tiny: bool = False, repeats: int | None = None
        ) -> Tuple[Table, Dict]:
    workers = TINY_WORKERS if tiny else WORKERS
    max_steal = 32 if tiny else 64
    repeats = repeats or (2 if tiny else 3)
    have = jax.device_count()

    rows: List[Dict] = []
    skipped: List[int] = []
    parity = True
    t = Table(f"Fig. 11: vmap-lane vs shard_map executor "
              f"({ROUNDS} transferring rounds per fused block, "
              f"min of {repeats}; {have} devices visible)",
              "W", ["vmap ms/rd", "mesh ms/rd", "mesh/vmap",
                    "moved/block", "parity"])
    for w in workers:
        if have < w:
            skipped.append(w)
            t.add(str(w), ["-", "-", "-", "-",
                           f"skipped ({have} devices < {w})"])
            continue
        cell = {m: _bench_mode(m, w, max_steal, repeats)
                for m in ("vmap", "mesh")}
        v, m = cell["vmap"], cell["mesh"]
        ok = (v["transferred_per_block"] == m["transferred_per_block"]
              and v["bytes_moved_per_block"] == m["bytes_moved_per_block"]
              and v["final_sizes"] == m["final_sizes"])
        parity = parity and ok
        rows.extend(cell.values())
        ratio = m["wall_per_round_ms"] / max(v["wall_per_round_ms"], 1e-9)
        t.add(str(w),
              [f"{v['wall_per_round_ms']:.2f}",
               f"{m['wall_per_round_ms']:.2f}",
               f"{ratio:.2f}x",
               v["transferred_per_block"],
               "ok" if ok else "MISMATCH"])
    data = {
        "workers": list(workers),
        "max_steal": max_steal,
        "rounds": ROUNDS,
        "repeats": repeats,
        "devices_visible": have,
        "skipped_workers": skipped,
        "cells": rows,
        # machine-independent acceptance: identical telemetry + final
        # queue sizes between the two execution modes, in EVERY cell —
        # a skipped cell (too few devices) fails the gate rather than
        # passing it vacuously.
        "mesh_matches_vmap": parity and not skipped,
    }
    return t, data


if __name__ == "__main__":
    run()[0].show()
