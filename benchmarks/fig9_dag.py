"""Figs. 9/10 — DAG-exploration pseudo workload.

Paper setup: workers with private queues explore a large DAG; outgoing
nodes are pushed to the worker's queue; an empty worker steals half from
a victim chosen by worker id; an atomic flag enforces one concurrent
stealer per queue.  Both implementations scale linearly to 128 threads.

THIS CONTAINER HAS 1 CPU CORE (and the GIL), so wall-clock thread scaling
is not reproducible here; we report (a) wall time for the work-stealing
run vs the per-item baseline at each worker count (same total work), and
(b) the algorithmic counters — steals, bulk-moved nodes, per-worker
explored balance — which are the machine-independent content of Fig. 9.
Graph sizes are scaled from the paper's (2.5M, 300M) to (100k, 1M) to
keep the harness fast; the generator is O(1)-memory (children are
computed, not stored).
"""

from __future__ import annotations

import threading
import time
from typing import List

from benchmarks.common import Table
from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                   llist_from_iter)

SIZES = (100_000, 1_000_000)
WORKERS = (1, 2, 4, 8)
FANOUT = 4


def _children(node: int, n_nodes: int) -> List[int]:
    base = node * FANOUT + 1
    return [c for c in range(base, base + FANOUT) if c < n_nodes]


def explore_ws(n_nodes: int, n_workers: int):
    """Work-stealing run on LF queues (steal-half, single stealer per
    queue enforced by an atomic flag as in the paper)."""
    queues = [LinkedWSQueue() for _ in range(n_workers)]
    flags = [threading.Lock() for _ in range(n_workers)]  # stealer flag
    explored = [0] * n_workers
    steals = [0] * n_workers
    moved = [0] * n_workers
    queues[0].push(llist_from_iter([0]))
    remaining = threading.Semaphore(0)
    done = threading.Event()
    count_lock = threading.Lock()
    total = [0]

    def worker(w: int):
        idle_spins = 0
        while not done.is_set():
            node = queues[w].pop()
            if node is None:
                # steal half from victims in id order (paper's policy)
                got = 0
                for v in range(n_workers):
                    if v == w:
                        continue
                    if flags[v].acquire(blocking=False):
                        try:
                            begin, _, cnt = queues[v].steal_optimized(0.5)
                        finally:
                            flags[v].release()
                        if cnt:
                            items = []
                            nd = begin
                            while nd is not None:
                                items.append(nd.payload)
                                nd = nd.next
                            queues[w].push(llist_from_iter(items))
                            steals[w] += 1
                            moved[w] += cnt
                            got = cnt
                            break
                if not got:
                    idle_spins += 1
                    if idle_spins > 50:
                        time.sleep(0.0005)
                    continue
                else:
                    idle_spins = 0
                continue
            explored[w] += 1
            kids = _children(node, n_nodes)
            if kids:
                queues[w].push(llist_from_iter(kids))
            with count_lock:
                total[0] += 1
                if total[0] >= n_nodes:
                    done.set()

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return dt, sum(explored), sum(steals), sum(moved), explored


def explore_baseline(n_nodes: int) -> float:
    """Single queue, per-item ops (TF_UB-style cost structure)."""
    q = PerItemDequeQueue()
    q.push([0])
    t0 = time.perf_counter()
    seen = 0
    while seen < n_nodes:
        node = q.pop()
        if node is None:
            break
        seen += 1
        q.push(_children(node, n_nodes))
    return time.perf_counter() - t0


def run() -> Table:
    t = Table("Fig. 9/10: DAG exploration (scaled; 1-core container — see "
              "docstring)", "nodes x workers",
              ["wall s", "explored", "steals", "bulk moved",
               "balance min/max"])
    for n in SIZES:
        base = explore_baseline(n)
        t.add(f"{n:,} x per-item baseline", [f"{base:.2f}", n, 0, 0, "-"])
        for w in WORKERS:
            dt, expl, st, mv, per = explore_ws(n, w)
            bal = f"{min(per):,}/{max(per):,}"
            t.add(f"{n:,} x {w}w", [f"{dt:.2f}", expl, st, mv, bal])
    return t


if __name__ == "__main__":
    run().show()
