"""Figs. 9/10 — DAG-exploration pseudo workload.

Paper setup: workers with private queues explore a large DAG; outgoing
nodes are pushed to the worker's queue; an empty worker steals half from
a victim chosen by worker id; an atomic flag enforces one concurrent
stealer per queue.  Both implementations scale linearly to 128 threads.

THIS CONTAINER HAS 1 CPU CORE (and the GIL), so wall-clock thread scaling
is not reproducible here; we report (a) wall time for the work-stealing
run vs the per-item baseline at each worker count (same total work), and
(b) the algorithmic counters — steals, bulk-moved nodes, per-worker
explored balance — which are the machine-independent content of Fig. 9.
Graph sizes are scaled from the paper's (2.5M, 300M) to (100k, 1M) to
keep the harness fast; the generator is O(1)-memory (children are
computed, not stored).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import Table
from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                   llist_from_iter)
from repro.core.ops import BulkOps
from repro.core.policy import StealPolicy
from repro.runtime import AdaptiveConfig, StealRuntime

SIZES = (100_000, 1_000_000)
WORKERS = (1, 2, 4, 8)
FANOUT = 4


def _children(node: int, n_nodes: int) -> List[int]:
    base = node * FANOUT + 1
    return [c for c in range(base, base + FANOUT) if c < n_nodes]


def explore_ws(n_nodes: int, n_workers: int):
    """Work-stealing run on LF queues (steal-half, single stealer per
    queue enforced by an atomic flag as in the paper)."""
    queues = [LinkedWSQueue() for _ in range(n_workers)]
    flags = [threading.Lock() for _ in range(n_workers)]  # stealer flag
    explored = [0] * n_workers
    steals = [0] * n_workers
    moved = [0] * n_workers
    queues[0].push(llist_from_iter([0]))
    remaining = threading.Semaphore(0)
    done = threading.Event()
    count_lock = threading.Lock()
    total = [0]

    def worker(w: int):
        idle_spins = 0
        while not done.is_set():
            node = queues[w].pop()
            if node is None:
                # steal half from victims in id order (paper's policy)
                got = 0
                for v in range(n_workers):
                    if v == w:
                        continue
                    if flags[v].acquire(blocking=False):
                        try:
                            begin, _, cnt = queues[v].steal_optimized(0.5)
                        finally:
                            flags[v].release()
                        if cnt:
                            items = []
                            nd = begin
                            while nd is not None:
                                items.append(nd.payload)
                                nd = nd.next
                            queues[w].push(llist_from_iter(items))
                            steals[w] += 1
                            moved[w] += cnt
                            got = cnt
                            break
                if not got:
                    idle_spins += 1
                    if idle_spins > 50:
                        time.sleep(0.0005)
                    continue
                else:
                    idle_spins = 0
                continue
            explored[w] += 1
            kids = _children(node, n_nodes)
            if kids:
                queues[w].push(llist_from_iter(kids))
            with count_lock:
                total[0] += 1
                if total[0] >= n_nodes:
                    done.set()

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return dt, sum(explored), sum(steals), sum(moved), explored


def explore_baseline(n_nodes: int) -> float:
    """Single queue, per-item ops (TF_UB-style cost structure)."""
    q = PerItemDequeQueue()
    q.push([0])
    t0 = time.perf_counter()
    seen = 0
    while seen < n_nodes:
        node = q.pop()
        if node is None:
            break
        seen += 1
        q.push(_children(node, n_nodes))
    return time.perf_counter() - t0


def run() -> Table:
    t = Table("Fig. 9/10: DAG exploration (scaled; 1-core container — see "
              "docstring)", "nodes x workers",
              ["wall s", "explored", "steals", "bulk moved",
               "balance min/max"])
    for n in SIZES:
        base = explore_baseline(n)
        t.add(f"{n:,} x per-item baseline", [f"{base:.2f}", n, 0, 0, "-"])
        for w in WORKERS:
            dt, expl, st, mv, per = explore_ws(n, w)
            bal = f"{min(per):,}/{max(per):,}"
            t.add(f"{n:,} x {w}w", [f"{dt:.2f}", expl, st, mv, bal])
    return t


# ---------------------------------------------------------------------------
# Device executor: fused supersteps vs per-round dispatch on the same DAG
# ---------------------------------------------------------------------------
#
# The same exploration discipline on StealRuntime lanes: pop a bulk of
# nodes, compute children arithmetically, bulk-push, rebalance.  Timing
# compares k sequential .round() calls (one dispatch + one host sync per
# round — telemetry and the adaptive update on the host) against ONE
# .run_fused(k) dispatch (the adaptive update scanned on device,
# telemetry read back once).  The compute is identical, so the gap is
# pure dispatch + host-sync overhead — the cost the fused superstep
# pipeline removes.

DEVICE_WORKERS = 8
DEVICE_BATCH = 64
DEVICE_CAPACITY = 4096
FUSED_K = 8
SPEC = jax.ShapeDtypeStruct((), jnp.int32)


def _device_body(n_nodes: int, batch: int, ops: BulkOps):
    fanout = jnp.int32(FANOUT)

    def body(q, carry):
        q, nodes, n_popped = ops.pop_bulk(q, batch, jnp.int32(batch))
        valid = jnp.arange(batch, dtype=jnp.int32) < n_popped
        kids = (nodes[:, None] * fanout + 1
                + jnp.arange(FANOUT, dtype=jnp.int32)[None, :])
        live = valid[:, None] & (kids < n_nodes)
        flat, flive = kids.reshape(-1), live.reshape(-1)
        order = jnp.argsort(~flive, stable=True)  # compact live to front
        flat = jnp.where(flive[order], flat[order], 0)
        q, _ = ops.push(q, flat, jnp.sum(flive.astype(jnp.int32)))
        return q, carry + jnp.sum(valid.astype(jnp.int32))

    return body


def _make_runtime(backend: str = "auto", *,
                  proportion: float = 0.5,
                  adaptive: bool = True,
                  adaptive_config: AdaptiveConfig | None = None
                  ) -> StealRuntime:
    policy = StealPolicy(proportion=proportion,
                         low_watermark=DEVICE_BATCH // 2,
                         high_watermark=4 * DEVICE_BATCH, max_steal=1024)
    return StealRuntime(DEVICE_WORKERS, DEVICE_CAPACITY, SPEC,
                        policy=policy, backend=backend,
                        max_pop=DEVICE_BATCH, adaptive=adaptive,
                        adaptive_config=adaptive_config)


def device_run(k: int = FUSED_K, tiny: bool = False) -> Tuple[Table, Dict]:
    """Wall-clock of k supersteps: per-round dispatch vs one fused scan."""
    n_nodes = 20_000 if tiny else 200_000
    repeats = 3 if tiny else 10
    rt = _make_runtime()
    body = _device_body(n_nodes, DEVICE_BATCH, rt.ops)
    rt.push(0, jnp.zeros((1,), jnp.int32), 1)
    carry0 = jnp.zeros((DEVICE_WORKERS,), jnp.int32)
    # Grow the frontier so the timed region rebalances real work, then
    # snapshot the seeded state (rounds may donate their input).
    carry0, _ = rt.round(body, carry0)
    for _ in range(5):
        carry0, _ = rt.round(body, carry0)
    seeded = jax.tree_util.tree_map(lambda x: x.copy(), rt.queues)
    p_seeded = rt.proportion
    rt.run_fused(k, body, carry0)  # compile the fused scan outside timing

    def reset():
        # Restore queue AND controller state so both modes replay the
        # identical adaptive trajectory (the host and device updates are
        # the same float32 computation) — the timed gap is pure
        # dispatch + host-sync overhead, never a different transfer plan.
        rt.queues = jax.tree_util.tree_map(lambda x: x.copy(), seeded)
        rt.controller.proportion = p_seeded

    def timed(fused: bool) -> Tuple[float, int]:
        best, explored = float("inf"), 0
        for _ in range(repeats):
            reset()
            carry = carry0
            t0 = time.perf_counter()
            if fused:
                carry, _ = rt.run_fused(k, body, carry)
            else:
                for _ in range(k):
                    carry, _ = rt.round(body, carry)
            jax.block_until_ready(rt.queues.size)
            best = min(best, time.perf_counter() - t0)
            explored = int(jnp.sum(carry))
        return best, explored

    dt_round, expl_round = timed(fused=False)
    dt_fused, expl_fused = timed(fused=True)
    speedup = dt_round / max(dt_fused, 1e-12)
    t = Table(f"Fig. 9 (device): {k} supersteps on {DEVICE_WORKERS} lanes "
              f"({n_nodes:,}-node DAG, batch {DEVICE_BATCH})",
              "mode", ["wall ms", "explored", "speedup"])
    t.add(f"{k} x round()", [dt_round * 1e3, expl_round, "1.00x"])
    t.add(f"run_fused({k})", [dt_fused * 1e3, expl_fused,
                              f"{speedup:.2f}x"])
    data = {
        "k": k, "n_nodes": n_nodes, "workers": DEVICE_WORKERS,
        "per_round_ms": dt_round * 1e3, "fused_ms": dt_fused * 1e3,
        "fused_speedup": speedup,
        "explored_per_round": expl_round, "explored_fused": expl_fused,
    }
    return t, data


# ---------------------------------------------------------------------------
# Steal-proportion autotuning sweep: AdaptiveConfig vs static proportions
# ---------------------------------------------------------------------------
#
# The ROADMAP follow-on: does the adaptive controller actually beat a
# well-chosen static proportion on the DAG workload?  Each config drains
# the same DAG through the executor's fused early-exit path; the
# machine-independent figure of merit is the superstep count to drain
# (wall time tie-breaks).  The per-config trajectory is deterministic,
# so a warm (compiling) pass establishes the counters and a second pass
# from the identical seeded state is timed.

STATIC_PROPORTIONS = (0.25, 0.5, 0.75)
ADAPTIVE_GAINS = (0.25, 0.5, 1.0)
ADAPTIVE_CLAMPS = ((0.125, 0.75), (0.25, 0.6))


def _drain_config(label: str, n_nodes: int, max_rounds: int, **rt_kw):
    rt = _make_runtime(**rt_kw)
    body = _device_body(n_nodes, DEVICE_BATCH, rt.ops)
    rt.push(0, jnp.zeros((1,), jnp.int32), 1)
    seeded = jax.tree_util.tree_map(lambda x: x.copy(), rt.queues)
    p0 = rt.proportion
    carry0 = jnp.zeros((DEVICE_WORKERS,), jnp.int32)

    # warm pass: compiles, and fixes the (deterministic) round count
    carry = rt.run(body, carry0, max_rounds=max_rounds, fused=FUSED_K)
    rounds = rt.rounds_run
    explored = int(jnp.sum(carry))

    # timed pass from the identical seeded state
    rt.queues = jax.tree_util.tree_map(lambda x: x.copy(), seeded)
    if rt.controller is not None:
        rt.controller.proportion = p0
    t0 = time.perf_counter()
    rt.run(body, carry0, max_rounds=max_rounds, fused=FUSED_K)
    jax.block_until_ready(rt.queues.size)
    wall = time.perf_counter() - t0
    return {"label": label, "rounds": rounds, "explored": explored,
            "wall_s": wall, "drained": explored >= n_nodes,
            "backend": rt.ops.resolved}


def adaptive_sweep(tiny: bool = False) -> Tuple[Table, Dict]:
    """Sweep AdaptiveConfig (gain x clamp range) against static
    proportions on the DAG workload; the winner (fewest supersteps to
    drain, wall-clock tie-break) is recorded for promotion to the
    defaults."""
    n_nodes = 20_000 if tiny else 200_000
    max_rounds = 4000
    results = []
    for p in STATIC_PROPORTIONS:
        results.append(_drain_config(f"static p={p}", n_nodes, max_rounds,
                                     proportion=p, adaptive=False))
    for gain in ADAPTIVE_GAINS:
        for lo, hi in ADAPTIVE_CLAMPS:
            cfg = AdaptiveConfig(gain=gain, min_proportion=lo,
                                 max_proportion=hi)
            results.append(_drain_config(
                f"adaptive gain={gain} clamp=[{lo},{hi}]", n_nodes,
                max_rounds, adaptive=True, adaptive_config=cfg))

    complete = [r for r in results if r["drained"]] or results
    winner = min(complete, key=lambda r: (r["rounds"], r["wall_s"]))
    t = Table(f"Fig. 9 adaptive sweep: supersteps to drain a "
              f"{n_nodes:,}-node DAG ({DEVICE_WORKERS} lanes)",
              "config", ["supersteps", "explored", "wall ms", "winner"])
    for r in results:
        t.add(r["label"], [r["rounds"], r["explored"], r["wall_s"] * 1e3,
                           "<--" if r is winner else ""])
    data = {"n_nodes": n_nodes, "workers": DEVICE_WORKERS,
            "fused_k": FUSED_K, "configs": results,
            "winner": winner["label"],
            # Off-TPU a kernel-routed backend executes the kernel
            # module's jnp oracle, not Pallas — disambiguate what the
            # per-config "backend" routing actually ran (as fig6 does).
            "backend_path": ("pallas" if jax.default_backend() == "tpu"
                             else "oracle")}
    return t, data


if __name__ == "__main__":
    run().show()
    device_run()[0].show()
    adaptive_sweep()[0].show()
