"""Fig. 7 — steal latency vs proportion (10..60%) from an initial queue
of 10,000 nodes.

Paper claim: LF_Queue's steal cost is dominated by the traversal to the
cut point and stays ~flat; per-item baselines grow linearly with the
stolen count.  All columns come from the unified harness: host
implementations through the ``HostQueue`` protocol (the LF_Queue column
is the production ``steal_optimized`` variant; ``fig8`` measures
counted-vs-optimized explicitly), device ring-queue backends through
``BulkOps`` — at least ``LFQ-JAX[reference]`` and ``LFQ-JAX[auto]``
(geometry-resolved ring-gather kernel routing).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import (Table, bench_steal, device_backends,
                               host_queue_impls, time_ns)
from repro.core import ops as bulk_ops

PROPORTIONS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6)
INITIAL = 10_000
CAPACITY = 16_384
MAX_STEAL = 8192


def _bench_device(backend: str, p: float, repeats: int = 60) -> float:
    ops = bulk_ops.make_ops(backend, capacity=CAPACITY, max_steal=MAX_STEAL)
    spec = jnp.zeros((), jnp.int32)
    q0 = bulk_ops.make_queue(CAPACITY, spec)
    items = jnp.arange(INITIAL, dtype=jnp.int32)
    q0, _ = ops.push(q0, items, jnp.int32(INITIAL), donate=False)
    jax.block_until_ready(q0.size)
    steal = jax.jit(lambda q: ops.steal(q, p, max_steal=MAX_STEAL))

    def op(q):
        st, batch, n = steal(q)
        jax.block_until_ready(n)

    return time_ns(lambda: q0, op, repeats=repeats, warmup=6)


def run(tiny: bool = False) -> Tuple[Table, Dict]:
    repeats = 10 if tiny else 60

    cols: Dict[str, object] = {}
    for name, factory in host_queue_impls().items():
        cols[name] = (lambda p, f=factory:
                      bench_steal(f, p, INITIAL, repeats))
    dev_names = device_backends()
    for backend in dev_names:
        cols[f"LFQ-JAX[{backend}]"] = (
            lambda p, be=backend: _bench_device(be, p, repeats))

    t = Table(f"Fig. 7: steal latency (ns) vs proportion (initial {INITIAL})",
              "steal %", list(cols))
    data: Dict = {"proportions": list(PROPORTIONS),
                  "columns": {n: [] for n in cols},
                  "device_backends": list(dev_names)}
    for p in PROPORTIONS:
        row = []
        for name, bench in cols.items():
            ns = bench(p)
            data["columns"][name].append(ns)
            row.append(ns)
        t.add(f"{int(p*100)}%", row)
    return t, data


if __name__ == "__main__":
    run()[0].show()
