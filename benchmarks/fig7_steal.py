"""Fig. 7 — steal latency vs proportion (10..60%) from an initial queue
of 10,000 nodes.

Paper claim: LF_Queue's steal cost is dominated by the traversal to the
cut point + suffix count and stays ~flat; per-item baselines grow
linearly with the stolen count.  LFQ-JAX(dev) is the device ring gather.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import Table, time_ns
from repro.core.host_queue import (LinkedWSQueue, PerItemDequeQueue,
                                   ResizingArrayQueue, llist_from_iter)
from repro.core import queue as q_ops

PROPORTIONS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6)
INITIAL = 10_000


def _bench_host(cls, p: float, repeats: int = 60) -> float:
    items = list(range(INITIAL))

    if cls is LinkedWSQueue:
        def setup():
            q = LinkedWSQueue()
            q.push(llist_from_iter(items))
            return q

        def op(q):
            q.steal(p)
    else:
        def setup():
            q = cls() if cls is PerItemDequeQueue else cls(capacity=64)
            q.push(items)
            return q

        def op(q):
            q.steal(p)
    return time_ns(setup, op, repeats=repeats, warmup=6)


def _bench_jax(p: float, use_kernel: bool = False,
               repeats: int = 60) -> float:
    spec = jnp.zeros((), jnp.int32)
    q0 = q_ops.make_queue(16_384, spec)
    items = jnp.arange(INITIAL, dtype=jnp.int32)
    q0, _ = jax.jit(q_ops.push)(q0, items, jnp.int32(INITIAL))
    jax.block_until_ready(q0.size)
    steal = jax.jit(lambda q: q_ops.steal(q, p, max_steal=8192,
                                          use_kernel=use_kernel))

    def setup():
        return q0

    def op(q):
        st, batch, n = steal(q)
        jax.block_until_ready(n)

    return time_ns(setup, op, repeats=repeats, warmup=6)


def run(tiny: bool = False) -> Tuple[Table, Dict]:
    t = Table(f"Fig. 7: steal latency (ns) vs proportion (initial {INITIAL})",
              "steal %", ["LF_Queue", "TF_UB-style", "TF_BD-style",
                          "LFQ-JAX(dev)", "LFQ-JAX(kernel)"])
    repeats = 10 if tiny else 60
    data: Dict = {"proportions": list(PROPORTIONS), "columns": {}}
    cols = {
        "LF_Queue": lambda p: _bench_host(LinkedWSQueue, p, repeats),
        "TF_UB-style": lambda p: _bench_host(PerItemDequeQueue, p, repeats),
        "TF_BD-style": lambda p: _bench_host(ResizingArrayQueue, p, repeats),
        "LFQ-JAX(dev)": lambda p: _bench_jax(p, repeats=repeats),
        "LFQ-JAX(kernel)": lambda p: _bench_jax(p, use_kernel=True,
                                                repeats=repeats),
    }
    for name in cols:
        data["columns"][name] = []
    for p in PROPORTIONS:
        row = []
        for name, bench in cols.items():
            ns = bench(p)
            data["columns"][name].append(ns)
            row.append(ns)
        t.add(f"{int(p*100)}%", row)
    return t, data


if __name__ == "__main__":
    run()[0].show()
