"""Beyond-paper: MoE bulk-steal token rebalancing (the paper's technique
as a model feature).  Measures (a) routing-plan latency with and without
the steal and (b) drop rate under skewed routing — the quality win the
steal buys at a near-zero plan cost."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Table
from repro.models.moe import route_with_bulk_steal


def _case(T: int, E: int, k: int, skew: float):
    logits = jax.random.normal(jax.random.PRNGKey(0), (T, E))
    logits = logits.at[:, 0].add(skew)
    probs = jax.nn.softmax(logits, -1)
    capacity = max(int(T * k / E * 1.25), k)

    out = {}
    for bulk in (False, True):
        fn = jax.jit(lambda p: route_with_bulk_steal(p, k, capacity,
                                                     bulk_steal=bulk))
        e, s, w, valid = fn(probs)
        jax.block_until_ready(valid)
        t0 = time.perf_counter_ns()
        reps = 30
        for _ in range(reps):
            e, s, w, valid = fn(probs)
        jax.block_until_ready(valid)
        ns = (time.perf_counter_ns() - t0) / reps
        drop = 1.0 - float(jnp.mean(valid.astype(jnp.float32)))
        out[bulk] = (ns, drop)
    return out


def run() -> Table:
    t = Table("MoE token rebalancing: GShard drop vs bulk steal",
              "T x E x k (skew)",
              ["drop plan ns", "drop rate", "steal plan ns", "steal drop"])
    for (T, E, k, skew) in [(4096, 64, 2, 0.0), (4096, 64, 2, 3.0),
                            (16384, 128, 8, 2.0), (16384, 8, 2, 3.0)]:
        r = _case(T, E, k, skew)
        t.add(f"{T} x {E} x {k} ({skew})",
              [r[False][0], f"{r[False][1]*100:.1f}%",
               r[True][0], f"{r[True][1]*100:.1f}%"])
    return t


if __name__ == "__main__":
    run().show()
