"""End-to-end LM training driver (example application).

Default: a ~100M-param llama-family model for a few hundred steps on the
work-stealing data pipeline with checkpoint/restart — scaled so a CPU
run finishes; pass --steps/--d-model/--layers to go bigger, or use
``python -m repro.launch.train --preset full`` on a TPU mesh for the
assigned configs.

  PYTHONPATH=src python examples/train_lm.py --steps 50
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import WorkStealingPipeline
from repro.data.synthetic import synth_batch
from repro.models import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=32_000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params at the defaults (12L, d=768, v=32k: ~110M).
    cfg = dataclasses.replace(
        configs.get("llama3.2-1b"),
        name="llama-100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=args.d_model // 256,
        head_dim=64, d_ff=args.d_model * 4, vocab_size=args.vocab,
        tie_embeddings=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    pipe = WorkStealingPipeline(
        n_hosts=1,
        make_batch=lambda shard, step: synth_batch(
            0, shard, step, args.batch, args.seq, cfg.vocab_size))

    start = 0
    if ckpt_lib.latest_step(args.ckpt_dir):
        (params, opt), start, _ = ckpt_lib.restore(args.ckpt_dir,
                                                   (params, opt))
        print(f"[train_lm] resumed from step {start}")

    for step in range(start, args.steps):
        raw = pipe.next_batch(0)
        batch = {"tokens": jnp.asarray(raw["tokens"]),
                 "labels": jnp.asarray(raw["labels"])}
        params, opt, m = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if (step + 1) % 50 == 0:
            ckpt_lib.save(args.ckpt_dir, step + 1, (params, opt))
    print("[train_lm] done")


if __name__ == "__main__":
    main()
