"""The paper's application: DD-based branch-and-bound MIP solving, from
the Fig. 2 toy to a parallel master-worker run.

  PYTHONPATH=src python examples/knapsack_solver.py [--n 18] [--workers 8]
"""

import argparse
import time

import jax.numpy as jnp

from repro.core.dd.bnb import solve
from repro.core.dd.diagram import build_bounds
from repro.core.dd.knapsack import dp_solve, paper_example, random_instance
from repro.core.dd.parallel import parallel_solve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=18)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--fused", type=int, default=8,
                    help="supersteps per device dispatch "
                         "(StealRuntime.run_fused; 1 = per-round)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"],
                    help="BulkOps queue backend for every op (master "
                         "steal/splice and worker bulk pop/push)")
    args = ap.parse_args()

    # 1. the paper's running example (Eq. 1 / Figs. 2-4)
    inst = paper_example()
    primal, dual = build_bounds(
        jnp.int32(inst.capacity), jnp.int32(0), jnp.int32(0),
        jnp.asarray(inst.weights, jnp.int32),
        jnp.asarray(inst.profits, jnp.int32), width=3, n_vars=inst.n)
    print(f"[paper Eq.1] restricted(primal)={int(primal)} <= opt=15 <= "
          f"relaxed(dual)={int(dual)}   (Figs. 3/4 give 13 <= 15 <= 19)")
    opt, _ = solve(inst, width=4)
    print(f"[paper Eq.1] DD branch-and-bound optimum: {opt}")

    # 2. a bigger instance: sequential vs parallel master-worker
    inst = random_instance(args.n, seed=3)
    expect = dp_solve(inst)
    t0 = time.time()
    seq_opt, seq_stats = solve(inst, width=args.width)
    t_seq = time.time() - t0
    t0 = time.time()
    par_opt, par_stats = parallel_solve(inst, n_workers=args.workers,
                                        explore_width=args.width, batch=4,
                                        fused_rounds=args.fused,
                                        backend=args.backend)
    t_par = time.time() - t0
    print(f"[n={args.n}] DP oracle={expect}  sequential={seq_opt} "
          f"({seq_stats['explored']} explored, {t_seq:.1f}s)  "
          f"parallel={par_opt} ({par_stats['explored']} explored over "
          f"{args.workers} workers, {par_stats['supersteps']} supersteps "
          f"fused {args.fused}/dispatch, "
          f"{par_stats['transferred']} nodes bulk-stolen, "
          f"backend={par_stats['backend']}, {t_par:.1f}s)")
    print(f"per-worker explored: {par_stats['per_worker_explored']}")
    tele = par_stats["telemetry"]
    print(f"runtime telemetry: {tele['steals']} steals moved "
          f"{tele['items_transferred']} nodes "
          f"({tele['bytes_transferred']} B) over {tele['rounds']} rounds; "
          f"adaptive proportion mean={tele['proportion_mean']:.3f} "
          f"final={tele['proportion_final']:.3f}")
    assert seq_opt == expect == par_opt


if __name__ == "__main__":
    main()
