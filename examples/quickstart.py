"""Quickstart: the lock-free bulk work-stealing queue, three ways.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.host_queue import LinkedWSQueue, llist_from_iter
from repro.core.ops import make_ops, make_queue
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import make_sharded_queues, vmapped_superstep

# -- 1. the paper's queue, faithful host port (Listings 1-4) ----------------
q = LinkedWSQueue()
q.push(llist_from_iter(range(10)))        # bulk push: ONE splice
print("owner pops newest:", q.pop())       # LIFO owner side
begin, end, count = q.steal(0.5)           # master steals the tail suffix
print(f"stealer got {count} oldest nodes; {len(q)} remain")

# -- 2. the TPU-native ring queue behind a BulkOps backend --------------------
# "auto" resolves the kernel routing ONCE here, from the geometry
# predicates (Pallas ring kernels where supported, the jnp reference
# oracle elsewhere); swap "auto" for "reference" or "pallas" to pin it.
ops = make_ops("auto", capacity=64, max_push=16, max_steal=32)
print("backend:", ops, "->", ops.resolved)
state = make_queue(capacity=64, item_spec=jnp.zeros((), jnp.int32))
state, _ = ops.push(state, jnp.arange(16), jnp.int32(16), donate=True)
state, item, ok = ops.pop(state)
print("device pop:", int(item), "valid:", bool(ok))
state, batch, n = jax.jit(
    lambda s: ops.steal(s, 0.5, max_steal=32))(state)
print("device bulk steal:", int(n), "items; size now", int(state.size))

# -- 3. the virtual master: SPMD rebalancing superstep ------------------------
# The superstep resolves its own BulkOps from policy.backend at trace
# time — every consumer shares the one operation contract.
policy = StealPolicy(proportion=0.5, high_watermark=4, low_watermark=1,
                     max_steal=16, backend="auto")
qs = make_sharded_queues(4, 64, jnp.zeros((), jnp.int32))
# worker 0 overloaded, others empty
seed = jnp.arange(16, dtype=jnp.int32)[None].repeat(4, 0)
ns = jnp.asarray([16, 0, 0, 0], jnp.int32)
qs, _ = jax.vmap(lambda q, b, n: ops.push(q, b, n))(qs, seed, ns)
step = vmapped_superstep(policy)
qs2, stats = step(qs)
print("sizes before:", [int(x) for x in qs.size],
      "after one master superstep:", [int(x) for x in qs2.size])
