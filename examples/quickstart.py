"""Quickstart: the lock-free bulk work-stealing queue, three ways.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import queue as q_ops
from repro.core.host_queue import LinkedWSQueue, llist_from_iter
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import make_sharded_queues, vmapped_superstep

# -- 1. the paper's queue, faithful host port (Listings 1-4) ----------------
q = LinkedWSQueue()
q.push(llist_from_iter(range(10)))        # bulk push: ONE splice
print("owner pops newest:", q.pop())       # LIFO owner side
begin, end, count = q.steal(0.5)           # master steals the tail suffix
print(f"stealer got {count} oldest nodes; {len(q)} remain")

# -- 2. the TPU-native ring queue: pure state transitions --------------------
state = q_ops.make_queue(capacity=64, item_spec=jnp.zeros((), jnp.int32))
state, _ = jax.jit(q_ops.push)(state, jnp.arange(16), jnp.int32(16))
state, item, ok = jax.jit(q_ops.pop)(state)
print("device pop:", int(item), "valid:", bool(ok))
state, batch, n = jax.jit(
    lambda s: q_ops.steal(s, 0.5, max_steal=32))(state)
print("device bulk steal:", int(n), "items; size now", int(state.size))

# -- 3. the virtual master: SPMD rebalancing superstep ------------------------
policy = StealPolicy(proportion=0.5, high_watermark=4, low_watermark=1,
                     max_steal=16)
qs = make_sharded_queues(4, 64, jnp.zeros((), jnp.int32))
# worker 0 overloaded, others empty
seed = jnp.arange(16, dtype=jnp.int32)[None].repeat(4, 0)
ns = jnp.asarray([16, 0, 0, 0], jnp.int32)
qs, _ = jax.vmap(q_ops.push)(qs, seed, ns)
step = vmapped_superstep(policy)
qs2, stats = step(qs)
print("sizes before:", [int(x) for x in qs.size],
      "after one master superstep:", [int(x) for x in qs2.size])
