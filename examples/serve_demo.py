"""Serving example: batched requests through the bulk-steal admission
master, with a deliberate straggler replica to show rebalancing.

  PYTHONPATH=src python examples/serve_demo.py
"""

import time

import jax
import numpy as np

from repro import configs
from repro.core.policy import StealPolicy
from repro.models import build_model
from repro.serve.engine import Replica, ServeCluster
from repro.serve.scheduler import AdmissionMaster, Request


def main():
    cfg = configs.reduced(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    reps = [Replica(model, params, wave_size=4, max_seq=64)
            for _ in range(3)]
    reps[0].speed = 0.25  # replica 0 straggles
    pol = StealPolicy(proportion=0.5, low_watermark=1, high_watermark=2)
    cluster = ServeCluster(reps, AdmissionMaster(3, policy=pol))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 8)),
                    max_new=8) for _ in range(30)]
    t0 = time.time()
    cluster.submit(reqs)   # ONE bulk admission (a single splice)
    done = cluster.run_until_drained()
    st = cluster.master.stats()
    print(f"[serve_demo] {len(done)}/30 requests in {time.time()-t0:.1f}s")
    print(f"  per-replica completed: {st['completed']} (replica 0 is 4x slow)")
    print(f"  master bulk-stole {st['stolen']} requests over "
          f"{st['rounds']} rounds")
    sample = done[0]
    print(f"  sample output ({sample.rid}): {sample.output}")
    assert len(done) == 30


if __name__ == "__main__":
    main()
