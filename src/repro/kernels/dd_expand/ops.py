"""Jitted wrapper for DD layer expansion (kernel on TPU, oracle on CPU)."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dd_expand.kernel import expand, expand_supported
from repro.kernels.dd_expand.ref import expand_ref

__all__ = ["expand_layer_bulk"]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def expand_layer_bulk(states, values, w, p, *, use_pallas: bool = False,
                      interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(N,) nodes -> (2N,) children [0-arcs then 1-arcs], diagram layout."""
    if (use_pallas or interpret) and expand_supported(states.shape[0]):
        s0, v0, s1, v1 = expand(states, values, w, p,
                                interpret=interpret or
                                jax.default_backend() != "tpu")
        return jnp.concatenate([s0, s1]), jnp.concatenate([v0, v1])
    return expand_ref(states, values, w, p)
