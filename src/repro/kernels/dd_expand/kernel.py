"""DD layer expansion (bulk node generation) as a Pallas TPU kernel.

The branch-and-bound hot spot: every superstep, each worker expands a
block of DD nodes into 2x children (the "bulk generation, often more
than a hundred nodes at once" of the paper's §II.A).  Pure VPU work —
elementwise compare/select over node blocks tiled into VMEM — but
keeping it in a kernel (a) fuses the feasibility test, both arcs, and
dead-slot masking into one pass and (b) feeds the queue_steal kernel's
ring buffers without bouncing through HBM-resident temporaries.

Grid: one program per node block; outputs both arcs for the block.
The arc weight/profit arrive as scalar-prefetch args so one compiled
kernel serves every layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["expand", "expand_supported"]

NEG = -(2 ** 30)
DEFAULT_BLOCK = 256


def expand_supported(n: int, *, block: int = DEFAULT_BLOCK) -> bool:
    """Whether :func:`expand` admits this geometry.  Mirrors the block
    selection below: the node array must be a whole number of (possibly
    shrunken) blocks.  Callers use this to fall back to the jnp oracle
    instead of tripping the kernel assert."""
    block = min(block, n)
    return block > 0 and n % block == 0


def _kernel(wp_ref, s_ref, v_ref, s0_ref, v0_ref, s1_ref, v1_ref):
    w = wp_ref[0]
    p = wp_ref[1]
    s = s_ref[...]
    v = v_ref[...]
    live = s >= 0
    s0_ref[...] = jnp.where(live, s, -1)
    v0_ref[...] = jnp.where(live, v, NEG)
    feas = live & (s >= w)
    s1_ref[...] = jnp.where(feas, s - w, -1)
    v1_ref[...] = jnp.where(feas, v + p, NEG)


def expand(states: jnp.ndarray, values: jnp.ndarray, w, p, *,
           block: int = DEFAULT_BLOCK, interpret: bool = False):
    """states/values: (N,) int32; returns (s0, v0, s1, v1) each (N,)."""
    N = states.shape[0]
    assert expand_supported(N, block=block), (N, block)
    block = min(block, N)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i, wp: (i,)),
            pl.BlockSpec((block,), lambda i, wp: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i, wp: (i,)),
            pl.BlockSpec((block,), lambda i, wp: (i,)),
            pl.BlockSpec((block,), lambda i, wp: (i,)),
            pl.BlockSpec((block,), lambda i, wp: (i,)),
        ],
    )
    wp = jnp.stack([jnp.asarray(w, jnp.int32), jnp.asarray(p, jnp.int32)])
    s0, v0, s1, v1 = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32)] * 4,
        interpret=interpret,
    )(wp, states, values)
    return s0, v0, s1, v1
