"""Pure-jnp oracle for the DD layer-expansion kernel.

Mirrors core.dd.diagram.expand_layer: each live node (state >= 0) emits a
0-arc child (unchanged) and a 1-arc child (state - w, value + p) when
feasible; dead slots propagate as (-1, NEG).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

NEG = -(2 ** 30)

__all__ = ["expand_ref"]


def expand_ref(states: jnp.ndarray, values: jnp.ndarray, w, p
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """states/values: (N,) int32 -> children (2N,) int32 each
    (first N = 0-arc children, second N = 1-arc children)."""
    live = states >= 0
    s0 = jnp.where(live, states, -1)
    v0 = jnp.where(live, values, NEG)
    feas = live & (states >= w)
    s1 = jnp.where(feas, states - w, -1)
    v1 = jnp.where(feas, values + p, NEG)
    return (jnp.concatenate([s0, s1]).astype(jnp.int32),
            jnp.concatenate([v0, v1]).astype(jnp.int32))
