"""Pure-jnp oracle for the bulk ring-segment move (queue steal / push).

``ring_gather(buf, lo, n, max_steal)``: rows ``(lo + i) % cap`` for
``i < n`` (rows >= n zeroed) — exactly what ``core.queue.steal_exact``
computes for the stolen block.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ring_gather_ref"]


def ring_gather_ref(buf: jnp.ndarray, lo, n, max_steal: int) -> jnp.ndarray:
    cap = buf.shape[0]
    offs = jnp.arange(max_steal, dtype=jnp.int32)
    phys = (jnp.asarray(lo, jnp.int32) + offs) % cap
    out = buf[phys]
    live = offs < jnp.asarray(n, jnp.int32)
    return jnp.where(live.reshape((max_steal,) + (1,) * (buf.ndim - 1)),
                     out, jnp.zeros_like(out))
