"""Jitted wrapper: ring-segment gather for arbitrary payload pytrees.

Leaves are flattened to (cap, -1), moved with the Pallas kernel (TPU) or
the jnp oracle (CPU), and reshaped back.  Used by kernel-routed
``repro.core.ops.BulkOps`` backends for ``steal`` / ``steal_exact``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.queue_steal.kernel import ring_gather
from repro.kernels.queue_steal.ref import ring_gather_ref

__all__ = ["steal_gather"]


@functools.partial(jax.jit, static_argnames=("max_steal", "use_pallas",
                                             "interpret"))
def steal_gather(buf_tree, lo, n, *, max_steal: int, use_pallas: bool = False,
                 interpret: bool = False):
    """buf_tree: pytree of (cap, ...) arrays -> pytree of (max_steal, ...)."""

    def one(buf):
        shape = buf.shape
        flat = buf.reshape(shape[0], -1)
        if use_pallas or interpret:
            out = ring_gather(flat, lo, n, max_steal,
                              interpret=interpret or
                              jax.default_backend() != "tpu")
        else:
            out = ring_gather_ref(flat, lo, n, max_steal)
        return out.reshape((max_steal,) + shape[1:])

    return jax.tree_util.tree_map(one, buf_tree)
