"""Bulk ring-segment move as a Pallas TPU kernel (the steal hot path).

The paper's steal is a single-cut detach of a contiguous suffix; on TPU
the payload move is a ring-buffer segment copy HBM->HBM staged through
VMEM.  The start offset ``lo`` is DYNAMIC, so a block of the output may
straddle two aligned blocks of the ring.  TPU-native approach:

  * ``lo`` arrives via scalar prefetch (PrefetchScalarGridSpec) so the
    BlockSpec index_map can align input DMA windows to it: output block
    ``i`` reads ring blocks ``a = (lo//BS + i) % nb`` and ``(a+1) % nb``.
  * In-kernel, the two VMEM tiles are concatenated and the true segment
    is cut out with one dynamic_slice at ``r = lo % BS`` — the same
    "sever at the cut point" structure as the paper's Listing 4, executed
    as vector moves instead of pointer chasing.
  * Rows past ``n`` (the stolen count) are zero-masked so the result can
    travel through summing collectives (see core.master).

Cost: O(batch) vectorized copy, constant per item — the kernel-level
realization of the paper's flat bulk-op latency (Fig. 6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ring_gather", "ring_gather_supported", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 128


def ring_gather_supported(capacity: int, max_steal: int, *,
                          block: int = DEFAULT_BLOCK) -> bool:
    """Whether :func:`ring_gather` admits this geometry.  Mirrors the
    block selection below: the ring and the transfer buffer must both be
    whole numbers of (possibly shrunken) blocks.  Callers use this to
    fall back to the jnp oracle instead of tripping the kernel assert."""
    block = min(block, max_steal, capacity)
    return block > 0 and capacity % block == 0 and max_steal % block == 0


def _kernel(lo_ref, n_ref, a_ref, b_ref, o_ref, *, block: int, width: int):
    i = pl.program_id(0)
    r = lo_ref[0] % block
    n = n_ref[0]
    both = jnp.concatenate([a_ref[...], b_ref[...]], axis=0)  # (2*BS, W)
    seg = jax.lax.dynamic_slice(both, (r, 0), (block, width))
    row = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, width), 0)
    o_ref[...] = jnp.where(row < n, seg, jnp.zeros_like(seg))


def ring_gather(buf: jnp.ndarray, lo: jnp.ndarray, n: jnp.ndarray,
                max_steal: int, *, block: int = DEFAULT_BLOCK,
                interpret: bool = False) -> jnp.ndarray:
    """buf: (cap, W); returns (max_steal, W) = rows (lo+i) % cap, i < n.

    cap and max_steal must be multiples of ``block``.
    """
    cap, width = buf.shape
    block = min(block, max_steal, cap)
    assert cap % block == 0 and max_steal % block == 0
    nb = cap // block
    n_out = max_steal // block

    kern = functools.partial(_kernel, block=block, width=width)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_out,),
        in_specs=[
            pl.BlockSpec((block, width),
                         lambda i, lo, n: ((lo[0] // block + i) % nb, 0)),
            pl.BlockSpec((block, width),
                         lambda i, lo, n: ((lo[0] // block + i + 1) % nb, 0)),
        ],
        out_specs=pl.BlockSpec((block, width), lambda i, lo, n: (i, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((max_steal, width), buf.dtype),
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32).reshape(1),
      jnp.asarray(n, jnp.int32).reshape(1), buf, buf)
