"""Jitted wrappers: owner-side bulk ring ops for arbitrary payload pytrees.

Leaves are flattened to ``(cap, -1)`` / ``(batch, -1)``, moved with the
Pallas kernels (TPU) or the jnp oracles (elsewhere), and reshaped back.
Used by kernel-routed ``repro.core.ops.BulkOps`` backends for ``push``
and ``pop_bulk``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.queue_push.kernel import ring_scatter, ring_slice
from repro.kernels.queue_push.ref import ring_scatter_ref, ring_slice_ref

__all__ = ["push_scatter", "pop_slice"]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def push_scatter(buf_tree, batch_tree, start, n, *, use_pallas: bool = False,
                 interpret: bool = False):
    """Splice ``batch_tree[i] -> buf_tree[(start + i) % cap]`` for
    ``i < n``; returns the updated ring pytree.  The Pallas path aliases
    the ring input to the output (``input_output_aliases``) so under a
    donating caller the splice is in place, never an O(capacity) copy."""
    bsz = jax.tree_util.tree_leaves(batch_tree)[0].shape[0]
    n = jnp.minimum(jnp.asarray(n, jnp.int32), jnp.int32(bsz))

    def one(buf, batch):
        shape = buf.shape
        flat = buf.reshape(shape[0], -1)
        fbatch = batch.reshape(bsz, -1)
        if use_pallas or interpret:
            out = ring_scatter(flat, fbatch, start, n,
                               interpret=interpret or
                               jax.default_backend() != "tpu")
        else:
            out = ring_scatter_ref(flat, fbatch, start, n)
        return out.reshape(shape)

    return jax.tree_util.tree_map(one, buf_tree, batch_tree)


@functools.partial(jax.jit, static_argnames=("max_n", "use_pallas",
                                             "interpret"))
def pop_slice(buf_tree, lo, size, n, *, max_n: int, use_pallas: bool = False,
              interpret: bool = False):
    """Detach the newest ``n`` rows (``n`` pre-clamped to ``size``):
    pytree of ``(cap, ...)`` arrays -> pytree of ``(max_n, ...)`` with
    rows >= ``n`` zeroed, oldest of the block first."""

    def one(buf):
        shape = buf.shape
        flat = buf.reshape(shape[0], -1)
        if use_pallas or interpret:
            out = ring_slice(flat, lo, size, n, max_n,
                             interpret=interpret or
                             jax.default_backend() != "tpu")
        else:
            out = ring_slice_ref(flat, lo, size, n, max_n)
        return out.reshape((max_n,) + shape[1:])

    return jax.tree_util.tree_map(one, buf_tree)
