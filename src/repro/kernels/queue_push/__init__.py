"""Owner-side bulk ring kernels: push splice + pop_bulk detach."""

from repro.kernels.queue_push.kernel import (DEFAULT_BLOCK, ring_scatter,
                                             ring_scatter_supported,
                                             ring_slice,
                                             ring_slice_supported)
from repro.kernels.queue_push.ops import pop_slice, push_scatter

__all__ = [
    "DEFAULT_BLOCK",
    "ring_scatter",
    "ring_scatter_supported",
    "ring_slice",
    "ring_slice_supported",
    "push_scatter",
    "pop_slice",
]
