"""Owner-side bulk ring ops as Pallas TPU kernels (push / pop hot path).

The paper's bulk push is a single splice of a pre-linked batch at the
owner end; its bulk pop detaches the newest suffix.  On the TPU ring
queue both are ring-buffer segment moves with a DYNAMIC cut point, the
mirror image of the steal-side gather (``kernels.queue_steal``):

``ring_scatter`` (push)
    Splices ``batch[i] -> buf[(start + i) % cap]`` for ``i < n`` with
    ``start = lo + size``.  The ring buffer is updated IN PLACE via
    ``input_output_aliases`` and the grid visits only the blocks the
    splice touches — cost is O(batch), constant per item and flat in the
    batch size (Fig. 6), never O(capacity).  Each output block straddles
    at most two aligned batch blocks; the true segment is cut out with
    one ``dynamic_slice`` at ``block - start % block`` and non-spliced
    rows pass the old ring contents through (read-modify-write of the
    aliased block).

``ring_slice`` (pop_bulk)
    Detaches the newest ``n`` rows, i.e. rows ``(lo + size - n + i) %
    cap`` for ``i < n`` (rows >= n zero-masked).  Structurally the
    steal-side gather with the cut at the OWNER end: the start offset is
    derived from three prefetched scalars (``lo``, ``size``, ``n``)
    inside the BlockSpec index maps, so the whole segment move is one
    kernel with no host-side cursor arithmetic.

Scalar cursors arrive via ``PrefetchScalarGridSpec`` so the input DMA
windows align to the dynamic cut before the kernel body runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "ring_scatter",
    "ring_scatter_supported",
    "ring_slice",
    "ring_slice_supported",
    "DEFAULT_BLOCK",
]

DEFAULT_BLOCK = 128


# ---------------------------------------------------------------------------
# ring_scatter: bulk push splice
# ---------------------------------------------------------------------------


def ring_scatter_supported(capacity: int, max_push: int, *,
                           block: int = DEFAULT_BLOCK) -> bool:
    """Whether :func:`ring_scatter` admits this geometry.  Mirrors the
    block selection below; additionally the splice span (``max_push``
    plus one straddle block) must not lap the ring, so every grid step
    writes a DISTINCT ring block (the in-place splice would otherwise
    read a block another step already rewrote)."""
    block = min(block, max_push, capacity)
    return (block > 0 and capacity % block == 0 and max_push % block == 0
            and max_push + block <= capacity)


def _scatter_kernel(start_ref, n_ref, prev_ref, cur_ref, buf_ref, o_ref, *,
                    block: int, width: int, max_push: int):
    i = pl.program_id(0)
    r = start_ref[0] % block
    n = jnp.minimum(n_ref[0], max_push)
    # Batch rows i*block - r + k, k in [0, block): cut one aligned window
    # out of the two candidate batch blocks.
    both = jnp.concatenate([prev_ref[...], cur_ref[...]], axis=0)
    vals = jax.lax.dynamic_slice(both, (block - r, 0), (block, width))
    off = (i * block - r
           + jax.lax.broadcasted_iota(jnp.int32, (block, width), 0))
    live = (off >= 0) & (off < n)
    # Read-modify-write: rows outside the splice keep the old ring
    # contents (the output aliases the ring buffer input).
    o_ref[...] = jnp.where(live, vals, buf_ref[...])


def ring_scatter(buf: jnp.ndarray, batch: jnp.ndarray, start: jnp.ndarray,
                 n: jnp.ndarray, *, block: int = DEFAULT_BLOCK,
                 interpret: bool = False) -> jnp.ndarray:
    """buf: (cap, W), batch: (max_push, W); returns buf with rows
    ``(start + i) % cap = batch[i]`` for ``i < n``.

    Geometry must satisfy :func:`ring_scatter_supported`; the ring
    buffer argument is donated to the output (in-place splice).
    """
    cap, width = buf.shape
    max_push = batch.shape[0]
    block = min(block, max_push, cap)
    assert ring_scatter_supported(cap, max_push, block=block)
    nb = cap // block
    bb = max_push // block

    kern = functools.partial(_scatter_kernel, block=block, width=width,
                             max_push=max_push)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # bb batch blocks land on bb + 1 ring blocks (dynamic straddle).
        grid=(bb + 1,),
        in_specs=[
            pl.BlockSpec((block, width),
                         lambda i, s, n: ((i - 1) % bb, 0)),
            pl.BlockSpec((block, width),
                         lambda i, s, n: (i % bb, 0)),
            pl.BlockSpec((block, width),
                         lambda i, s, n: ((s[0] // block + i) % nb, 0)),
        ],
        out_specs=pl.BlockSpec((block, width),
                               lambda i, s, n: ((s[0] // block + i) % nb, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap, width), buf.dtype),
        # Inputs count scalar-prefetch args first: buf is operand 4.
        input_output_aliases={4: 0},
        interpret=interpret,
    )(jnp.asarray(start, jnp.int32).reshape(1),
      jnp.asarray(n, jnp.int32).reshape(1), batch, batch, buf)


# ---------------------------------------------------------------------------
# ring_slice: bulk pop detach
# ---------------------------------------------------------------------------


def ring_slice_supported(capacity: int, max_n: int, *,
                         block: int = DEFAULT_BLOCK) -> bool:
    """Whether :func:`ring_slice` admits this geometry (same tiling rule
    as the steal-side gather: ring and transfer buffer must be whole
    numbers of possibly-shrunken blocks)."""
    block = min(block, max_n, capacity)
    return block > 0 and capacity % block == 0 and max_n % block == 0


def _slice_kernel(lo_ref, size_ref, n_ref, a_ref, b_ref, o_ref, *,
                  block: int, width: int, cap: int):
    i = pl.program_id(0)
    n = n_ref[0]
    start = (lo_ref[0] + size_ref[0] - n) % cap
    r = start % block
    both = jnp.concatenate([a_ref[...], b_ref[...]], axis=0)
    seg = jax.lax.dynamic_slice(both, (r, 0), (block, width))
    row = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, width), 0)
    o_ref[...] = jnp.where(row < n, seg, jnp.zeros_like(seg))


def ring_slice(buf: jnp.ndarray, lo: jnp.ndarray, size: jnp.ndarray,
               n: jnp.ndarray, max_n: int, *, block: int = DEFAULT_BLOCK,
               interpret: bool = False) -> jnp.ndarray:
    """buf: (cap, W); returns (max_n, W) = the newest ``n`` rows in queue
    order (oldest of the block first), rows >= ``n`` zeroed.  ``n`` must
    already be clamped to ``size``."""
    cap, width = buf.shape
    block = min(block, max_n, cap)
    assert ring_slice_supported(cap, max_n, block=block)
    nb = cap // block
    n_out = max_n // block

    def _start_block(lo, size, n):
        return ((lo[0] + size[0] - n[0]) % cap) // block

    kern = functools.partial(_slice_kernel, block=block, width=width,
                             cap=cap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_out,),
        in_specs=[
            pl.BlockSpec((block, width),
                         lambda i, lo, sz, n:
                         ((_start_block(lo, sz, n) + i) % nb, 0)),
            pl.BlockSpec((block, width),
                         lambda i, lo, sz, n:
                         ((_start_block(lo, sz, n) + i + 1) % nb, 0)),
        ],
        out_specs=pl.BlockSpec((block, width), lambda i, lo, sz, n: (i, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((max_n, width), buf.dtype),
        interpret=interpret,
    )(jnp.asarray(lo, jnp.int32).reshape(1),
      jnp.asarray(size, jnp.int32).reshape(1),
      jnp.asarray(n, jnp.int32).reshape(1), buf, buf)
