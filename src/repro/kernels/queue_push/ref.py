"""Pure-jnp oracles for the owner-side bulk ring ops (push / pop_bulk).

``ring_scatter_ref(buf, batch, start, n)``: splice rows ``batch[i]`` into
``buf[(start + i) % cap]`` for ``i < n`` — exactly the masked ring-scatter
``core.queue.push`` performs at ``start = lo + size``.

``ring_slice_ref(buf, lo, size, n, max_n)``: rows
``(lo + size - n + i) % cap`` for ``i < n`` (rows >= n zeroed) — the
newest-``n`` block ``core.queue.pop_bulk`` detaches, oldest-of-block
first.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ring_scatter_ref", "ring_slice_ref"]


def ring_scatter_ref(buf: jnp.ndarray, batch: jnp.ndarray, start, n
                     ) -> jnp.ndarray:
    """``n`` must be pre-clamped to ``batch.shape[0]`` (ops.py does)."""
    cap = buf.shape[0]
    bsz = batch.shape[0]
    # Mirror the kernel's structure — a read-modify-write over the static
    # ring (one gather + select, O(capacity) regardless of batch size) —
    # rather than an XLA scatter, whose CPU lowering is per-row and would
    # make the oracle's latency grow with the batch.
    off = (jnp.arange(cap, dtype=jnp.int32)
           - jnp.asarray(start, jnp.int32)) % cap
    live = off < jnp.asarray(n, jnp.int32)
    vals = batch[jnp.minimum(off, bsz - 1)]
    return jnp.where(live.reshape((cap,) + (1,) * (buf.ndim - 1)),
                     vals, buf)


def ring_slice_ref(buf: jnp.ndarray, lo, size, n, max_n: int) -> jnp.ndarray:
    cap = buf.shape[0]
    start = (jnp.asarray(lo, jnp.int32) + jnp.asarray(size, jnp.int32)
             - jnp.asarray(n, jnp.int32)) % cap
    offs = jnp.arange(max_n, dtype=jnp.int32)
    phys = (start + offs) % cap
    out = buf[phys]
    live = offs < jnp.asarray(n, jnp.int32)
    return jnp.where(live.reshape((max_n,) + (1,) * (buf.ndim - 1)),
                     out, jnp.zeros_like(out))
