"""Jitted public wrapper for the flash-attention kernel.

``mha(q, k, v, ...)`` takes model-layout tensors (B, S, H, hd) /
(B, T, K, hd), expands GQA KV heads, transposes to the kernel layout, and
dispatches to the Pallas kernel (TPU) or the jnp oracle (CPU and any
platform without Mosaic).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    flash_attention,
    flash_attention_supported,
)
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["mha"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "use_pallas", "interpret"))
def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: Optional[int] = None,
        softcap: Optional[float] = None, use_pallas: bool = False,
        interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, T, K, hd) with H % K == 0.

    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    T = k.shape[1]
    if not ((use_pallas or interpret) and flash_attention_supported(S, T)):
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    qt = jnp.moveaxis(q, 2, 1)  # (B, H, S, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap,
                          interpret=interpret or not _on_tpu())
    return jnp.moveaxis(out, 1, 2)
