"""Pure-jnp oracle for the flash-attention kernel.

Plain softmax attention over flat heads with optional causal mask,
sliding window, and gemma2-style logit softcap — numerically the target
the Pallas kernel must match (fp32 softmax).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, T, H, hd) (KV already expanded to H).

    Returns (B, S, H, hd) in q.dtype.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    logits = logits / jnp.sqrt(hd)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    q_pos = jnp.arange(S)[:, None] + (T - S)  # right-aligned queries
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    logits = jnp.where(ok[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
