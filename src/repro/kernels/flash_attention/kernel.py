"""Flash attention as a Pallas TPU kernel.

TPU adaptation (vs. the CUDA flash-attention algorithm): the grid's last
dimension executes SEQUENTIALLY on a TensorCore, so instead of a per-CTA
inner loop, the KV-block loop IS the last grid dimension and the running
(m, l, acc) softmax state lives in VMEM scratch that persists across those
sequential grid steps.  Q/K/V blocks are tiled into VMEM by BlockSpecs
with MXU-aligned tiles (block sizes multiples of 128 on the matmul dims);
the (BQ, BK) logits tile never leaves VMEM.

Layout: q (B, H, S, hd), k/v (B, H, T, hd) — heads flattened into the
grid; causal / sliding-window masking and gemma2 softcap fused in-kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention", "flash_attention_supported",
           "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_K"]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def flash_attention_supported(S: int, T: int, *,
                              block_q: int = DEFAULT_BLOCK_Q,
                              block_k: int = DEFAULT_BLOCK_K) -> bool:
    """Whether :func:`flash_attention` admits this geometry.  Mirrors the
    block selection below: both sequence lengths must be whole numbers of
    (possibly shrunken) blocks.  Callers use this to fall back to the jnp
    oracle instead of tripping the kernel assert."""
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    return (block_q > 0 and block_k > 0
            and S % block_q == 0 and T % block_k == 0)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], block_q: int, block_k: int,
            t_offset: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (BQ, BK)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap

    # positions: queries right-aligned at t_offset (t_offset = T - S).
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + t_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    logits = jnp.where(ok, logits, _NEG_INF)

    m_prev = m_scr[...]                          # (BQ, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, H, T, hd).  Returns (B, H, S, hd)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert flash_attention_supported(
        S, T, block_q=block_q, block_k=block_k), (S, T, block_q, block_k)
    n_q, n_kv = S // block_q, T // block_k
    scale = 1.0 / (hd ** 0.5)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, t_offset=T - S, n_kv=n_kv)

    return pl.pallas_call(
        kern,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=_scratch(block_q, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(block_q: int, hd: int):
    """Running (m, l) + fp32 accumulator, persisted in VMEM across the
    sequential KV grid steps."""
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, hd), jnp.float32),
    ]
