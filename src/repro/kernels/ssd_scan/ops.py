"""Jitted wrapper dispatching model-layout SSD to the Pallas kernel.

Model layout: x (B, S, nh, hd), dt (B, S, nh), A (nh,), Bm/Cm (B, S, ns),
D (nh,) — flattened to (B*nh, ...) for the kernel grid; Bm/Cm broadcast
over heads.  CPU path uses the jnp reference (models.ssm.ssd_chunked).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan, ssd_scan_supported

__all__ = ["ssd"]


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def ssd(x, dt, A, Bm, Cm, D, *, chunk: int, use_pallas: bool = False,
        interpret: bool = False):
    """Returns (y (B, S, nh, hd), final_state (B, nh, hd, ns))."""
    if not ((use_pallas or interpret)
            and ssd_scan_supported(x.shape[1], chunk)):
        from repro.models.ssm import ssd_chunked

        return ssd_chunked(x, dt, A, Bm, Cm, D, chunk)

    B, S, nh, hd = x.shape
    ns = Bm.shape[-1]
    xf = jnp.moveaxis(x, 2, 1).reshape(B * nh, S, hd)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(B * nh, S)
    Bf = jnp.broadcast_to(Bm[:, None], (B, nh, S, ns)).reshape(B * nh, S, ns)
    Cf = jnp.broadcast_to(Cm[:, None], (B, nh, S, ns)).reshape(B * nh, S, ns)
    af = jnp.tile(A, B)
    Df = jnp.tile(D, B)
    y, fin = ssd_scan(xf, dtf, af, Bf, Cf, Df, chunk=chunk,
                      interpret=interpret or jax.default_backend() != "tpu")
    y = jnp.moveaxis(y.reshape(B, nh, S, hd), 1, 2)
    return y, fin.reshape(B, nh, hd, ns)
