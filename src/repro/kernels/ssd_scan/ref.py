"""Pure-jnp oracle for the SSD intra-chunk kernel.

For ONE chunk (per batch x head): given x (Q, hd), dt (Q,), a (scalar,
negative), B (Q, ns), C (Q, ns) and the carried state (hd, ns):

    cs_i   = cumsum(dt * a)                      (within-chunk log decay)
    L_ij   = exp(cs_i - cs_j) * dt_j   (j <= i)
    y_i    = sum_j (C_i . B_j) L_ij x_j          (intra)
           + (C_i . state) exp(cs_i)             (inter: carried state)
           + D x_i                               (skip)
    state' = state * exp(cs_Q) + sum_j B_j dt_j exp(cs_Q - cs_j) x_j

Matches models.ssm.ssd_chunked step-for-step (fp32 math).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["ssd_chunk_ref"]


def ssd_chunk_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                  B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                  state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (Q, hd), dt: (Q,), a: scalar, B/C: (Q, ns), D: scalar,
    state: (hd, ns).  Returns (y (Q, hd), new_state (hd, ns))."""
    Q, hd = x.shape
    f32 = jnp.float32
    x, dt, B, C, state = (t.astype(f32) for t in (x, dt, B, C, state))
    cs = jnp.cumsum(dt * a)                               # (Q,)
    diff = cs[:, None] - cs[None, :]                      # (Q, Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal, jnp.exp(diff), 0.0) * dt[None, :]
    G = C @ B.T                                           # (Q, Q)
    y = (G * L) @ x                                       # intra
    y = y + jnp.exp(cs)[:, None] * (C @ state.T)          # inter
    y = y + D * x                                         # skip
    seg = jnp.exp(cs[-1])
    w = dt * jnp.exp(cs[-1] - cs)                         # (Q,)
    new_state = state * seg + jnp.einsum("qh,qn->hn", x * w[:, None], B)
    return y, new_state
