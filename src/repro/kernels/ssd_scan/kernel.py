"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: the chunk dimension is the
LAST grid axis (sequential on a TensorCore), and the carried SSM state
(hd x ns per head) lives in VMEM scratch across those steps — the Pallas
analogue of ``lax.scan`` over chunks in the jnp reference, but with the
(Q, Q) decay-masked intra-chunk block computed entirely in VMEM and the
two matmuls (C.B^T and (G*L).x) hitting the MXU with 128-aligned tiles.

Grid: (batch * heads, n_chunks).  Block tensors per step:
  x (Q, hd), dt (Q, 1), B/C (Q, ns) — VMEM footprint for Q=256, hd=64,
  ns=128 is ~0.4 MB plus the (Q, Q) mask: well inside 16 MB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan", "ssd_scan_supported"]


def ssd_scan_supported(S: int, chunk: int) -> bool:
    """Whether :func:`ssd_scan` admits this geometry: the sequence must
    be a whole number of chunks.  Callers use this to fall back to the
    jnp oracle instead of tripping the kernel assert."""
    return chunk > 0 and S % chunk == 0


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, fin_ref,
            st_scr, *, Q: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[0].astype(jnp.float32)              # (Q, hd)
    dt = dt_ref[0].astype(jnp.float32)            # (Q, 1)
    a = a_ref[0, 0]                                # scalar
    B = b_ref[0].astype(jnp.float32)              # (Q, ns)
    C = c_ref[0].astype(jnp.float32)              # (Q, ns)
    D = d_ref[0, 0]

    dta = dt[:, 0] * a
    cs = jnp.cumsum(dta)                          # (Q,)
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = col <= row
    L = jnp.where(causal, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    L = L * dt[:, 0][None, :]
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(G * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, hd)

    state = st_scr[...]                           # (hd, ns)
    y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # C @ state.T
    y = y + D * x
    y_ref[0] = y.astype(y_ref.dtype)

    seg = jnp.exp(cs[Q - 1])
    w = (dt[:, 0] * jnp.exp(cs[Q - 1] - cs))[:, None]   # (Q, 1)
    st_new = state * seg + jax.lax.dot_general(
        x * w, B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (hd, ns)
    st_scr[...] = st_new

    @pl.when(ci == n_chunks - 1)
    def _emit():
        fin_ref[0] = st_new.astype(fin_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray, *,
             chunk: int, interpret: bool = False):
    """x: (BH, S, hd); dt: (BH, S); a, D: (BH,); B, C: (BH, S, ns).

    Batch and heads are flattened into BH (B/C already broadcast per head
    group by the caller).  Returns (y (BH, S, hd), final_state (BH, hd, ns)).
    """
    BH, S, hd = x.shape
    ns = B.shape[-1]
    Q = chunk
    assert ssd_scan_supported(S, Q), (S, Q)
    nc = S // Q

    kern = functools.partial(_kernel, Q=Q, n_chunks=nc)
    y, fin = pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, Q, ns), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, ns), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, ns), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), x.dtype),
            jax.ShapeDtypeStruct((BH, hd, ns), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ns), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], a[:, None], B, C, D[:, None])
    return y, fin
