"""Fused exchange-side transfer as a Pallas TPU kernel (compact superstep).

In the compact collective superstep (``core.master``, ``exchange=
"compact"``) every lane all_gathers one raw ``(max_steal, ...)`` ring
window and the victim's "detach" is a pure cursor bump — no masked block
is ever materialized on the victim.  What remains is the thief side:
cut the victim's stolen segment out of the replicated ``(W * max_steal,
...)`` gathered buffer (the ``steal_exact`` gather, relocated to the
thief) and splice it into the thief's own ring at the owner end (the
bulk ``push``).  ``ring_transfer`` fuses those two data movements into
ONE kernel:

* the source row offset ``src_start = src_row * max_steal`` is DYNAMIC
  (which victim the replicated plan paired this thief with), so the
  input DMA windows are aligned to it via scalar prefetch — the
  ``(max_steal, ...)`` intermediate ``gathered[src]`` block that a
  select-then-push pipeline would materialize never exists;
* the splice start ``head = (lo + size) % cap`` is DYNAMIC too, exactly
  as in ``kernels.queue_push.ring_scatter``: each touched ring block
  straddles at most two aligned gathered blocks, the true segment is cut
  with one ``dynamic_slice`` at ``block - head % block``, and rows
  outside ``[0, n)`` pass the old ring contents through (read-modify-
  write of the aliased block — the ring buffer is updated IN PLACE via
  ``input_output_aliases``);
* the grid covers only the ``max_steal // block + 1`` ring blocks the
  splice touches — cost is O(max_steal), never O(capacity) and never
  O(W * max_steal).

Structurally this is ``ring_scatter`` generalized with a dynamic source
offset into a source buffer W times larger than the splice span.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ring_transfer", "ring_transfer_supported", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 128


def ring_transfer_supported(capacity: int, max_steal: int, *,
                            block: int = DEFAULT_BLOCK) -> bool:
    """Whether :func:`ring_transfer` admits this geometry.  Same rule as
    the push-side ring-scatter: ring and transfer span must be whole
    numbers of (possibly shrunken) blocks, and the splice span
    (``max_steal`` plus one straddle block) must not lap the ring so
    every grid step writes a DISTINCT ring block.  The gathered source
    is ``n_lanes * max_steal`` rows, automatically block-aligned when
    ``max_steal`` is."""
    block = min(block, max_steal, capacity)
    return (block > 0 and capacity % block == 0 and max_steal % block == 0
            and max_steal + block <= capacity)


def _transfer_kernel(c_ref, prev_ref, cur_ref, buf_ref, o_ref, *,
                     block: int, width: int, max_steal: int):
    i = pl.program_id(0)
    head, n = c_ref[0], c_ref[2]
    r = head % block
    n = jnp.minimum(n, max_steal)
    # Gathered rows src_start + i*block - r + k, k in [0, block): cut one
    # aligned window out of the two candidate gathered blocks.
    both = jnp.concatenate([prev_ref[...], cur_ref[...]], axis=0)
    vals = jax.lax.dynamic_slice(both, (block - r, 0), (block, width))
    off = (i * block - r
           + jax.lax.broadcasted_iota(jnp.int32, (block, width), 0))
    live = (off >= 0) & (off < n)
    # Read-modify-write: rows outside the splice keep the old ring
    # contents (the output aliases the ring buffer input).
    o_ref[...] = jnp.where(live, vals, buf_ref[...])


def ring_transfer(buf: jnp.ndarray, gathered: jnp.ndarray,
                  head: jnp.ndarray, src_start: jnp.ndarray,
                  n: jnp.ndarray, *, max_steal: int,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = False) -> jnp.ndarray:
    """buf: (cap, W), gathered: (S, W) with ``S = n_lanes * max_steal``;
    returns buf with rows ``(head + i) % cap = gathered[src_start + i]``
    for ``i < n`` (``n <= max_steal``).

    ``src_start`` must be a multiple of the span ``max_steal`` (it is
    ``src_row * max_steal``), which keeps the dynamic source windows
    block-aligned.  Geometry must satisfy
    :func:`ring_transfer_supported`; the ring buffer argument is donated
    to the output (in-place splice).
    """
    cap, width = buf.shape
    srows = gathered.shape[0]
    block = min(block, max_steal, cap)
    assert ring_transfer_supported(cap, max_steal, block=block)
    assert srows % block == 0
    nb = cap // block
    sb = srows // block
    bb = max_steal // block

    kern = functools.partial(_transfer_kernel, block=block, width=width,
                             max_steal=max_steal)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        # bb gathered blocks land on bb + 1 ring blocks (dynamic straddle).
        grid=(bb + 1,),
        in_specs=[
            pl.BlockSpec((block, width),
                         lambda i, c: ((c[1] // block + (i - 1) % bb) % sb,
                                       0)),
            pl.BlockSpec((block, width),
                         lambda i, c: ((c[1] // block + i % bb) % sb, 0)),
            pl.BlockSpec((block, width),
                         lambda i, c: ((c[0] // block + i) % nb, 0)),
        ],
        out_specs=pl.BlockSpec((block, width),
                               lambda i, c: ((c[0] // block + i) % nb, 0)),
    )
    scalars = jnp.stack([jnp.asarray(head, jnp.int32),
                         jnp.asarray(src_start, jnp.int32),
                         jnp.asarray(n, jnp.int32)])
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap, width), buf.dtype),
        # Inputs count the scalar-prefetch arg first: buf is operand 3.
        input_output_aliases={3: 0},
        interpret=interpret,
    )(scalars, gathered, gathered, buf)
