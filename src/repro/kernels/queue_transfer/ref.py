"""Pure-jnp oracle for the fused exchange-side transfer.

``ring_transfer_ref(buf, gathered, head, src_start, n)``: splice rows
``gathered[src_start + i]`` into ``buf[(head + i) % cap]`` for ``i < n``
— the thief-side cut-and-splice the compact superstep performs after the
window all_gather (``steal_exact``'s gather relocated to the thief,
fused with the bulk ``push``; see ``kernels.queue_transfer.kernel``).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ring_transfer_ref"]


def ring_transfer_ref(buf: jnp.ndarray, gathered: jnp.ndarray, head,
                      src_start, n) -> jnp.ndarray:
    """``n`` must be pre-clamped to the span (ops.py does)."""
    cap = buf.shape[0]
    srows = gathered.shape[0]
    # Mirror the kernel's structure — a read-modify-write over the static
    # ring (one gather + select) — rather than an XLA scatter, whose CPU
    # lowering is per-row (see queue_push.ref for the same reasoning).
    off = (jnp.arange(cap, dtype=jnp.int32)
           - jnp.asarray(head, jnp.int32)) % cap
    live = off < jnp.asarray(n, jnp.int32)
    rows = jnp.minimum(jnp.asarray(src_start, jnp.int32) + off, srows - 1)
    vals = gathered[rows]
    return jnp.where(live.reshape((cap,) + (1,) * (buf.ndim - 1)),
                     vals, buf)
