"""Jitted wrapper: fused exchange-side transfer for arbitrary payload pytrees.

Leaves of the ring are flattened to ``(cap, -1)`` and the gathered
window stack to ``(W * max_steal, -1)``, moved with the Pallas kernel
(TPU) or the jnp oracle (elsewhere), and reshaped back.  Used by
kernel-routed ``repro.core.ops.BulkOps`` backends for ``transfer`` (the
compact superstep's thief-side cut-and-splice).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.queue_transfer.kernel import ring_transfer
from repro.kernels.queue_transfer.ref import ring_transfer_ref

__all__ = ["transfer_splice"]


@functools.partial(jax.jit, static_argnames=("max_steal", "use_pallas",
                                             "interpret"))
def transfer_splice(buf_tree, gathered_tree, head, src_row, n, *,
                    max_steal: int, use_pallas: bool = False,
                    interpret: bool = False):
    """Splice ``gathered_tree[src_row, :n] -> buf_tree[(head + i) % cap]``;
    ``gathered_tree`` leaves are ``(W, max_steal, ...)`` stacks of
    per-lane windows.  Returns the updated ring pytree.  The Pallas path
    aliases the ring input to the output (``input_output_aliases``) so
    under a donating caller the splice is in place, and the
    ``gathered[src_row]`` block is never materialized."""
    src_start = jnp.asarray(src_row, jnp.int32) * jnp.int32(max_steal)
    n = jnp.minimum(jnp.asarray(n, jnp.int32), jnp.int32(max_steal))

    def one(buf, gathered):
        shape = buf.shape
        w = gathered.shape[0]
        flat = buf.reshape(shape[0], -1)
        fg = gathered.reshape(w * max_steal, -1)
        if use_pallas or interpret:
            out = ring_transfer(flat, fg, head, src_start, n,
                                max_steal=max_steal,
                                interpret=interpret or
                                jax.default_backend() != "tpu")
        else:
            out = ring_transfer_ref(flat, fg, head, src_start, n)
        return out.reshape(shape)

    return jax.tree_util.tree_map(one, buf_tree, gathered_tree)
