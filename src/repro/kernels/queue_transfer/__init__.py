"""Fused exchange-side transfer kernel (compact superstep hot path)."""

from repro.kernels.queue_transfer.kernel import (  # noqa: F401
    ring_transfer,
    ring_transfer_supported,
)
from repro.kernels.queue_transfer.ops import transfer_splice  # noqa: F401
from repro.kernels.queue_transfer.ref import ring_transfer_ref  # noqa: F401
