"""Adaptive steal-proportion control for the executor.

The paper's ``steal(p)`` takes a static proportion; §V cites
Adnan-Sato-style dynamic chunk sizing as the natural extension.  Here the
master's observed queue sizes feed a small controller that servos the
proportion toward ``core.policy.adaptive_chunk``'s idle/busy-ratio
target:

* many idle workers + few victims -> steal a larger fraction so one
  round can feed several drained lanes from one victim;
* few idle workers -> steal less, preserving victim locality (the
  paper's argument for leaving the owner's hot head intact).

The feedback step itself is :func:`adaptive_update` — PURE jnp, float32
— so it runs in two places with one source of truth:

* **on device**, inside ``StealRuntime.run_fused``'s ``lax.scan`` carry,
  where the proportion is re-tuned every fused round without ever
  leaving the device (zero recompiles, zero host syncs);
* **on host**, via :class:`AdaptiveController`, for per-round driving
  (``StealRuntime.round``) and host-level consumers (the serving
  admission master) — the proportion is fed into the jitted superstep
  as a *traced* scalar, so updating it never recompiles.

Because both paths evaluate the identical float32 computation, a fused
k-round run follows the same proportion trajectory as k sequential
host-driven rounds.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.policy import StealPolicy

__all__ = ["AdaptiveConfig", "AdaptiveController", "adaptive_update"]


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Controller bounds and dynamics.

    Attributes:
      min_proportion / max_proportion: clamp range (the paper's Fig. 7/8
        sweep stays within [0.1, 0.6]; stealing > 3/4 would invert the
        imbalance).
      gain: first-order smoothing toward the target (1.0 = jump straight
        to the target each round).  The BENCH_PR3 full-size sweep found
        gain/clamp indistinguishable on rounds-to-drain (every adaptive
        config drained the Fig. 9 DAG in the same 420 supersteps; wall
        differences were within noise), so only the sweep's unambiguous
        winner was promoted — static p=0.25, now the
        :class:`~repro.core.policy.StealPolicy` default — and the
        smoothing default stays 0.5, which also spreads work across
        more lanes on the DD branch-and-bound workload than an
        unsmoothed jump does.
    """

    min_proportion: float = 0.125
    max_proportion: float = 0.75
    gain: float = 0.5


def adaptive_update(proportion, sizes, *, policy: StealPolicy,
                    config: AdaptiveConfig) -> jnp.ndarray:
    """One feedback step: float32 scalar in, float32 scalar out.

    Pure jnp (usable inside jit / scan).  The target is
    ``core.policy.adaptive_chunk`` vectorized: scale the stolen
    proportion with the idle/busy imbalance, clamped to [0.125, 0.75],
    then first-order-smooth toward it.  When the plan can pair no
    (victim, thief) this round there is no transfer to size, so hold
    rather than servo on zero signal.
    """
    sizes = jnp.asarray(sizes)
    p = jnp.asarray(proportion, jnp.float32)
    n_idle = jnp.sum((sizes <= policy.low_watermark).astype(jnp.int32))
    n_busy = jnp.sum((sizes >= policy.high_watermark).astype(jnp.int32))
    ratio = (n_idle.astype(jnp.float32)
             / jnp.maximum(n_idle + n_busy, 1).astype(jnp.float32))
    target = jnp.clip(jnp.float32(policy.proportion) * 2.0 * ratio,
                      0.125, 0.75)
    p_new = p + jnp.float32(config.gain) * (target - p)
    p_new = jnp.clip(p_new, config.min_proportion, config.max_proportion)
    return jnp.where((n_idle > 0) & (n_busy > 0), p_new, p)


class AdaptiveController:
    """Host-side wrapper: history + the NEXT round's proportion.

    Delegates the arithmetic to :func:`adaptive_update` so the host
    trajectory is bit-identical to the on-device fused one.
    """

    def __init__(self, policy: StealPolicy,
                 config: Optional[AdaptiveConfig] = None):
        self.policy = policy
        self.config = config or AdaptiveConfig()
        self.proportion = float(jnp.float32(policy.proportion))
        self.history: List[float] = [self.proportion]
        # Straggler response (train.fault.StragglerMonitor wiring): while
        # boosted, the proportion HANDED OUT is scaled up so the master
        # steals harder against a flagged-straggler lane for a bounded
        # number of rounds; the servo state itself is untouched, so the
        # boost decays to the normal trajectory instead of destabilizing
        # the feedback loop.
        self._boost_rounds_left = 0
        self._boost_factor = 1.0
        # Which lanes the active boost is attributed to, so reviving a
        # lane clears exactly its penalty (a lane-less flag attributes
        # to nobody and only ever decays by rounds).
        self._boost_lanes: set = set()

    def flag_straggler(self, rounds: int = 4, factor: float = 1.5,
                       lane: Optional[int] = None) -> None:
        """A straggler was flagged: boost the emitted steal proportion by
        ``factor`` (clamped to the config max) for the next ``rounds``
        controller updates.  ``lane`` attributes the boost so
        :meth:`clear_straggler` (revival) can cancel it."""
        self._boost_rounds_left = max(self._boost_rounds_left, int(rounds))
        self._boost_factor = float(factor)
        if lane is not None:
            self._boost_lanes.add(int(lane))

    def clear_straggler(self, lane: Optional[int] = None) -> None:
        """Cancel straggler penalty: for ``lane`` (a revived lane must
        not come back pre-penalized), or all of it when ``lane`` is
        None.  The boost only drops when no attributed lane remains —
        clearing one of two flagged lanes keeps the other's boost."""
        if lane is None:
            self._boost_lanes.clear()
            self._boost_rounds_left = 0
            self._boost_factor = 1.0
            return
        if int(lane) in self._boost_lanes:
            self._boost_lanes.discard(int(lane))
            if not self._boost_lanes:
                self._boost_rounds_left = 0
                self._boost_factor = 1.0

    @property
    def effective_proportion(self) -> float:
        """What the next round should actually use: the servo proportion,
        temporarily scaled while a straggler boost is active."""
        if self._boost_rounds_left > 0:
            return float(jnp.float32(min(
                self.proportion * self._boost_factor,
                self.config.max_proportion)))
        return self.proportion

    def update(self, sizes) -> float:
        """One feedback step from the post-round size vector."""
        p = float(adaptive_update(jnp.float32(self.proportion),
                                  jnp.asarray(np.asarray(sizes), jnp.int32),
                                  policy=self.policy, config=self.config))
        self.proportion = p
        self.history.append(p)
        if self._boost_rounds_left > 0:
            self._boost_rounds_left -= 1
            if self._boost_rounds_left == 0:
                self._boost_lanes.clear()
        return p

    def absorb(self, proportions_used, final_proportion) -> None:
        """Sync host state after an on-device fused run: ``proportions_used``
        are the k per-round values the scan consumed (element 0 is the
        pre-run proportion already in ``history``), ``final_proportion``
        the post-run carry value."""
        post = [float(x) for x in np.asarray(proportions_used)[1:]]
        self.proportion = float(final_proportion)
        self.history.extend(post + [self.proportion])
        if self._boost_rounds_left > 0:
            self._boost_rounds_left = max(
                0, self._boost_rounds_left - len(post) - 1)
            if self._boost_rounds_left == 0:
                self._boost_lanes.clear()
