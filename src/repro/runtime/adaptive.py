"""Adaptive steal-proportion control for the executor.

The paper's ``steal(p)`` takes a static proportion; §V cites
Adnan-Sato-style dynamic chunk sizing as the natural extension.  Here the
master's observed queue sizes (``RebalanceStats.sizes_after``) feed a
small host-side controller that servos the proportion toward
``core.policy.adaptive_chunk``'s idle/busy-ratio target:

* many idle workers + few victims -> steal a larger fraction so one
  round can feed several drained lanes from one victim;
* few idle workers -> steal less, preserving victim locality (the
  paper's argument for leaving the owner's hot head intact).

The proportion is fed into the jitted superstep as a *traced* scalar
(see ``executor.StealRuntime``), so updating it never recompiles.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.policy import StealPolicy, adaptive_chunk

__all__ = ["AdaptiveConfig", "AdaptiveController"]


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Controller bounds and dynamics.

    Attributes:
      min_proportion / max_proportion: clamp range (the paper's Fig. 7/8
        sweep stays within [0.1, 0.6]; stealing > 3/4 would invert the
        imbalance).
      gain: first-order smoothing toward the target (1.0 = jump straight
        to the target each round).
    """

    min_proportion: float = 0.125
    max_proportion: float = 0.75
    gain: float = 0.5


class AdaptiveController:
    """Servo ``proportion`` from observed queue-size imbalance."""

    def __init__(self, policy: StealPolicy,
                 config: Optional[AdaptiveConfig] = None):
        self.policy = policy
        self.config = config or AdaptiveConfig()
        self.proportion = float(policy.proportion)
        self.history: List[float] = [self.proportion]

    def update(self, sizes) -> float:
        """One feedback step from the post-round size vector."""
        sizes = np.asarray(sizes)
        n_idle = int(np.sum(sizes <= self.policy.low_watermark))
        n_busy = int(np.sum(sizes >= self.policy.high_watermark))
        if n_idle > 0 and n_busy > 0:
            target = adaptive_chunk(n_idle, n_busy,
                                    base=self.policy.proportion)
            cfg = self.config
            p = self.proportion + cfg.gain * (target - self.proportion)
            self.proportion = float(
                min(max(p, cfg.min_proportion), cfg.max_proportion))
        # Otherwise the plan pairs no (victim, thief) this round — there is
        # no transfer to size, so hold rather than servo on zero signal.
        self.history.append(self.proportion)
        return self.proportion
