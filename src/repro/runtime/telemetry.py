"""Per-round observability for the steal runtime.

Host-side, numpy-only (it must also serve the serving controller, which
never touches a device): each rebalancing round appends one
:class:`RoundRecord` with the steal count, items/bytes moved, the
exchange payload (``bytes_moved`` — what the round's block collective
carried per lane, the Fig. 10 scaling metric), the queue-depth histogram
and imbalance statistics — plus, when the phase probe is armed
(``StealRuntime.attach_phase_probe``, DESIGN.md §11), the round's
wall-clock split across ``worker_body`` / ``exchange`` / ``splice`` /
``adaptive_update``.  Wave-level consumers (the serving engine) append
:class:`WaveRecord` entries through the same object, and fault /
detector transitions land both as counters (:attr:`Telemetry.
fault_events`) and as a round-stamped event log (:attr:`Telemetry.
fault_log`) so one telemetry stream covers the master's rounds, the
workload's waves and the failures on a single logical-round timeline —
exactly what :mod:`repro.obs.trace` renders and
:mod:`repro.obs.metrics` exposes.

``summary()`` collapses the log into the benchmark-facing aggregates
(the DESIGN.md experiment sections consume these): a dict with

* ``rounds`` / ``steals`` / ``items_transferred`` /
  ``bytes_transferred`` / ``bytes_moved`` — lifetime round totals;
* ``proportion_mean`` / ``proportion_final`` / ``imbalance_final`` —
  adaptive-controller trajectory endpoints;
* ``waves`` / ``served`` / ``tokens`` (and ``migrated`` when nonzero) —
  only when wave records exist;
* ``requests`` + ``ttft_p50/p95/p99`` + ``latency_p50/p95/p99`` (in
  logical rounds) — only when request records exist;
* ``straggler_steps`` always, ``faults`` (the event counters dict) when
  any were recorded.

Per-phase wall-clock aggregates live in :meth:`Telemetry.phase_summary`,
kept separate because they exist only on probed runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["item_nbytes", "reduce_round_stats", "RoundRecord", "WaveRecord",
           "RequestRecord", "Telemetry"]


def item_nbytes(item_spec: Any) -> int:
    """Bytes per queue item — delegates to ``core.ops.item_nbytes``, the
    single source of truth (the master's ``bytes_moved`` uses the same
    accounting, so payload and transfer byte telemetry can't diverge)."""
    from repro.core.ops import item_nbytes as _impl

    return _impl(item_spec)


def reduce_round_stats(stats, *, n_workers: int, pod_size: Optional[int] = None
                       ) -> tuple:
    """Exact ``(n_steals, n_transferred, bytes_moved)`` for one round from
    per-lane ``RebalanceStats`` counters (numpy leaves, leading axis =
    lanes).

    This is the one reduction both executors share: the vmapped
    ``StealRuntime`` reads lanes of a stacked array, the mesh runtime
    reads the same layout after shard_map gathered each device's shard
    into lane order — so per-shard counters reduce to the identical
    exact ``RoundRecord`` regardless of where the lanes live.

    Flat mode: per-lane counters are replicated, so element 0 is exact.
    Hierarchical mode: lane ``(p, 0)`` carries pod p's intra-pod share;
    the cross-pod share lives in the ``*_xpod`` fields, nonzero only on
    lane-0 representatives and replicated across them — summing intra
    over pods and adding xpod ONCE is exact.  ``bytes_moved`` stays
    PER-LANE (the busiest lane's injection: its pod's intra-level
    payload plus the pod-level share)."""
    if pod_size is None:
        return (int(np.asarray(stats.n_steals).reshape(-1)[0]),
                int(np.asarray(stats.n_transferred).reshape(-1)[0]),
                int(np.asarray(stats.bytes_moved).reshape(-1)[0]))
    n_pods = n_workers // pod_size
    rep = lambda x: np.asarray(x).reshape(n_pods, -1)[:, 0]
    n_steals = int(rep(stats.n_steals).sum()) + int(
        rep(stats.n_steals_xpod)[0])
    n_transferred = int(rep(stats.n_transferred).sum()) + int(
        rep(stats.n_transferred_xpod)[0])
    bytes_moved = int(rep(stats.bytes_moved).max()) + int(
        rep(stats.bytes_moved_xpod)[0])
    return n_steals, n_transferred, bytes_moved


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One rebalancing round, as observed by the master.

    The ``t_*`` phase fields are zero unless the round ran under an
    armed phase probe (``StealRuntime.attach_phase_probe``) — then they
    attribute the round's wall-clock in seconds, ``phase_timed`` is
    True, and ``phase_estimated`` distinguishes a fused block's
    calibrated split from the unfused path's fence-bounded measurement
    (:mod:`repro.obs.phase`)."""

    round: int
    proportion: float          # steal proportion used THIS round
    n_steals: int              # victim->thief transfers planned
    n_transferred: int         # items moved
    transfer_bytes: int        # payload bytes moved
    bytes_moved: int           # exchange payload, busiest lane's view
    sizes_total: int
    sizes_max: int
    sizes_mean: float
    depth_hist: Sequence[int]  # queue-depth histogram over workers
    t_worker: float = 0.0      # wall seconds: worker body
    t_exchange: float = 0.0    # wall seconds: block-exchange collective
    t_splice: float = 0.0      # wall seconds: splice + bookkeeping tail
    t_adaptive: float = 0.0    # wall seconds: adaptive proportion update
    t_round: float = 0.0       # wall seconds attributed to this round
    phase_timed: bool = False
    phase_estimated: bool = False

    @property
    def imbalance(self) -> float:
        """max/mean load ratio (1.0 = perfectly balanced)."""
        return self.sizes_max / self.sizes_mean if self.sizes_mean else 0.0


@dataclasses.dataclass(frozen=True)
class WaveRecord:
    """One workload wave (e.g. a serving engine tick), as observed by
    whoever drives the rounds — same stream, coarser granularity.

    The SLO fields are percentiles over every :class:`RequestRecord`
    completed up to and including this wave (in logical rounds — the
    deterministic clock all execution modes share), filled in by
    :meth:`Telemetry.record_wave` whenever request records exist."""

    wave: int
    served: int                # requests completed this wave
    tokens: int                # tokens generated this wave (0 if n/a)
    loads: Sequence[int]       # per-worker load after the wave
    evicted: int = 0           # workers evicted (cumulative) at this wave
    stragglers: int = 0       # straggler flags raised this wave
    migrated: int = 0          # in-flight requests migrated (KV and all)
    ttft_p50: float = 0.0      # admit -> first-token percentiles (rounds)
    ttft_p95: float = 0.0
    ttft_p99: float = 0.0
    latency_p50: float = 0.0   # admit -> finish percentiles (rounds)
    latency_p95: float = 0.0
    latency_p99: float = 0.0
    round: int = -1            # logical round the wave closed at (-1 =
    #                            recorded before round alignment existed)


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One served request's admit -> first-token -> finish timeline,
    stamped in LOGICAL rounds (the per-lane round counter every
    execution mode advances identically) so SLO telemetry is
    deterministic and bit-comparable across host/vmap/mesh."""

    rid: int
    admit: int                 # round the request was admitted
    first: int                 # round the first token was generated
    finish: int                # round the last token was generated
    tokens: int                # tokens actually generated

    @property
    def ttft(self) -> int:
        """Time-to-first-token, in rounds."""
        return self.first - self.admit

    @property
    def latency(self) -> int:
        """Admit-to-finish latency, in rounds."""
        return self.finish - self.admit


def _percentiles(values) -> tuple:
    """(p50, p95, p99) of a non-empty value sequence."""
    arr = np.asarray(values, np.float64)
    return tuple(float(np.percentile(arr, p)) for p in (50.0, 95.0, 99.0))


class Telemetry:
    """Append-only per-round log + aggregate summary."""

    def __init__(self, item_bytes: int = 1, capacity: Optional[int] = None,
                 n_bins: int = 8):
        self.item_bytes = int(item_bytes)
        self.capacity = capacity
        self.n_bins = n_bins
        self.rounds: List[RoundRecord] = []
        self.waves: List[WaveRecord] = []
        self.requests: List[RequestRecord] = []
        # Resilience counters: kills / restarts / evictions / shrink /
        # grow events and straggler flags, recorded by the runtime's
        # fault layer next to the round + wave streams so one telemetry
        # object tells the whole story of a faulted run.
        self.fault_events: Dict[str, int] = {}
        # Round-stamped event log: (kind, lane, round) per record_fault
        # call (lane -1 = not lane-attributed) — what the trace exporter
        # renders as instant events on the round timeline.
        self.fault_log: List[tuple] = []
        self.straggler_steps = 0

    def record(self, *, sizes, n_steals: int, n_transferred: int,
               proportion: float, bytes_moved: int = 0,
               phases: Optional[Dict[str, Any]] = None) -> RoundRecord:
        """Append one round.  ``phases`` optionally carries the phase
        probe's wall-clock attribution — the dict
        :meth:`repro.obs.phase.PhaseSample.as_record` produces
        (``t_worker``/``t_exchange``/``t_splice``/``t_adaptive``/
        ``t_round``/``phase_estimated``); kept a plain mapping so this
        module stays numpy-only."""
        sizes = np.asarray(sizes)
        hi = self.capacity if self.capacity else max(int(sizes.max()), 1)
        hist, _ = np.histogram(sizes, bins=self.n_bins, range=(0, hi))
        extra: Dict[str, Any] = {}
        if phases is not None:
            extra = {k: phases.get(k, 0.0)
                     for k in ("t_worker", "t_exchange", "t_splice",
                               "t_adaptive", "t_round")}
            extra["phase_estimated"] = bool(
                phases.get("phase_estimated", False))
            extra["phase_timed"] = True
        rec = RoundRecord(
            round=len(self.rounds),
            proportion=float(proportion),
            n_steals=int(n_steals),
            n_transferred=int(n_transferred),
            transfer_bytes=int(n_transferred) * self.item_bytes,
            bytes_moved=int(bytes_moved),
            sizes_total=int(sizes.sum()),
            sizes_max=int(sizes.max()) if sizes.size else 0,
            sizes_mean=float(sizes.mean()) if sizes.size else 0.0,
            depth_hist=tuple(int(x) for x in hist),
            **extra,
        )
        self.rounds.append(rec)
        return rec

    def record_wave(self, *, loads, served: int, tokens: int = 0,
                    evicted: int = 0, stragglers: int = 0,
                    migrated: int = 0) -> WaveRecord:
        """Append one workload wave (serving tick, solver epoch, ...).
        When request records exist (:meth:`record_request`), the wave
        carries the cumulative SLO percentiles at this point in time."""
        slo = {}
        if self.requests:
            t50, t95, t99 = _percentiles([r.ttft for r in self.requests])
            l50, l95, l99 = _percentiles([r.latency for r in self.requests])
            slo = dict(ttft_p50=t50, ttft_p95=t95, ttft_p99=t99,
                       latency_p50=l50, latency_p95=l95, latency_p99=l99)
        rec = WaveRecord(
            wave=len(self.waves),
            served=int(served),
            tokens=int(tokens),
            loads=tuple(int(x) for x in np.asarray(loads).reshape(-1)),
            evicted=int(evicted),
            stragglers=int(stragglers),
            migrated=int(migrated),
            round=len(self.rounds),
            **slo,
        )
        self.waves.append(rec)
        return rec

    def record_request(self, *, rid: int, admit: int, first: int,
                       finish: int, tokens: int) -> RequestRecord:
        """Append one served request's admit/first-token/finish stamps
        (logical rounds)."""
        rec = RequestRecord(rid=int(rid), admit=int(admit), first=int(first),
                            finish=int(finish), tokens=int(tokens))
        self.requests.append(rec)
        return rec

    def record_fault(self, kind: str, n: int = 1,
                     lane: Optional[int] = None) -> None:
        """Count one resilience event (``"kill"`` / ``"restart"`` /
        ``"evict"`` / ``"suspect"`` / ``"shrink"`` / ``"grow"`` /
        ``"straggler"`` / ...).  ``lane`` attributes the event to a
        queue lane in the round-stamped :attr:`fault_log` (one log entry
        per call, stamped with the current round count).  Straggler
        flags additionally feed :attr:`straggler_steps`, the counter
        :meth:`summary` exports."""
        self.fault_events[kind] = self.fault_events.get(kind, 0) + int(n)
        self.fault_log.append((kind, -1 if lane is None else int(lane),
                               len(self.rounds)))
        if kind == "straggler":
            self.straggler_steps += int(n)

    # -- aggregates ----------------------------------------------------------

    @property
    def total_steals(self) -> int:
        return sum(r.n_steals for r in self.rounds)

    @property
    def total_transferred(self) -> int:
        return sum(r.n_transferred for r in self.rounds)

    @property
    def total_transfer_bytes(self) -> int:
        return sum(r.transfer_bytes for r in self.rounds)

    @property
    def total_bytes_moved(self) -> int:
        """Total per-lane exchange payload across rounds (the number the
        compact superstep shrinks by ~W vs the dense one)."""
        return sum(r.bytes_moved for r in self.rounds)

    @property
    def total_served(self) -> int:
        return sum(w.served for w in self.waves)

    @property
    def total_tokens(self) -> int:
        return sum(w.tokens for w in self.waves)

    def phase_summary(self) -> Dict[str, Any]:
        """Aggregate the probed rounds' wall-clock attribution: per phase
        (``worker_body`` / ``exchange`` / ``splice`` /
        ``adaptive_update``) the total and mean seconds plus the fraction
        of attributed wall, and the timed/estimated round counts.  Rounds
        recorded without a probe are excluded; with none probed the dict
        is just ``{"timed_rounds": 0}``."""
        timed = [r for r in self.rounds if r.phase_timed]
        out: Dict[str, Any] = {"timed_rounds": len(timed)}
        if not timed:
            return out
        out["estimated_rounds"] = sum(1 for r in timed if r.phase_estimated)
        totals = {
            "worker_body": sum(r.t_worker for r in timed),
            "exchange": sum(r.t_exchange for r in timed),
            "splice": sum(r.t_splice for r in timed),
            "adaptive_update": sum(r.t_adaptive for r in timed),
        }
        wall = sum(r.t_round for r in timed)
        out["wall_s"] = wall
        denom = sum(totals.values()) or 1.0
        out["phases"] = {
            name: {"total_s": t, "mean_s": t / len(timed),
                   "fraction": t / denom}
            for name, t in totals.items()
        }
        return out

    def summary(self) -> Dict[str, Any]:
        props = [r.proportion for r in self.rounds]
        out = {
            "rounds": len(self.rounds),
            "steals": self.total_steals,
            "items_transferred": self.total_transferred,
            "bytes_transferred": self.total_transfer_bytes,
            "bytes_moved": self.total_bytes_moved,
            "proportion_mean": float(np.mean(props)) if props else 0.0,
            "proportion_final": props[-1] if props else 0.0,
            "imbalance_final": self.rounds[-1].imbalance if self.rounds
            else 0.0,
        }
        if self.waves:
            out["waves"] = len(self.waves)
            out["served"] = self.total_served
            out["tokens"] = self.total_tokens
            migrated = sum(w.migrated for w in self.waves)
            if migrated:
                out["migrated"] = migrated
        if self.requests:
            t50, t95, t99 = _percentiles([r.ttft for r in self.requests])
            l50, l95, l99 = _percentiles([r.latency for r in self.requests])
            out["requests"] = len(self.requests)
            out["ttft_p50"], out["ttft_p95"], out["ttft_p99"] = t50, t95, t99
            out["latency_p50"] = l50
            out["latency_p95"] = l95
            out["latency_p99"] = l99
        out["straggler_steps"] = self.straggler_steps
        if self.fault_events:
            out["faults"] = dict(self.fault_events)
        return out
