"""Per-round observability for the steal runtime.

Host-side, numpy-only (it must also serve the serving controller, which
never touches a device): each rebalancing round appends one
:class:`RoundRecord` with the steal count, items/bytes moved, the
queue-depth histogram and imbalance statistics.  ``summary()`` collapses
the log into the numbers EXPERIMENTS.md wants (total transfer volume,
mean/final proportion, final imbalance).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["item_nbytes", "RoundRecord", "Telemetry"]


def item_nbytes(item_spec: Any) -> int:
    """Bytes per queue item: sum over payload-pytree leaves."""
    import jax
    import jax.numpy as jnp

    total = 0
    for leaf in jax.tree_util.tree_leaves(item_spec):
        total += int(np.prod(leaf.shape, dtype=np.int64)) * jnp.dtype(
            leaf.dtype).itemsize
    return total


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One rebalancing round, as observed by the master."""

    round: int
    proportion: float          # steal proportion used THIS round
    n_steals: int              # victim->thief transfers planned
    n_transferred: int         # items moved
    transfer_bytes: int        # payload bytes moved
    sizes_total: int
    sizes_max: int
    sizes_mean: float
    depth_hist: Sequence[int]  # queue-depth histogram over workers

    @property
    def imbalance(self) -> float:
        """max/mean load ratio (1.0 = perfectly balanced)."""
        return self.sizes_max / self.sizes_mean if self.sizes_mean else 0.0


class Telemetry:
    """Append-only per-round log + aggregate summary."""

    def __init__(self, item_bytes: int = 1, capacity: Optional[int] = None,
                 n_bins: int = 8):
        self.item_bytes = int(item_bytes)
        self.capacity = capacity
        self.n_bins = n_bins
        self.rounds: List[RoundRecord] = []

    def record(self, *, sizes, n_steals: int, n_transferred: int,
               proportion: float) -> RoundRecord:
        sizes = np.asarray(sizes)
        hi = self.capacity if self.capacity else max(int(sizes.max()), 1)
        hist, _ = np.histogram(sizes, bins=self.n_bins, range=(0, hi))
        rec = RoundRecord(
            round=len(self.rounds),
            proportion=float(proportion),
            n_steals=int(n_steals),
            n_transferred=int(n_transferred),
            transfer_bytes=int(n_transferred) * self.item_bytes,
            sizes_total=int(sizes.sum()),
            sizes_max=int(sizes.max()) if sizes.size else 0,
            sizes_mean=float(sizes.mean()) if sizes.size else 0.0,
            depth_hist=tuple(int(x) for x in hist),
        )
        self.rounds.append(rec)
        return rec

    # -- aggregates ----------------------------------------------------------

    @property
    def total_steals(self) -> int:
        return sum(r.n_steals for r in self.rounds)

    @property
    def total_transferred(self) -> int:
        return sum(r.n_transferred for r in self.rounds)

    @property
    def total_transfer_bytes(self) -> int:
        return sum(r.transfer_bytes for r in self.rounds)

    def summary(self) -> Dict[str, Any]:
        props = [r.proportion for r in self.rounds]
        return {
            "rounds": len(self.rounds),
            "steals": self.total_steals,
            "items_transferred": self.total_transferred,
            "bytes_transferred": self.total_transfer_bytes,
            "proportion_mean": float(np.mean(props)) if props else 0.0,
            "proportion_final": props[-1] if props else 0.0,
            "imbalance_final": self.rounds[-1].imbalance if self.rounds
            else 0.0,
        }
