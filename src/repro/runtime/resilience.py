"""Fault injection and recovery for the steal executors.

Production fleets lose and regain workers mid-run; the paper's own
mechanism is the recovery primitive — a dead worker is just a victim
stolen at proportion 1.0, and the multiplicity tolerance already
licensed for the relaxed backend bounds the duplication a crash between
an exchange and its splice can produce (DESIGN.md §8).  This module
supplies the machinery around that observation:

* :class:`FaultPlan` — a deterministic, seedable schedule of injected
  failures (kill lane w at round r, drop one round's exchange, delay a
  lane's worker body by k rounds).  The plan compiles to small
  replicated int32 arrays that ride into the jitted round as traced
  inputs, so the identical plan replays bit-identically under
  ``jax.vmap`` lanes and under ``shard_map`` meshes — and the host can
  mutate the schedule between dispatches (planned eviction, re-admission
  on grow) without recompiling.
* :func:`make_resilient_lane` — the fault-aware round body
  :func:`repro.runtime.executor.make_lane_step` delegates to.  Per
  round: the worker body's effects are discarded for dead/delayed lanes
  (the body still executes on every lane, so worker collectives stay
  collective), the normal rebalancing plan is computed with dead lanes
  masked out (neither idle-eligible nor victims), and then ONE extra
  recovery superstep runs whose replicated plan steals each dead lane's
  entire ring — ``min(size, max_steal, thief free space)`` per round,
  i.e. proportion 1.0 — into the least-loaded survivors, through the
  SAME exchange collectives and kernels as every other round (the
  zero-transfer fast path makes it free while nobody is dead).
* :func:`mask_sizes` — the size-vector mask the adaptive controller
  sees: dead lanes advertise the neither-idle-nor-busy sentinel, so the
  proportion servo never counts a corpse as an idle thief.

The fault context (``ctx``) threaded through the executors is either a
plain int32 round index (fault injection off — the compiled round is
byte-identical to the pre-resilience one) or a dict of the round index
plus the schedule arrays (fault injection on).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax import lax

from repro.core import master as master_ops
from repro.core.policy import StealPolicy, plan_transfers

__all__ = [
    "NEVER",
    "FaultPlan",
    "FaultState",
    "ctx_round",
    "ctx_advance",
    "ctx_specs",
    "dead_mask",
    "mask_sizes",
    "masked_plan",
    "recovery_plan",
    "make_resilient_lane",
]

Pytree = Any

# "This lane is never killed": any round index compares < NEVER.
NEVER = np.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# Fault plans


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of injected failures.

    Attributes:
      kills: ``(lane, round)`` pairs — lane ``lane`` dies at the START of
        round ``round`` (it executes no worker body from that round on
        and is masked out of every plan; its ring is drained by recovery
        steals).  Round indices are GLOBAL (``StealRuntime.rounds_run``
        numbering), so a plan replays identically across ``round()`` /
        ``run_fused`` dispatch boundaries.
      delays: ``(lane, round, k)`` triples — lane ``lane`` skips its
        worker body for rounds ``[round, round + k)`` (a straggler: it
        still participates in exchanges, it just produces nothing).
      drops: round indices whose block exchange is dropped entirely (the
        plan is forced empty — both the normal and the recovery transfer
        move nothing that round; a lost collective, recovered next
        round).

    An empty ``FaultPlan()`` is meaningful: it arms the fault machinery
    (recovery supersteps, mutable kill schedule) without scheduling any
    failure — what planned eviction and the elastic serve master use.
    """

    kills: Tuple[Tuple[int, int], ...] = ()
    delays: Tuple[Tuple[int, int, int], ...] = ()
    drops: Tuple[int, ...] = ()

    @classmethod
    def random(cls, n_workers: int, *, seed: int, n_kills: int = 1,
               n_delays: int = 0, n_drops: int = 0,
               max_round: int = 16, max_delay: int = 4) -> "FaultPlan":
        """A seeded random plan: ``n_kills`` distinct lanes killed (never
        lane 0, so at least one survivor remains), ``n_delays`` straggler
        windows and ``n_drops`` dropped exchanges, all in rounds
        ``[1, max_round)``.  Same seed -> same plan -> same replay, in
        either execution mode."""
        rng = np.random.default_rng(seed)
        if n_kills >= n_workers:
            raise ValueError("cannot kill every lane")
        lanes = rng.choice(np.arange(1, n_workers), size=n_kills,
                           replace=False)
        kills = tuple((int(w), int(rng.integers(1, max_round)))
                      for w in lanes)
        delays = tuple((int(rng.integers(0, n_workers)),
                        int(rng.integers(1, max_round)),
                        int(rng.integers(1, max_delay + 1)))
                       for _ in range(n_delays))
        drops = tuple(int(rng.integers(1, max_round))
                      for _ in range(n_drops))
        return cls(kills=kills, delays=delays, drops=drops)

    def validate(self, n_workers: int) -> None:
        for w, r in self.kills:
            if not (0 <= w < n_workers):
                raise ValueError(f"kill lane {w} out of range [0, {n_workers})")
            if r < 0:
                raise ValueError(f"kill round {r} negative")
        for w, r, k in self.delays:
            if not (0 <= w < n_workers):
                raise ValueError(f"delay lane {w} out of range")
            if r < 0 or k < 1:
                raise ValueError(f"bad delay window ({r}, {k})")
        if len({w for w, _ in self.kills}) >= n_workers:
            raise ValueError("plan kills every lane; recovery needs a thief")


class FaultState:
    """Host-side, mutable compilation of a :class:`FaultPlan`.

    Owns the schedule arrays the jitted round consumes as traced inputs:
    ``kill_round[w]`` (NEVER = alive forever), one ``[delay_from,
    delay_until)`` straggler window per lane, and the padded
    ``drop_rounds`` vector.  Mutation (:meth:`kill` for planned eviction
    or detected death, :meth:`revive` for grow/re-admission) changes
    VALUES only — shapes are fixed at construction — so no dispatch ever
    recompiles."""

    def __init__(self, plan: FaultPlan, n_workers: int):
        plan.validate(n_workers)
        self.plan = plan
        self.n_workers = int(n_workers)
        self.kill_round = np.full((n_workers,), NEVER, np.int32)
        for w, r in plan.kills:
            self.kill_round[w] = min(self.kill_round[w], np.int32(r))
        self.delay_from = np.full((n_workers,), NEVER, np.int32)
        self.delay_until = np.full((n_workers,), NEVER, np.int32)
        for w, r, k in plan.delays:  # one window per lane; last wins
            self.delay_from[w] = np.int32(r)
            self.delay_until[w] = np.int32(r + k)
        drops = sorted(set(plan.drops))
        self.drop_rounds = np.asarray(drops or [-1], np.int32)

    # -- host mutation (no recompiles: values change, shapes don't) ---------

    def kill(self, lane: int, at_round: int) -> None:
        self.kill_round[lane] = np.int32(min(int(self.kill_round[lane]),
                                             int(at_round)))

    def revive(self, lane: int) -> None:
        self.kill_round[lane] = NEVER

    def dead_at(self, round_index: int) -> np.ndarray:
        """(W,) bool: which lanes are dead at ``round_index``."""
        return np.asarray(self.kill_round) <= np.int32(round_index)

    # -- the traced context --------------------------------------------------

    def ctx(self, round0: int) -> Dict[str, jnp.ndarray]:
        return {
            "round": jnp.int32(round0),
            "kill_round": jnp.asarray(self.kill_round),
            "delay_from": jnp.asarray(self.delay_from),
            "delay_until": jnp.asarray(self.delay_until),
            "drop_rounds": jnp.asarray(self.drop_rounds),
        }

    # -- snapshot/restore ----------------------------------------------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        return {
            "kill_round": np.asarray(self.kill_round),
            "delay_from": np.asarray(self.delay_from),
            "delay_until": np.asarray(self.delay_until),
            "drop_rounds": np.asarray(self.drop_rounds),
        }

    def load_state(self, state: Dict[str, np.ndarray]) -> None:
        self.kill_round = np.asarray(state["kill_round"], np.int32).copy()
        self.delay_from = np.asarray(state["delay_from"], np.int32).copy()
        self.delay_until = np.asarray(state["delay_until"], np.int32).copy()
        self.drop_rounds = np.asarray(state["drop_rounds"], np.int32).copy()


# ---------------------------------------------------------------------------
# The traced fault context (scalar round index when injection is off)


def ctx_round(ctx) -> jnp.ndarray:
    """The current round index carried by a fault context."""
    return ctx["round"] if isinstance(ctx, dict) else ctx


def ctx_advance(ctx):
    """The context for the NEXT round (round index + 1, schedule shared)."""
    if isinstance(ctx, dict):
        return {**ctx, "round": ctx["round"] + 1}
    return ctx + 1


def ctx_specs(fault_active: bool):
    """The ``shard_map`` in/out spec for a fault context: everything in it
    is replicated (the round index and the schedule are the same on every
    lane — the virtual master's view)."""
    from jax.sharding import PartitionSpec as P

    if not fault_active:
        return P()
    return {"round": P(), "kill_round": P(), "delay_from": P(),
            "delay_until": P(), "drop_rounds": P()}


def dead_mask(ctx) -> jnp.ndarray:
    """(W,) bool, replicated: lanes dead at the context's round."""
    return ctx["kill_round"] <= ctx["round"]


def mask_sizes(sizes: jnp.ndarray, ctx, policy: StealPolicy) -> jnp.ndarray:
    """The size vector as the adaptive controller should see it: dead
    lanes advertise the hierarchical superstep's neither-idle-nor-busy
    sentinel (``low_watermark + 1``), so a drained corpse never counts as
    an idle thief and never inflates the steal proportion."""
    if not isinstance(ctx, dict):
        return sizes
    sentinel = jnp.int32(policy.low_watermark + 1)
    return jnp.where(dead_mask(ctx), sentinel, sizes)


# ---------------------------------------------------------------------------
# Replicated plans (pure jnp — every lane computes the identical answer)


def _noop_plan(n: int) -> jnp.ndarray:
    idx = jnp.arange(n, dtype=jnp.int32)
    return jnp.stack([idx, jnp.zeros((n,), jnp.int32)], axis=-1)


def masked_plan(sizes: jnp.ndarray, dead: jnp.ndarray,
                policy: StealPolicy) -> jnp.ndarray:
    """The normal rebalancing plan with dead lanes masked out: they are
    neither idle-eligible (work must not move INTO a corpse) nor victims
    (their whole ring belongs to the recovery plan, not a proportional
    steal).  Implemented as :func:`~repro.core.policy.plan_transfers`
    over a size vector where dead lanes advertise the sentinel — steal
    amounts are computed from victim rows, which are always alive, so
    they still read TRUE sizes and the exchange clamps agree."""
    sentinel = jnp.int32(policy.low_watermark + 1)
    return plan_transfers(jnp.where(dead, sentinel, sizes), policy)


def recovery_plan(sizes: jnp.ndarray, dead: jnp.ndarray, *,
                  max_steal: int, capacity: int,
                  thief_ok: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The dead-worker-as-victim plan: rank dead lanes that still hold
    work by size (fullest first) and surviving lanes by load (emptiest
    first), pair them, and steal ``min(size, max_steal, thief free
    space)`` — proportion 1.0, bounded per round by the exchange window,
    so a ring larger than ``max_steal`` drains over successive rounds.
    Same ``(W, 2)`` layout as :func:`~repro.core.policy.plan_transfers`;
    executed by the unmodified compact (or dense) exchange.

    ``thief_ok`` optionally restricts who may receive: the cross-pod
    recovery rows of the hierarchical lane pass the per-row liveness
    mask here, because a LIVE pod's lane in some row may itself be a
    dead lane — it must not be handed a dead pod's ring."""
    n = sizes.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    victim = dead & (sizes > 0)
    thief = ~dead if thief_ok is None else (thief_ok & ~dead)

    victim_order = jnp.argsort(jnp.where(victim, -sizes, jnp.int32(2**30)))
    thief_order = jnp.argsort(jnp.where(thief, sizes, jnp.int32(2**30)))
    n_pairs = jnp.minimum(jnp.sum(victim.astype(jnp.int32)),
                          jnp.sum(thief.astype(jnp.int32)))
    live = jnp.arange(n, dtype=jnp.int32) < n_pairs

    victim_of_pair = victim_order.astype(jnp.int32)
    thief_of_pair = thief_order.astype(jnp.int32)
    amt = jnp.minimum(sizes[victim_of_pair], jnp.int32(max_steal))
    # Never overflow the thief: proportion-1.0 steals ignore watermarks,
    # so the free-space clamp must be in the REPLICATED plan (both ends
    # derive their cut from it, so victim and thief stay in agreement).
    amt = jnp.minimum(amt, jnp.int32(capacity) - sizes[thief_of_pair])
    amt = jnp.where(live, jnp.maximum(amt, 0), 0)

    src = jnp.full((n,), idx, dtype=jnp.int32)
    src = src.at[thief_of_pair].set(
        jnp.where(live, victim_of_pair, thief_of_pair), mode="drop")
    amtv = jnp.zeros((n,), jnp.int32).at[thief_of_pair].set(amt, mode="drop")
    return jnp.stack([src, amtv], axis=-1)


# ---------------------------------------------------------------------------
# The fault-aware lane step


def _select(keep_old: jnp.ndarray, old: Pytree, new: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(keep_old, a, b), old, new)


def make_resilient_lane(policy: StealPolicy, ops, worker_fn, *,
                        axis_name: str, pod_axis: Optional[str] = None,
                        hierarchical: bool = False,
                        stage: Optional[str] = None):
    """The fault-injecting round body for ONE lane:
    ``(q, carry, proportion, ctx) -> (q, carry, stats)`` — what
    :func:`repro.runtime.executor.make_lane_step` returns when the
    runtime was built with a :class:`FaultPlan`.

    Per round, in order: (1) the worker body runs on EVERY lane (worker
    collectives stay collective) but its effects are discarded on dead
    and delayed lanes; (2) the normal superstep executes the
    dead-masked plan; (3) one recovery superstep executes the
    dead-worker-as-victim plan (free via the zero-transfer fast path
    while nobody is dead).  Dropped-exchange rounds force both plans
    empty.  The merged stats keep the round's full transfer accounting
    (``sizes_before`` from before any exchange, ``sizes_after`` from
    after recovery, counters summed).

    With ``hierarchical=True`` (a 2-D ``(pod_axis, axis_name)`` lane
    grid) the round composes FOUR plans, all derived from the replicated
    schedule so every lane/mode agrees bit-for-bit:

    * the intra-pod normal plan with the pod's dead lanes masked;
    * the cross-pod normal plan over lane-0 representatives, where a pod
      whose representative is dead abstains (sentinel) until revival —
      its work still flows intra-pod, and its dead rep's ring drains
      intra-pod (a dead LANE is a pod-local event);
    * the intra-pod recovery plan (dead-fullest -> alive-emptiest within
      the pod);
    * the cross-pod recovery plan for ENTIRELY dead pods: each ring row
      ``w`` drains dead pods' lane-``w`` rings into the emptiest live
      pod's lane-``w``, with ``thief_ok`` excluding rows whose own lane
      is dead in an otherwise-live pod.

    Cross-pod recovery counts are folded onto lane-0 representatives
    (``psum`` over the worker axis), preserving the
    :func:`repro.runtime.telemetry.reduce_round_stats` accounting
    convention: xpod counters nonzero only at lane ``(p, 0)``.

    ``stage`` truncates the lane for the phase probe exactly as in
    :func:`~repro.runtime.executor.make_lane_step`: ``"worker"`` stops
    after the (skip-masked) worker body, ``"exchange"`` after the normal
    block exchange with the SAME dead-masked plan the full round uses
    (the recovery supersteps belong to the splice share).  Prefix lanes
    return a DCE-proof scalar token in the stats slot and never commit
    state."""
    if hierarchical and pod_axis is None:
        raise ValueError("hierarchical resilient lane needs a pod_axis")
    if stage not in (None, "worker", "exchange"):
        raise ValueError(f"unknown stage {stage!r}")

    def flat_lane(q, carry, proportion, ctx):
        r = ctx_round(ctx)
        me = lax.axis_index(axis_name)
        i_am_dead = r >= ctx["kill_round"][me]
        i_am_delayed = (r >= ctx["delay_from"][me]) & (r < ctx["delay_until"][me])

        if worker_fn is not None:
            q_new, carry_new = worker_fn(q, carry)
            skip = i_am_dead | i_am_delayed
            q = _select(skip, q, q_new)
            carry = _select(skip, carry, carry_new)
        if stage == "worker":
            return q, carry, master_ops.probe_token(q)

        pol = dataclasses.replace(policy, proportion=proportion)
        cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
        dead = lax.all_gather(i_am_dead, axis_name)  # (W,) replicated
        drop = jnp.any(ctx["drop_rounds"] == r)

        # Normal rebalancing over the survivors.
        sizes = master_ops.gather_sizes(q, worker_axis=axis_name)
        plan = masked_plan(sizes, dead, pol)
        plan = jnp.where(drop, _noop_plan(sizes.shape[0]), plan)
        if stage == "exchange":
            token = master_ops.exchange_probe(q, pol, axis_name=axis_name,
                                              ops=ops, plan=plan)
            return q, carry, token
        q, stats = master_ops.superstep(q, pol, axis_name=axis_name,
                                        ops=ops, plan=plan)

        # Recovery: dead rings stolen at proportion 1.0 by the least-
        # loaded survivors, through the identical exchange.
        sizes2 = master_ops.gather_sizes(q, worker_axis=axis_name)
        rplan = recovery_plan(sizes2, dead, max_steal=pol.max_steal,
                              capacity=cap)
        rplan = jnp.where(drop, _noop_plan(sizes2.shape[0]), rplan)
        q, rstats = master_ops.superstep(q, pol, axis_name=axis_name,
                                         ops=ops, plan=rplan)

        stats = stats._replace(
            sizes_after=rstats.sizes_after,
            n_transferred=stats.n_transferred + rstats.n_transferred,
            n_steals=stats.n_steals + rstats.n_steals,
            bytes_moved=stats.bytes_moved + rstats.bytes_moved,
        )
        return q, carry, stats

    def hier_lane(q, carry, proportion, ctx):
        from repro.core.ops import QueueState

        r = ctx_round(ctx)
        # psum of a literal folds to the static axis size at trace time,
        # so these drive static reshapes/plan widths.
        pod_size = lax.psum(1, axis_name)
        n_pods = lax.psum(1, pod_axis)
        w_idx = lax.axis_index(axis_name)
        p_idx = lax.axis_index(pod_axis)
        me = p_idx * pod_size + w_idx  # flat lane order: pod-major

        # The schedule is replicated, so every liveness view derives
        # from ctx with no collectives: the flat mask, my pod's slice,
        # and the entirely-dead-pod vector.
        dead_flat = dead_mask(ctx)                     # (W,)
        dead2d = dead_flat.reshape(n_pods, pod_size)   # (n_pods, pod_size)
        dead_intra = dead2d[p_idx]                     # (pod_size,)
        pod_dead = jnp.all(dead2d, axis=1)             # (n_pods,)
        i_am_dead = dead_flat[me]
        i_am_delayed = (r >= ctx["delay_from"][me]) & (r < ctx["delay_until"][me])

        if worker_fn is not None:
            q_new, carry_new = worker_fn(q, carry)
            skip = i_am_dead | i_am_delayed
            q = _select(skip, q, q_new)
            carry = _select(skip, carry, carry_new)
        if stage == "worker":
            return q, carry, master_ops.probe_token(q)

        pol = dataclasses.replace(policy, proportion=proportion)
        cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
        drop = jnp.any(ctx["drop_rounds"] == r)

        # (1) Intra-pod normal superstep, the pod's dead lanes masked.
        sizes_pod = master_ops.gather_sizes(q, worker_axis=axis_name)
        plan = masked_plan(sizes_pod, dead_intra, pol)
        plan = jnp.where(drop, _noop_plan(pod_size), plan)
        if stage == "exchange":
            token = master_ops.exchange_probe(q, pol, axis_name=axis_name,
                                              ops=ops, plan=plan)
            return q, carry, token
        q, intra = master_ops.superstep(q, pol, axis_name=axis_name,
                                        ops=ops, plan=plan)

        # (2) Cross-pod normal superstep via lane-0 representatives —
        # the hierarchical_superstep sentinel trick, with a dead rep's
        # pod abstaining entirely (its work still flows intra-pod).
        sentinel = jnp.int32(pol.low_watermark + 1)
        rep_dead = dead2d[p_idx, 0]
        eff_size = jnp.where((w_idx == 0) & ~rep_dead, q.size, sentinel)
        q_eff = QueueState(buf=q.buf, lo=q.lo, size=eff_size)
        sizes_x = lax.all_gather(eff_size, pod_axis)   # (n_pods,) per row
        pod_plan = plan_transfers(sizes_x, pol)
        pod_plan = jnp.where(drop, _noop_plan(n_pods), pod_plan)
        q_eff, pod_stats = master_ops.superstep(q_eff, pol,
                                                axis_name=pod_axis,
                                                ops=ops, plan=pod_plan)
        delta = q_eff.size - eff_size
        q = QueueState(buf=q_eff.buf, lo=q_eff.lo, size=q.size + delta)

        # (3) Intra-pod recovery: a dead LANE's ring drains into its
        # pod-mates (dead-fullest -> alive-emptiest, proportion 1.0).
        # No-op in an entirely dead pod — no live thief exists there.
        sizes2 = master_ops.gather_sizes(q, worker_axis=axis_name)
        rplan = recovery_plan(sizes2, dead_intra, max_steal=pol.max_steal,
                              capacity=cap)
        rplan = jnp.where(drop, _noop_plan(pod_size), rplan)
        q, irec = master_ops.superstep(q, pol, axis_name=axis_name,
                                       ops=ops, plan=rplan)

        # (4) Cross-pod recovery: a dead POD escalates — each ring row w
        # drains the dead pods' lane-w rings into the emptiest live
        # pod's lane-w, riding the same exchange over the pod axis.
        dead_row = dead2d[:, w_idx]                    # (n_pods,) my row
        sizes_row = lax.all_gather(q.size, pod_axis)
        xplan = recovery_plan(sizes_row, pod_dead, max_steal=pol.max_steal,
                              capacity=cap, thief_ok=~dead_row)
        xplan = jnp.where(drop, _noop_plan(n_pods), xplan)
        q, xrec = master_ops.superstep(q, pol, axis_name=pod_axis,
                                       ops=ops, plan=xplan)

        # Accounting, reduce_round_stats-exact: intra recovery adds to
        # the per-pod intra counters; per-row cross-pod recovery counts
        # are summed over the rows of a pod (replicated across pods) and
        # folded onto lane-0 so the xpod fields stay nonzero only on
        # representatives.  bytes stay PER-LANE (physical injection).
        is_rep = w_idx == 0
        xrec_nt = lax.psum(xrec.n_transferred, axis_name)
        xrec_ns = lax.psum(xrec.n_steals, axis_name)
        stats = intra._replace(
            sizes_after=xrec.sizes_after,
            n_transferred=intra.n_transferred + irec.n_transferred,
            n_steals=intra.n_steals + irec.n_steals,
            bytes_moved=intra.bytes_moved + irec.bytes_moved,
            n_transferred_xpod=(pod_stats.n_transferred
                                + jnp.where(is_rep, xrec_nt, 0)),
            n_steals_xpod=(pod_stats.n_steals
                           + jnp.where(is_rep, xrec_ns, 0)),
            bytes_moved_xpod=pod_stats.bytes_moved + xrec.bytes_moved,
        )
        return q, carry, stats

    return hier_lane if hierarchical else flat_lane
