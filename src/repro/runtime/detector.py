"""Automatic failure detection: healthy -> suspected -> dead, revivable.

PR 7 made death *declared*: somebody calls ``kill_lane`` and the
recovery superstep drains the corpse.  Production failures are not
declared — a lane just stops answering, or answers late.  This module is
the policy that INFERS death from behaviour, shared by every executor
mode and both serve admission masters, so "how many slow rounds before
we give up on a worker" is configured once instead of ad-hoc per layer
(it replaces the old streak counter inside ``serve/engine.py``).

The detector is deliberately host-side and observation-driven: it never
touches device state itself.  Callers feed it one boolean observation
per (lane, round) — ``slow=True`` when the lane missed its deadline
(a :class:`repro.train.fault.StragglerMonitor` timeout, a replayed
delay-schedule window, a wall-clock wave straggler) — and the detector
answers with the lane's state, firing the escalation callbacks its owner
registered:

* ``on_suspect(lane)`` — the lane crossed ``suspect_after`` consecutive
  slow observations.  Fired on EVERY slow observation at or past the
  threshold (not just the crossing), so the owner can keep a temporary
  proportion boost alive for as long as the lane keeps lagging; the
  runtime wires this to :meth:`StealRuntime.note_straggler`.
* ``on_dead(lane)`` — the streak reached ``dead_after``: the lane is
  declared dead.  The runtime wires this to a real
  :meth:`StealRuntime.kill_lane`, so the very next round masks the lane
  out of every plan and the recovery superstep starts draining its ring.
  A dead lane's subsequent observations are ignored until
  :meth:`FailureDetector.revive`.
* ``on_revive(lane)`` — an explicit revival (grow, re-admission): all
  streak state clears, the lane restarts healthy.

Determinism: the detector itself is a pure function of its observation
sequence.  When the observations come from the replayed fault schedule
(``StealRuntime._feed_detector``), the same :class:`FaultPlan` produces
the same suspect/kill sequence under vmap and mesh execution — detector
escalation preserves bit-identical replay parity.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Callable, List, Optional

__all__ = ["DetectorPolicy", "FailureDetector",
           "HEALTHY", "SUSPECTED", "DEAD"]

HEALTHY = "healthy"
SUSPECTED = "suspected"
DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class DetectorPolicy:
    """The one escalation policy every layer shares.

    Attributes:
      suspect_after: consecutive slow observations before a lane is
        SUSPECTED (straggler boost territory).
      dead_after: consecutive slow observations before a lane is
        declared DEAD (a real ``kill_lane``).  ``None`` disables the
        death escalation entirely — the detector then only ever
        suspects, which is how a boost-only owner (no fault layer)
        runs it.
      healthy_after: consecutive on-time observations before a
        SUSPECTED lane is cleared back to HEALTHY.
      boost_rounds / boost_factor: the ``note_straggler`` proportion
        boost parameters the owner applies per ``on_suspect`` firing.
      wall_clock: ALSO classify real measured dispatch wall times fed
        through :meth:`FailureDetector.observe_wall` (the runtime feeds
        per-round dispatch wall when this is set — wall-clock detection
        on the RUNTIME path, not just the serve masters' monitors).
        Off by default so CI replay determinism and the vmap/mesh
        parity suites are untouched: wall observations are inherently
        non-deterministic.
      wall_slow_factor: a wall observation is "slow" when it exceeds
        this multiple of the lane's rolling baseline (median of its
        ``wall_window`` most recent observations).
      wall_window: rolling-baseline window length, in observations; a
        lane is never judged before it has ``max(4, wall_window // 4)``
        samples of history.
      wall_kill: let wall-driven streaks escalate all the way to DEAD.
        Off by default — a collective dispatch wall cannot finger WHICH
        lane is slow, so by default wall slowness only ever suspects
        (boosting the steal proportion), never kills.
    """

    suspect_after: int = 2
    dead_after: Optional[int] = 6
    healthy_after: int = 2
    boost_rounds: int = 4
    boost_factor: float = 1.5
    wall_clock: bool = False
    wall_slow_factor: float = 2.0
    wall_window: int = 32
    wall_kill: bool = False

    def __post_init__(self):
        if self.suspect_after < 1:
            raise ValueError(f"suspect_after must be >= 1, "
                             f"got {self.suspect_after}")
        if self.healthy_after < 1:
            raise ValueError(f"healthy_after must be >= 1, "
                             f"got {self.healthy_after}")
        if self.dead_after is not None and self.dead_after < self.suspect_after:
            raise ValueError(
                f"dead_after={self.dead_after} must be >= "
                f"suspect_after={self.suspect_after} (suspicion precedes "
                f"death) or None to disable the kill escalation")
        if self.wall_slow_factor <= 1.0:
            raise ValueError(f"wall_slow_factor must be > 1.0, "
                             f"got {self.wall_slow_factor}")
        if self.wall_window < 4:
            raise ValueError(f"wall_window must be >= 4, "
                             f"got {self.wall_window}")


class FailureDetector:
    """Per-lane healthy/suspected/dead state machine (host-side).

    Args:
      n_lanes: number of lanes (replicas) tracked.
      policy: the shared :class:`DetectorPolicy` (default-constructed
        when omitted).
      on_suspect / on_dead / on_revive: escalation callbacks, each
        ``(lane: int) -> None``; see the module docstring for when they
        fire.  All optional — an unwired detector is a pure classifier.
    """

    def __init__(self, n_lanes: int, policy: Optional[DetectorPolicy] = None,
                 *, on_suspect: Optional[Callable[[int], None]] = None,
                 on_dead: Optional[Callable[[int], None]] = None,
                 on_revive: Optional[Callable[[int], None]] = None):
        if n_lanes < 1:
            raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
        self.n_lanes = int(n_lanes)
        self.policy = policy or DetectorPolicy()
        self.on_suspect = on_suspect
        self.on_dead = on_dead
        self.on_revive = on_revive
        self._state: List[str] = [HEALTHY] * self.n_lanes
        self._slow_streak = [0] * self.n_lanes
        self._fast_streak = [0] * self.n_lanes
        # Per-lane rolling wall-clock history for observe_wall (bounded;
        # allocated eagerly — it's W deques of <= wall_window floats).
        self._wall_hist = [collections.deque(maxlen=self.policy.wall_window)
                           for _ in range(self.n_lanes)]

    # -- observations --------------------------------------------------------

    def observe(self, lane: int, slow: bool) -> str:
        """Feed one observation for ``lane``; returns its (new) state.

        A DEAD lane short-circuits: corpses produce no meaningful
        heartbeats, and their state only changes through
        :meth:`revive`."""
        return self._observe(lane, slow, allow_kill=True)

    def observe_wall(self, lane: int, wall_s: float) -> str:
        """Feed one REAL wall-clock observation (seconds) for ``lane``;
        returns its (new) state.

        The observation is classified against the lane's own rolling
        baseline — the median of its last ``wall_window`` observations —
        as ``slow = wall_s > wall_slow_factor * baseline``, then runs the
        same streak machine as :meth:`observe`, except that wall-driven
        streaks stop at SUSPECTED unless ``policy.wall_kill`` (the wall
        of one SPMD dispatch is a collective signal: it says "this round
        ran slow", not "this lane is at fault", so by default it boosts
        the steal proportion but never kills).  The sample is appended to
        the history AFTER classification (a spike judges against clean
        history; the median keeps later baselines robust to <50 %
        outliers), and no lane is judged before ``max(4,
        wall_window // 4)`` samples exist."""
        self._check_lane(lane)
        if self._state[lane] == DEAD:
            return DEAD
        pol = self.policy
        hist = self._wall_hist[lane]
        min_samples = max(4, pol.wall_window // 4)
        slow = False
        if len(hist) >= min_samples:
            baseline = statistics.median(hist)
            slow = wall_s > pol.wall_slow_factor * baseline
        hist.append(float(wall_s))
        return self._observe(lane, slow, allow_kill=pol.wall_kill)

    def _observe(self, lane: int, slow: bool, *, allow_kill: bool) -> str:
        self._check_lane(lane)
        if self._state[lane] == DEAD:
            return DEAD
        pol = self.policy
        if slow:
            self._slow_streak[lane] += 1
            self._fast_streak[lane] = 0
            streak = self._slow_streak[lane]
            if (allow_kill and pol.dead_after is not None
                    and streak >= pol.dead_after):
                self._state[lane] = DEAD
                if self.on_dead is not None:
                    self.on_dead(lane)
            elif streak >= pol.suspect_after:
                self._state[lane] = SUSPECTED
                # Re-fired on every slow observation past the threshold,
                # so the owner's temporary boost tracks the lag window.
                if self.on_suspect is not None:
                    self.on_suspect(lane)
        else:
            self._fast_streak[lane] += 1
            self._slow_streak[lane] = 0
            if (self._state[lane] == SUSPECTED
                    and self._fast_streak[lane] >= pol.healthy_after):
                self._state[lane] = HEALTHY
        return self._state[lane]

    def revive(self, lane: int) -> None:
        """Clear ``lane`` back to HEALTHY with zeroed streaks (grow,
        re-admission, or the runtime's ``revive_lane``)."""
        self._check_lane(lane)
        was_dead = self._state[lane] == DEAD
        self._state[lane] = HEALTHY
        self._slow_streak[lane] = 0
        self._fast_streak[lane] = 0
        self._wall_hist[lane].clear()
        if was_dead and self.on_revive is not None:
            self.on_revive(lane)

    # -- inspection ----------------------------------------------------------

    def state(self, lane: int) -> str:
        self._check_lane(lane)
        return self._state[lane]

    def states(self) -> List[str]:
        return list(self._state)

    def streak(self, lane: int) -> int:
        """The lane's current consecutive-slow count."""
        self._check_lane(lane)
        return self._slow_streak[lane]

    def _check_lane(self, lane: int) -> None:
        if not (0 <= lane < self.n_lanes):
            raise ValueError(f"lane {lane} out of range [0, {self.n_lanes})")
