"""Unified work-stealing runtime: one entry point for every workload.

This package is the production layer over the paper's data structure and
virtual master.  It exists so that the DAG solver, the serving scheduler
and the benchmarks all drive the *same* queue-operation contract —
:class:`repro.core.ops.BulkOps` — instead of each consumer re-wiring
``core.queue``/``core.master`` by hand.

The BulkOps contract
--------------------
Every queue operation (``push / pop / pop_bulk / steal / steal_exact``)
lives on a backend object with a uniform ``(state, ...) -> (state,
batch, n)`` signature and a ``donate=`` option (jitted, ring donated —
the in-place production call shape).  Backends are registry-named:
``"reference"`` (jnp oracle), ``"pallas"`` (hand-written ring kernels),
``"auto"`` (kernel routing resolved ONCE at construction from the
geometry predicates, honouring the ``REPRO_QUEUE_BACKEND`` environment
override), ``"relaxed"`` (the fence-free multiplicity-tolerant
Castañeda & Piña variant, ``repro.core.relaxed``).  :class:`~repro.runtime.executor.StealRuntime` resolves its
backend at construction (``backend="auto"`` default) and exposes it as
``runtime.ops`` so worker bodies pop/push through the identical routing
the master's steal uses; swapping backends never touches consumer code
— which is how the paper benchmarks implementations against each other.

* :class:`~repro.runtime.executor.StealRuntime` owns a stack of
  per-worker queues (``core.sharded_queue``) and runs
  ``master.superstep`` / ``hierarchical_superstep`` rounds over them,
  optionally interleaved with a user worker body (pop → compute → push).
  ``run_fused(k)`` advances k rounds in one dispatch;
  ``run_fused(k, until_drained=True)`` early-exits on device at drain
  and reports the rounds actually executed.
* :class:`~repro.runtime.adaptive.AdaptiveController` replaces the
  static ``StealPolicy.proportion`` with a feedback loop on the observed
  queue-size imbalance (``RebalanceStats``), fed back as a *traced*
  scalar so re-tuning never recompiles.
* :mod:`~repro.runtime.telemetry` records per-round steal counts,
  transfer bytes and queue-depth histograms
  (:func:`~repro.runtime.telemetry.reduce_round_stats` is the one exact
  per-round reduction both execution modes share).

The round body itself is mode-agnostic
(:func:`~repro.runtime.executor.make_lane_step`):
:class:`repro.distributed.MeshStealRuntime` runs the identical body —
and the identical fused loop — with one queue lane per device under
``shard_map``, bit-identical to the vmapped runtime here.

Resilience (:mod:`~repro.runtime.resilience`): constructing either
runtime with a :class:`~repro.runtime.resilience.FaultPlan` arms
deterministic fault injection (kill/delay/drop schedules that replay
bit-identically in both execution modes) plus the recovery layer — dead
lanes are drained at proportion 1.0 through the ordinary exchange
superstep, queue snapshots (``save_state``/``restore_state``/
``attach_snapshots``) ride :mod:`repro.train.checkpoint` for elastic
crash-resume, and ``kill_lane``/``revive_lane``/``note_straggler`` give
hosts live control (planned eviction, shrink/grow, straggler response).
Failure detection (:mod:`~repro.runtime.detector`):
``runtime.attach_detector(DetectorPolicy(...))`` arms the shared
healthy → suspected → dead state machine that converts slow-round
streaks into proportion boosts and, past ``dead_after``, real
``kill_lane`` escalations — the same policy object the serve admission
masters use for ``auto_evict_after``.

How the paper's single-stealer invariant is preserved
-----------------------------------------------------
The paper requires one owner and (at most) one concurrent stealer per
queue (§II.B).  The executor enforces this at *superstep granularity*:
within one round, a lane's owner ops (the worker body's ``pop_bulk`` /
``push``) complete before the replicated master plan severs at most ONE
tail block per victim (``plan_transfers`` pairs each victim with exactly
one thief), and the spliced inbox lands after the cut.  Because the
whole round is a single deterministic collective schedule, owner and
stealer can never interleave *within* a round, so the paper's
acquire/release and drain re-check machinery is unnecessary — the
conservation property (no task lost or duplicated) is asserted by
``tests/test_runtime.py`` across arbitrary adaptive rounds and every
backend.

Open validation item: the Pallas ring kernels' in-place behaviour
(``input_output_aliases`` + dynamic index_map) is parity-tested in
interpret mode only; confirmation on real TPU hardware remains open
before claiming the in-place splice numbers (see ROADMAP).
"""

from repro.runtime.adaptive import AdaptiveConfig, AdaptiveController
from repro.runtime.detector import DetectorPolicy, FailureDetector
from repro.runtime.executor import StealRuntime
from repro.runtime.resilience import FaultPlan, FaultState
from repro.runtime.telemetry import (RoundRecord, Telemetry, WaveRecord,
                                     item_nbytes)

__all__ = [
    "AdaptiveConfig",
    "AdaptiveController",
    "DetectorPolicy",
    "FailureDetector",
    "FaultPlan",
    "FaultState",
    "StealRuntime",
    "RoundRecord",
    "WaveRecord",
    "Telemetry",
    "item_nbytes",
]
