"""Unified work-stealing runtime: one entry point for every workload.

This package is the production layer over the paper's data structure and
virtual master.  It exists so that the DAG solver, the serving scheduler
and the benchmarks all drive the *same* steal hot path — the Pallas
ring-gather kernel — instead of each consumer re-wiring
``core.queue``/``core.master`` by hand:

* :class:`~repro.runtime.executor.StealRuntime` owns a stack of
  per-worker queues (``core.sharded_queue``) and runs
  ``master.superstep`` / ``hierarchical_superstep`` rounds over them,
  optionally interleaved with a user worker body (pop → compute → push).
* :class:`~repro.runtime.adaptive.AdaptiveController` replaces the
  static ``StealPolicy.proportion`` with a feedback loop on the observed
  queue-size imbalance (``RebalanceStats``), fed back as a *traced*
  scalar so re-tuning never recompiles.
* :mod:`~repro.runtime.telemetry` records per-round steal counts,
  transfer bytes and queue-depth histograms.

How the paper's single-stealer invariant is preserved
-----------------------------------------------------
The paper requires one owner and (at most) one concurrent stealer per
queue (§II.B).  The executor enforces this at *superstep granularity*:
within one round, a lane's owner ops (the worker body's ``pop_bulk`` /
``push``) complete before the replicated master plan severs at most ONE
tail block per victim (``plan_transfers`` pairs each victim with exactly
one thief), and the spliced inbox lands after the cut.  Because the
whole round is a single deterministic collective schedule, owner and
stealer can never interleave *within* a round, so the paper's
acquire/release and drain re-check machinery is unnecessary — the
conservation property (no task lost or duplicated) is asserted by
``tests/test_runtime.py`` across arbitrary adaptive rounds.
"""

from repro.runtime.adaptive import AdaptiveConfig, AdaptiveController
from repro.runtime.executor import StealRuntime
from repro.runtime.telemetry import RoundRecord, Telemetry, item_nbytes

__all__ = [
    "AdaptiveConfig",
    "AdaptiveController",
    "StealRuntime",
    "RoundRecord",
    "Telemetry",
    "item_nbytes",
]
