"""The unified executor: adaptive rebalancing rounds over queue lanes.

``StealRuntime`` is the one entry point every workload drives (DD
branch-and-bound, serving admission replay, the Fig. 7/8 benchmarks).  A
*round* is::

    [worker body: pop_bulk -> compute -> push]   (optional, per lane)
    master.superstep / hierarchical_superstep    (bulk steal rebalance)

compiled ONCE as a single jitted function.  Four properties make it the
production hot path:

* **Kernel-backed queue ops** — the policy is pinned with
  ``use_kernel=True`` (default), so every victim-side block detach goes
  through ``repro.kernels.queue_steal.ring_gather`` and every thief-side
  splice through ``repro.kernels.queue_push.ring_scatter`` (Pallas on
  TPU, the jnp oracles elsewhere).
* **Donated queue state** — the round function donates the stacked
  ``QueueState``, so XLA aliases the ring buffers input->output and the
  rebalance updates in place instead of copying the full-capacity rings
  every superstep (donation is skipped on backends without support).
* **Traced proportion** — the steal proportion enters as a scalar
  argument, so the :class:`~repro.runtime.adaptive.AdaptiveController`
  can re-tune it every round with zero recompiles.
* **Fused supersteps** — :meth:`StealRuntime.run_fused` ``lax.scan``s k
  rounds in ONE dispatch: the adaptive update runs on device inside the
  scan carry and per-round telemetry is stacked ``(k, ...)`` and read
  back once, so autotuning never leaves the device and k rounds cost one
  dispatch + one host sync instead of k of each.

Worker bodies run *under vmap/shard_map* with the runtime's axis name in
scope, so they may use collectives (e.g. ``lax.pmax`` for a global
incumbent) exactly like ``core.dd.parallel`` does.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax import lax

from repro.core import master as master_ops
from repro.core import queue as q_ops
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import make_sharded_queues
from repro.runtime.adaptive import (AdaptiveConfig, AdaptiveController,
                                    adaptive_update)
from repro.runtime.telemetry import Telemetry, item_nbytes

Pytree = Any
WorkerFn = Callable[[q_ops.QueueState, Pytree], Tuple[q_ops.QueueState, Pytree]]

__all__ = ["StealRuntime"]


class StealRuntime:
    """Owns W per-worker queues and drives adaptive rebalancing rounds.

    Args:
      n_workers: number of queue lanes (vmap lanes on one device; one per
        device under shard_map — the round function is mode-agnostic).
      capacity: static ring capacity per lane.
      item_spec: payload pytree of ``ShapeDtypeStruct``/arrays per item.
      policy: base :class:`StealPolicy`; its ``proportion`` seeds the
        adaptive controller, the rest (watermarks, ``max_steal``) is
        static.
      adaptive: enable the steal-proportion feedback loop (default on).
      use_kernel: route steals through the Pallas ring-gather kernel
        (default on — the production path; non-TPU backends fall back to
        the jnp oracle inside the dispatcher).
      pod_size: if set, lanes are grouped into pods of this size and each
        round runs :func:`master.hierarchical_superstep` (intra-pod, then
        cross-pod via lane-0 representatives).
    """

    def __init__(self, n_workers: int, capacity: int, item_spec: Pytree, *,
                 policy: Optional[StealPolicy] = None,
                 adaptive: bool = True,
                 adaptive_config: Optional[AdaptiveConfig] = None,
                 use_kernel: bool = True,
                 axis_name: str = "workers",
                 pod_size: Optional[int] = None,
                 pod_axis: str = "pods"):
        if pod_size is not None and n_workers % pod_size != 0:
            raise ValueError(
                f"n_workers={n_workers} not divisible by pod_size={pod_size}")
        self.n_workers = int(n_workers)
        self.capacity = int(capacity)
        self.item_spec = item_spec
        self.axis_name = axis_name
        self.pod_size = pod_size
        self.pod_axis = pod_axis
        base = policy or StealPolicy()
        self.policy = dataclasses.replace(base, use_kernel=use_kernel)
        self.queues = make_sharded_queues(n_workers, capacity, item_spec)
        self.controller = (AdaptiveController(self.policy, adaptive_config)
                           if adaptive else None)
        self.telemetry = Telemetry(item_bytes=item_nbytes(item_spec),
                                   capacity=capacity)
        self.rounds_run = 0
        self._compiled: Dict[Any, Callable] = {}

    # -- state access --------------------------------------------------------

    @property
    def proportion(self) -> float:
        """The steal proportion the NEXT round will use."""
        return (self.controller.proportion if self.controller
                else self.policy.proportion)

    def sizes(self) -> np.ndarray:
        return np.asarray(self.queues.size)

    def total_size(self) -> int:
        return int(self.sizes().sum())

    # -- host-side seeding / draining ---------------------------------------

    def push(self, worker: int, batch: Pytree, n: int) -> int:
        """Owner-side bulk push into one lane (host-level seeding)."""
        qi = jax.tree_util.tree_map(lambda x: x[worker], self.queues)
        qi, pushed = q_ops.push(qi, batch, jnp.int32(n))
        self.queues = jax.tree_util.tree_map(
            lambda full, one: full.at[worker].set(one), self.queues, qi)
        return int(pushed)

    def drain(self) -> list:
        """Pop every lane dry (host-level; for tests/inspection).  Returns
        a list of per-lane item lists, newest-first per lane."""
        out = []
        for i in range(self.n_workers):
            qi = jax.tree_util.tree_map(lambda x: x[i], self.queues)
            lane = []
            while int(qi.size) > 0:
                qi, item, valid = q_ops.pop(qi)
                assert bool(valid)
                lane.append(jax.tree_util.tree_map(np.asarray, item))
            out.append(lane)
            self.queues = jax.tree_util.tree_map(
                lambda full, one: full.at[i].set(one), self.queues, qi)
        return out

    # -- the round -----------------------------------------------------------

    def _make_step(self, worker_fn: Optional[WorkerFn]) -> Callable:
        """Un-jitted ``(qs, carry, proportion) -> (qs, carry, stats)``."""
        policy = self.policy
        axis_name, pod_axis = self.axis_name, self.pod_axis
        pod_size = self.pod_size

        def lane(q, carry, proportion):
            if worker_fn is not None:
                q, carry = worker_fn(q, carry)
            pol = dataclasses.replace(policy, proportion=proportion)
            if pod_size is not None:
                q, stats = master_ops.hierarchical_superstep(
                    q, pol, worker_axis=axis_name, pod_axis=pod_axis)
            else:
                q, stats = master_ops.superstep(q, pol, axis_name=axis_name)
            return q, carry, stats

        if pod_size is None:
            mapped = jax.vmap(lane, axis_name=axis_name,
                              in_axes=(0, 0, None))

            def step(qs, carry, proportion):
                return mapped(qs, carry, proportion)
        else:
            n_pods = self.n_workers // pod_size
            inner = jax.vmap(lane, axis_name=axis_name, in_axes=(0, 0, None))
            outer = jax.vmap(inner, axis_name=pod_axis, in_axes=(0, 0, None))

            def step(qs, carry, proportion):
                split = jax.tree_util.tree_map(
                    lambda x: x.reshape((n_pods, pod_size) + x.shape[1:]),
                    (qs, carry))
                qs2, carry2, stats = outer(*split, proportion)
                merge = jax.tree_util.tree_map(
                    lambda x: x.reshape((self.n_workers,) + x.shape[2:]),
                    (qs2, carry2, stats))
                return merge

        return step

    @staticmethod
    def _donate_argnums() -> tuple:
        return () if jax.default_backend() == "cpu" else (0,)

    def _compile(self, worker_fn: Optional[WorkerFn]) -> Callable:
        return jax.jit(self._make_step(worker_fn),
                       donate_argnums=self._donate_argnums())

    def _compile_fused(self, worker_fn: Optional[WorkerFn],
                       k: int) -> Callable:
        """One dispatch for k rounds: the superstep scanned on device with
        the adaptive proportion updated as a traced scalar inside the
        carry, telemetry stacked ``(k, ...)`` along the scan axis."""
        step = self._make_step(worker_fn)
        policy, controller = self.policy, self.controller
        config = controller.config if controller else None

        def fused(qs, carry, p0):
            def body(state, _):
                qs, carry, p = state
                qs, carry, stats = step(qs, carry, p)
                tele = {"stats": stats, "sizes": qs.size, "proportion": p}
                if controller is not None:
                    p = adaptive_update(p, qs.size, policy=policy,
                                        config=config)
                return (qs, carry, p), tele

            (qs, carry, p), tele = lax.scan(body, (qs, carry, p0), None,
                                            length=k)
            return qs, carry, p, tele

        return jax.jit(fused, donate_argnums=self._donate_argnums())

    def _round_counts(self, stats) -> Tuple[int, int]:
        """Exact (n_steals, n_transferred) for one round's stats (numpy
        leaves, leading axis = lanes)."""
        if self.pod_size is None:
            # Per-lane stats are replicated in flat mode: element 0 exact.
            return (int(np.asarray(stats.n_steals).reshape(-1)[0]),
                    int(np.asarray(stats.n_transferred).reshape(-1)[0]))
        # Hierarchical mode: lane (p, 0) carries pod p's intra-pod share;
        # the cross-pod share lives in the *_xpod fields, nonzero only on
        # lane-0 representatives and replicated across them — summing
        # intra over pods and adding xpod ONCE is exact (the former
        # upper-bound replication is gone).
        n_pods = self.n_workers // self.pod_size
        rep = lambda x: np.asarray(x).reshape(n_pods, -1)[:, 0]
        n_steals = int(rep(stats.n_steals).sum()) + int(
            rep(stats.n_steals_xpod)[0])
        n_transferred = int(rep(stats.n_transferred).sum()) + int(
            rep(stats.n_transferred_xpod)[0])
        return n_steals, n_transferred

    def round(self, worker_fn: Optional[WorkerFn] = None,
              carry: Optional[Pytree] = None
              ) -> Tuple[Pytree, master_ops.RebalanceStats]:
        """Run one round; feeds telemetry and the adaptive controller.

        ``carry`` is a pytree with a leading ``(n_workers,)`` axis handed
        to ``worker_fn`` per lane (a zero placeholder when omitted).
        Returns ``(carry_out, stats)``.

        The compiled round is cached by ``worker_fn`` *object identity*:
        pass the same function object every round (close over config
        once, outside the loop) — a fresh lambda/partial per call would
        recompile the superstep every round.
        """
        fn = self._compiled.get(worker_fn)
        if fn is None:
            fn = self._compiled[worker_fn] = self._compile(worker_fn)
        if carry is None:
            carry = jnp.zeros((self.n_workers,), jnp.int32)
        proportion = self.proportion
        self.queues, carry, stats = fn(self.queues, carry,
                                       jnp.float32(proportion))
        sizes = self.sizes()
        n_steals, n_transferred = self._round_counts(stats)
        self.telemetry.record(sizes=sizes, n_steals=n_steals,
                              n_transferred=n_transferred,
                              proportion=proportion)
        if self.controller is not None:
            self.controller.update(sizes)
        self.rounds_run += 1
        return carry, stats

    def run_fused(self, k: int, worker_fn: Optional[WorkerFn] = None,
                  carry: Optional[Pytree] = None
                  ) -> Tuple[Pytree, master_ops.RebalanceStats]:
        """Run ``k`` rounds in ONE device dispatch (a ``lax.scan`` over the
        compiled superstep).

        Versus ``k`` calls to :meth:`round`, this removes ``k - 1``
        dispatch + host-sync round trips: the queue state is donated and
        threaded through the scan carry, the adaptive proportion is
        updated on device as a traced scalar
        (:func:`repro.runtime.adaptive.adaptive_update` — the same
        float32 computation the host controller runs, so the trajectory
        is identical), and per-round telemetry is stacked ``(k, ...)``
        along the scan axis and read back once at the end.

        Returns ``(carry_out, stats)`` where ``stats`` leaves carry a
        leading ``(k,)`` round axis.  The same caching rule as
        :meth:`round` applies: pass the same ``worker_fn`` object every
        call — the compiled scan is cached by ``(worker_fn, k)``.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        key = ("fused", worker_fn, k)
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._compiled[key] = self._compile_fused(worker_fn, k)
        if carry is None:
            carry = jnp.zeros((self.n_workers,), jnp.int32)
        p0 = jnp.float32(self.proportion)
        self.queues, carry, p_final, tele = fn(self.queues, carry, p0)
        # ONE host read-back for the whole fused run.
        tele = jax.tree_util.tree_map(np.asarray, tele)
        stats = tele["stats"]
        for r in range(k):
            stats_r = jax.tree_util.tree_map(lambda x: x[r], stats)
            n_steals, n_transferred = self._round_counts(stats_r)
            self.telemetry.record(sizes=tele["sizes"][r],
                                  n_steals=n_steals,
                                  n_transferred=n_transferred,
                                  proportion=float(tele["proportion"][r]))
        if self.controller is not None:
            self.controller.absorb(tele["proportion"], float(p_final))
        self.rounds_run += k
        return carry, stats

    def run(self, worker_fn: Optional[WorkerFn] = None,
            carry: Optional[Pytree] = None, *,
            max_rounds: int = 10_000,
            stop_when_empty: bool = True,
            fused: int = 1) -> Pytree:
        """Drive rounds until the queues drain (or ``max_rounds``).

        With ``fused > 1`` the loop advances ``fused`` rounds per device
        dispatch (:meth:`run_fused`) and only checks the drain condition
        between fused blocks — the single-dispatch superstep pipeline.
        """
        rounds = 0
        while rounds < max_rounds:
            if fused > 1:
                k = min(fused, max_rounds - rounds)
                carry, _ = self.run_fused(k, worker_fn, carry)
                rounds += k
            else:
                carry, _ = self.round(worker_fn, carry)
                rounds += 1
            if stop_when_empty and self.total_size() == 0:
                break
        return carry
