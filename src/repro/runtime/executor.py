"""The unified executor: adaptive rebalancing rounds over queue lanes.

``StealRuntime`` is the one entry point every workload drives (DD
branch-and-bound, serving admission replay, the Fig. 7/8 benchmarks).  A
*round* is::

    [worker body: pop_bulk -> compute -> push]   (optional, per lane)
    master.superstep / hierarchical_superstep    (bulk steal rebalance)

compiled ONCE as a single jitted function.  Four properties make it the
production hot path:

* **One queue contract, pluggable backends** — the runtime resolves a
  :class:`repro.core.ops.BulkOps` backend ONCE at construction
  (``backend="auto"`` consults the kernel geometry predicates; the
  resolved object is exposed as :attr:`StealRuntime.ops`).  Every
  victim-side block detach, thief-side splice and worker-body queue op
  goes through that backend — the Pallas ring kernels when the routing
  resolves to them, the jnp reference oracle otherwise.
* **Donated queue state** — the round function donates the stacked
  ``QueueState``, so XLA aliases the ring buffers input->output and the
  rebalance updates in place instead of copying the full-capacity rings
  every superstep (donation is skipped on backends without support).
* **Traced proportion** — the steal proportion enters as a scalar
  argument, so the :class:`~repro.runtime.adaptive.AdaptiveController`
  can re-tune it every round with zero recompiles.
* **Fused supersteps** — :meth:`StealRuntime.run_fused` ``lax.scan``s k
  rounds in ONE dispatch: the adaptive update runs on device inside the
  scan carry and per-round telemetry is stacked ``(k, ...)`` and read
  back once, so autotuning never leaves the device and k rounds cost one
  dispatch + one host sync instead of k of each.  With
  ``until_drained=True`` the scan becomes a ``lax.while_loop`` that
  stops on device the moment every lane is empty and reports the rounds
  actually executed.

Worker bodies run *under vmap/shard_map* with the runtime's axis name in
scope, so they may use collectives (e.g. ``lax.pmax`` for a global
incumbent) exactly like ``core.dd.parallel`` does.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax import lax

from repro.core import master as master_ops
from repro.core import ops as bulk_ops
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import make_sharded_queues
from repro.runtime import resilience
from repro.runtime.adaptive import (AdaptiveConfig, AdaptiveController,
                                    adaptive_update)
from repro.runtime.resilience import FaultPlan, FaultState
from repro.runtime.telemetry import (Telemetry, item_nbytes,
                                     reduce_round_stats)

Pytree = Any
WorkerFn = Callable[[bulk_ops.QueueState, Pytree],
                    Tuple[bulk_ops.QueueState, Pytree]]

__all__ = ["StealRuntime", "make_lane_step"]


def make_lane_step(policy: StealPolicy, ops: bulk_ops.BulkOps,
                   worker_fn: Optional[WorkerFn], *, axis_name: str,
                   pod_axis: Optional[str] = None,
                   hierarchical: bool = False,
                   fault: bool = False,
                   stage: Optional[str] = None) -> Callable:
    """The mode-agnostic round body for ONE lane:
    ``(q, carry, proportion, ctx) -> (q, carry, stats)``.

    This is the single definition of what a round IS — optional worker
    body, then the rebalancing superstep (flat over ``axis_name``, or
    hierarchical over ``(pod_axis, axis_name)``), with the steal
    proportion injected as a traced scalar.  Both executors build their
    execution mode AROUND it: :class:`StealRuntime` maps it with
    ``jax.vmap(axis_name=...)`` over stacked lanes on one device, and
    :class:`repro.distributed.MeshStealRuntime` runs it per-shard under
    ``shard_map`` over real mesh axes of the same names.  Because the
    collectives resolve through the axis names either way, the two modes
    execute the identical computation — the parity tests assert the
    results are bit-identical.

    ``ctx`` is the fault context (see :mod:`repro.runtime.resilience`):
    a bare int32 round index when ``fault=False`` (ignored by the lane
    body, so the compiled round is unchanged), or the replicated fault
    schedule dict when ``fault=True`` — then the returned lane is
    :func:`~repro.runtime.resilience.make_resilient_lane`, which also
    runs the dead-ring recovery superstep each round (intra-pod recovery
    plus the cross-pod dead-POD escalation when ``hierarchical=True``).

    ``stage`` selects a TRUNCATED PREFIX of the round for the phase
    probe (:mod:`repro.obs.phase`) — ``None`` (the default, the only
    value production dispatches ever use) is the full round above;
    ``"worker"`` stops after the worker body; ``"exchange"`` stops after
    the block-exchange collective (:func:`repro.core.master.
    exchange_probe`).  Prefix lanes return ``(q, carry, token)`` with a
    DCE-proof scalar token in the stats slot; they are compiled into a
    SEPARATE jit cache, run on immutable inputs, and their results are
    discarded — timing them and subtracting attributes wall-clock to
    ``worker_body`` / ``exchange`` / ``splice`` without touching the
    committed round.  On the hierarchical grid ``"exchange"`` covers the
    intra-pod exchange only (the cross-pod level folds into the splice
    share — documented in DESIGN.md §11).
    """
    if stage not in (None, "worker", "exchange"):
        raise ValueError(f"unknown stage {stage!r}")
    if fault:
        return resilience.make_resilient_lane(policy, ops, worker_fn,
                                              axis_name=axis_name,
                                              pod_axis=pod_axis,
                                              hierarchical=hierarchical,
                                              stage=stage)

    def lane(q, carry, proportion, ctx):
        del ctx  # round index only; same signature as the fault lane
        if worker_fn is not None:
            q, carry = worker_fn(q, carry)
        if stage == "worker":
            return q, carry, master_ops.probe_token(q)
        pol = dataclasses.replace(policy, proportion=proportion)
        if stage == "exchange":
            token = master_ops.exchange_probe(q, pol, axis_name=axis_name,
                                              ops=ops)
            return q, carry, token
        if hierarchical:
            q, stats = master_ops.hierarchical_superstep(
                q, pol, worker_axis=axis_name, pod_axis=pod_axis, ops=ops)
        else:
            q, stats = master_ops.superstep(q, pol, axis_name=axis_name,
                                            ops=ops)
        return q, carry, stats

    return lane


class StealRuntime:
    """Owns W per-worker queues and drives adaptive rebalancing rounds.

    Args:
      n_workers: number of queue lanes (vmap lanes on one device; one per
        device under shard_map — the round function is mode-agnostic).
      capacity: static ring capacity per lane.
      item_spec: payload pytree of ``ShapeDtypeStruct``/arrays per item.
      policy: base :class:`StealPolicy`; its ``proportion`` seeds the
        adaptive controller, the rest (watermarks, ``max_steal``) is
        static.
      adaptive: enable the steal-proportion feedback loop (default on).
      backend: optional override for the :class:`~repro.core.ops.BulkOps`
        backend (a registry name or an existing instance).  When omitted
        the runtime honours ``policy.backend`` (default ``"auto"``), so
        a pinned ``StealPolicy(backend="reference")`` selects the same
        implementation here as it does in a standalone
        ``master.superstep``.  ``"auto"`` resolves the kernel routing
        here, once, from the queue geometry (capacity,
        ``policy.max_steal``, and ``max_pop`` for worker-body bulk pops)
        and honours the ``REPRO_QUEUE_BACKEND`` environment override.
        The resolved backend is exposed as :attr:`ops` so worker bodies
        drive the exact same routing.
      max_pop: geometry hint for ``"auto"``: the largest ``max_n`` worker
        bodies will pass to ``ops.pop_bulk`` (None leaves the bulk-pop on
        the reference path).
      pod_size: if set, lanes are grouped into pods of this size and each
        round runs :func:`master.hierarchical_superstep` (intra-pod, then
        cross-pod via lane-0 representatives).
      fault_plan: arm the resilience layer with a deterministic
        :class:`~repro.runtime.resilience.FaultPlan` (kill/delay/drop
        schedule).  An EMPTY ``FaultPlan()`` schedules nothing but still
        arms the machinery — live :meth:`kill_lane`/:meth:`revive_lane`
        (planned eviction, shrink/grow) and the per-round recovery
        superstep that drains dead rings at proportion 1.0.  ``None``
        (default) leaves the compiled round byte-identical to the
        fault-free executor.  Composes with ``pod_size``: on the
        hierarchical grid a dead LANE drains intra-pod, an entirely
        dead POD escalates to a cross-pod recovery plan.
    """

    def __init__(self, n_workers: int, capacity: int, item_spec: Pytree, *,
                 policy: Optional[StealPolicy] = None,
                 adaptive: bool = True,
                 adaptive_config: Optional[AdaptiveConfig] = None,
                 backend: str | bulk_ops.BulkOps | None = None,
                 max_pop: Optional[int] = None,
                 axis_name: str = "workers",
                 pod_size: Optional[int] = None,
                 pod_axis: str = "pods",
                 queue_sharding=None,
                 fault_plan: Optional[FaultPlan] = None):
        if pod_size is not None and n_workers % pod_size != 0:
            raise ValueError(
                f"n_workers={n_workers} not divisible by pod_size={pod_size}")
        self.n_workers = int(n_workers)
        self.capacity = int(capacity)
        self.item_spec = item_spec
        self.axis_name = axis_name
        self.pod_size = pod_size
        self.pod_axis = pod_axis
        base = policy or StealPolicy()
        if backend is None:
            backend = base.backend  # honour a pinned policy.backend
        self.ops = bulk_ops.make_ops(
            backend, capacity=self.capacity, max_push=base.max_steal,
            max_pop=max_pop, max_steal=base.max_steal)
        self.policy = dataclasses.replace(base, backend=self.ops.name)
        # ``queue_sharding`` (a NamedSharding over the lane axis) places
        # each lane's ring on its owning device from the first byte —
        # what the mesh subclass passes; the stack is built sharded, not
        # built dense and re-placed.
        self.queues = make_sharded_queues(n_workers, capacity, item_spec,
                                          sharding=queue_sharding)
        # Sanitizer wiring: REPRO_CHECK=1 (or an explicitly checked
        # backend) turns on per-round invariant checkpoints — stats
        # arithmetic plus, for pure rebalancing rounds, exact multiset
        # conservation of the live items across lanes.
        from repro.analysis.sanitize import CheckedBulkOps

        self._check = isinstance(self.ops, CheckedBulkOps)
        self.controller = (AdaptiveController(self.policy, adaptive_config)
                           if adaptive else None)
        self.telemetry = Telemetry(item_bytes=item_nbytes(item_spec),
                                   capacity=capacity)
        self.rounds_run = 0
        self._compiled: Dict[Any, Callable] = {}
        # Phase probe (repro.obs.phase): truncated-prefix programs live
        # in their OWN cache so elastic.compile_count — which audits
        # ``_compiled`` as the zero-recompile gate — never sees them.
        self._phase_probe = None
        self._probe_compiled: Dict[Any, Callable] = {}
        self._probe_warmed: set = set()
        # Resilience: the host-side fault schedule (None = machinery off,
        # zero trace-structure change) and the snapshot cadence.
        if fault_plan is not None:
            # The dead-lane sentinel (low_watermark + 1) must be neither
            # idle-eligible nor a victim, or masked plans would route
            # work into corpses.
            lo = self.policy.low_watermark + 1
            hi = max(self.policy.high_watermark, self.policy.queue_limit)
            if not (self.policy.low_watermark < lo < hi):
                raise ValueError(
                    f"fault injection needs low_watermark + 1 ="
                    f" {lo} strictly between low_watermark and"
                    f" max(high_watermark, queue_limit) = {hi}")
            self.fault: Optional[FaultState] = FaultState(fault_plan,
                                                          self.n_workers)
            if fault_plan.kills:
                self.telemetry.record_fault("planned_kill",
                                            len(fault_plan.kills))
        else:
            self.fault = None
        # Automatic failure detection (attach_detector): None = off.
        self.detector = None
        self._snapshot_dir: Optional[str] = None
        self._snapshot_every = 0
        self._snapshot_keep = 3
        self._last_snapshot_round = -1

    # -- state access --------------------------------------------------------

    @property
    def proportion(self) -> float:
        """The steal proportion the NEXT round will use (including any
        temporary straggler boost the controller is applying)."""
        return (self.controller.effective_proportion if self.controller
                else self.policy.proportion)

    def sizes(self) -> np.ndarray:
        return np.asarray(self.queues.size)

    def total_size(self) -> int:
        return int(self.sizes().sum())

    # -- host-side seeding / draining ---------------------------------------

    def push(self, worker: int, batch: Pytree, n: int) -> int:
        """Owner-side bulk push into one lane (host-level seeding)."""
        qi = jax.tree_util.tree_map(lambda x: x[worker], self.queues)
        qi, pushed = self.ops.push(qi, batch, jnp.int32(n))
        self.queues = jax.tree_util.tree_map(
            lambda full, one: full.at[worker].set(one), self.queues, qi)
        return int(pushed)

    def drain(self) -> list:
        """Pop every lane dry (host-level; for tests/inspection).  Returns
        a list of per-lane item lists, newest-first per lane."""
        out = []
        for i in range(self.n_workers):
            qi = jax.tree_util.tree_map(lambda x: x[i], self.queues)
            lane = []
            while int(qi.size) > 0:
                qi, item, valid = self.ops.pop(qi)
                assert bool(valid)
                lane.append(jax.tree_util.tree_map(np.asarray, item))
            out.append(lane)
            self.queues = jax.tree_util.tree_map(
                lambda full, one: full.at[i].set(one), self.queues, qi)
        return out

    # -- resilience: live faults, stragglers ---------------------------------

    def _require_fault(self) -> FaultState:
        if self.fault is None:
            raise RuntimeError(
                "fault layer not armed — construct the runtime with "
                "fault_plan=FaultPlan() to enable kill/revive")
        return self.fault

    def kill_lane(self, lane: int, at_round: Optional[int] = None) -> None:
        """Declare lane ``lane`` dead from round ``at_round`` (default:
        the next round).  Its worker body stops producing, it leaves
        every plan, and the recovery superstep drains its ring into the
        survivors at proportion 1.0 over the following rounds.  Pure
        host-side value mutation — no recompile.

        Killing an already-dead lane raises: silently rescheduling a
        corpse's kill round would rewrite replay history (the schedule is
        the determinism contract) and mask double-kill bugs in callers."""
        fault = self._require_fault()
        at = self.rounds_run if at_round is None else at_round
        if bool(fault.dead_at(max(at, self.rounds_run))[lane]):
            raise ValueError(
                f"lane {lane} is already dead (kill_round="
                f"{int(fault.kill_round[lane])}); revive_lane first")
        fault.kill(lane, at)
        self.telemetry.record_fault("kill", lane=lane)

    def revive_lane(self, lane: int) -> None:
        """Re-admit a killed lane (grow / end of eviction): it rejoins
        plans from the next round with whatever its (drained) ring holds.
        Any accumulated straggler penalty for the lane is cleared — a
        revived lane starts with a clean bill of health, not
        pre-penalized by its past life."""
        self._require_fault().revive(lane)
        if self.controller is not None:
            self.controller.clear_straggler(lane)
        if self.detector is not None:
            self.detector.revive(lane)
        self.telemetry.record_fault("revive", lane=lane)

    def dead_lanes(self) -> np.ndarray:
        """(W,) bool: lanes dead as of the next round to run."""
        if self.fault is None:
            return np.zeros((self.n_workers,), bool)
        return self.fault.dead_at(self.rounds_run)

    def note_straggler(self, rounds: int = 4, factor: float = 1.5,
                       lane: Optional[int] = None) -> None:
        """Record a detected straggler (``train.fault.StragglerMonitor``
        wiring): counts into telemetry and temporarily boosts the
        adaptive steal proportion so the master rebalances harder while
        the slow lane lags.  ``lane`` attributes the boost so a later
        :meth:`revive_lane` can clear exactly that lane's penalty."""
        self.telemetry.record_fault("straggler", lane=lane)
        if self.controller is not None:
            self.controller.flag_straggler(rounds=rounds, factor=factor,
                                           lane=lane)

    # -- resilience: automatic failure detection ------------------------------

    def attach_detector(self, policy=None) -> "FailureDetector":
        """Arm the automatic failure detector: per-lane delay streaks from
        the fault schedule (or any external observer calling
        ``detector.observe``) escalate suspected -> dead through ONE
        policy — a suspected lane gets a :meth:`note_straggler`
        proportion boost, a lane past ``dead_after`` consecutive slow
        rounds gets a real :meth:`kill_lane` and its ring drains through
        the recovery superstep.  Requires the fault layer
        (``fault_plan=``).  Returns the detector (also at
        :attr:`detector`)."""
        from repro.runtime.detector import DetectorPolicy, FailureDetector

        self._require_fault()
        pol = policy or DetectorPolicy()

        def on_suspect(lane: int) -> None:
            self.telemetry.record_fault("suspect", lane=lane)
            self.note_straggler(rounds=pol.boost_rounds,
                                factor=pol.boost_factor, lane=lane)

        def on_dead(lane: int) -> None:
            # The user (or an overlapping schedule) may have killed the
            # lane already — the detector's verdict is then moot.
            if not bool(self.dead_lanes()[lane]):
                self.kill_lane(lane)
                self.telemetry.record_fault("auto_kill", lane=lane)

        def on_revive(lane: int) -> None:
            if self.controller is not None:
                self.controller.clear_straggler(lane)

        self.detector = FailureDetector(self.n_workers, pol,
                                        on_suspect=on_suspect,
                                        on_dead=on_dead,
                                        on_revive=on_revive)
        return self.detector

    def _feed_detector(self, round0: int, n_rounds: int,
                       wall_s: Optional[float] = None) -> None:
        """Feed the armed detector one observation per (round, live lane)
        from the replayed delay schedule.  Host-side replay of the same
        replicated schedule the lanes traced — deterministic, so vmap
        and mesh runs convert the same delay windows into the same
        kills at the same rounds (replay parity is preserved).

        When ``DetectorPolicy.wall_clock`` is set, the measured dispatch
        wall (``wall_s``, covering ``n_rounds`` rounds) ALSO feeds each
        live lane's rolling wall baseline via ``observe_wall`` — real
        slowness detection on the runtime path.  The dispatch is one
        SPMD program, so the wall is a collective signal: it cannot
        finger the slow lane, it flags rounds whose whole dispatch ran
        slow against each lane's own history (suspected -> boost; never
        a kill unless ``wall_kill``).  Off by default, keeping CI replay
        determinism and the vmap/mesh parity tests untouched."""
        if self.detector is None or self.fault is None:
            return
        f = self.fault
        for r in range(round0, round0 + n_rounds):
            dead = f.dead_at(r)
            slow = (f.delay_from <= r) & (r < f.delay_until)
            for w in range(self.n_workers):
                if dead[w]:
                    continue  # corpses emit no heartbeats at all
                self.detector.observe(w, bool(slow[w]))
        pol = self.detector.policy
        if (wall_s is not None and n_rounds > 0
                and getattr(pol, "wall_clock", False)):
            per_round = wall_s / n_rounds
            dead = f.dead_at(round0 + n_rounds)
            for w in range(self.n_workers):
                if not dead[w]:
                    self.detector.observe_wall(w, per_round)

    def _controller_sizes(self, sizes: np.ndarray) -> np.ndarray:
        """The size vector the host controller servos on: dead lanes
        masked to the sentinel (mirrors the on-device masking in the
        fused path)."""
        if self.fault is None:
            return sizes
        dead = self.fault.dead_at(self.rounds_run + 1)
        return np.where(dead, np.int32(self.policy.low_watermark + 1),
                        np.asarray(sizes, np.int32))

    # -- resilience: queue snapshot / restore --------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """The checkpointable runtime state: the stacked queues, the
        servo proportion (un-boosted) and the global round counter —
        plus the fault schedule when armed.  Snapshots are taken only at
        round boundaries, which are exactly the consistency points where
        conservation holds (no item is mid-exchange)."""
        if self.controller is not None:
            p = self.controller.proportion
        else:
            p = self.policy.proportion
        out: Dict[str, Any] = {
            "queues": self.queues,
            "proportion": jnp.float32(p),
            "rounds_run": jnp.int32(self.rounds_run),
        }
        if self.fault is not None:
            out["fault"] = {k: jnp.asarray(v)
                            for k, v in self.fault.state_dict().items()}
        return out

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.queues = jax.tree_util.tree_map(jnp.asarray, state["queues"])
        p = float(np.asarray(state["proportion"]))
        if self.controller is not None:
            self.controller.proportion = p
            self.controller.history.append(p)
        self.rounds_run = int(np.asarray(state["rounds_run"]))
        if self.fault is not None and "fault" in state:
            self.fault.load_state({k: np.asarray(v)
                                   for k, v in state["fault"].items()})

    def _state_shardings(self, template: Dict[str, Any]):
        """Shardings for elastic restore of :meth:`state_dict` — None in
        the single-device runtime (plain host arrays); the mesh runtime
        overrides this to place queue lanes on their owning devices."""
        del template
        return None

    def save_state(self, ckpt_dir: str, *, keep: int = 3) -> int:
        """Atomic queue snapshot at the current round boundary (written
        via :mod:`repro.train.checkpoint`: tmp dir + rename, keep-k GC).
        Returns the step (= ``rounds_run``) it was saved under."""
        from repro.train import checkpoint

        extra = {"n_workers": self.n_workers, "capacity": self.capacity,
                 "fault_events": dict(self.telemetry.fault_events),
                 "straggler_steps": self.telemetry.straggler_steps}
        checkpoint.save(ckpt_dir, self.rounds_run, self.state_dict(),
                        extra=extra, keep=keep)
        return self.rounds_run

    def restore_state(self, ckpt_dir: str, *, step: Optional[int] = None
                      ) -> int:
        """Restore queues/proportion/round-counter (and fault schedule)
        from the latest (or given) snapshot.  Elastic: the checkpoint
        holds full host arrays, and :meth:`_state_shardings` re-places
        them onto THIS runtime's devices — a snapshot written under an
        8-device mesh restores onto 1 device or a different mesh shape.
        Returns the restored round index."""
        from repro.train import checkpoint

        template = self.state_dict()
        state, _step, extra = checkpoint.restore(
            ckpt_dir, template, step=step,
            shardings=self._state_shardings(template))
        self.load_state_dict(state)
        for kind, n in (extra.get("fault_events") or {}).items():
            self.telemetry.fault_events.setdefault(kind, 0)
            self.telemetry.fault_events[kind] = max(
                self.telemetry.fault_events[kind], int(n))
        self.telemetry.straggler_steps = max(
            self.telemetry.straggler_steps,
            int(extra.get("straggler_steps", 0)))
        self.telemetry.record_fault("restore")
        self._last_snapshot_round = self.rounds_run
        return self.rounds_run

    def attach_snapshots(self, ckpt_dir: str, *, every: int = 8,
                         keep: int = 3) -> None:
        """Snapshot the queue state every ``every`` rounds (checked after
        each :meth:`round` / :meth:`run_fused` dispatch — always at a
        round boundary, never mid-exchange)."""
        self._snapshot_dir = ckpt_dir
        self._snapshot_every = max(int(every), 1)
        self._snapshot_keep = keep
        self._last_snapshot_round = self.rounds_run

    def _maybe_snapshot(self) -> None:
        if self._snapshot_dir is None:
            return
        if self.rounds_run - self._last_snapshot_round >= self._snapshot_every:
            self.save_state(self._snapshot_dir, keep=self._snapshot_keep)
            self._last_snapshot_round = self.rounds_run

    # -- the round -----------------------------------------------------------

    def _lane_step(self, worker_fn: Optional[WorkerFn],
                   stage: Optional[str] = None) -> Callable:
        """The shared one-lane round body (see :func:`make_lane_step`)."""
        return make_lane_step(self.policy, self.ops, worker_fn,
                              axis_name=self.axis_name,
                              pod_axis=self.pod_axis,
                              hierarchical=self.pod_size is not None,
                              fault=self.fault is not None,
                              stage=stage)

    def _ctx(self, round0: int):
        """The fault context for a dispatch starting at global round
        ``round0``: the replicated schedule dict when the fault layer is
        armed, a bare int32 round index otherwise (both are traced, so
        host-side schedule mutation never recompiles)."""
        if self.fault is not None:
            return self.fault.ctx(round0)
        return jnp.int32(round0)

    def _make_step(self, worker_fn: Optional[WorkerFn],
                   stage: Optional[str] = None) -> Callable:
        """Un-jitted ``(qs, carry, proportion, ctx) -> (qs, carry, stats)``.
        A non-None ``stage`` builds the phase probe's truncated prefix of
        the same round (stats slot holds the DCE-proof token)."""
        pod_size = self.pod_size
        axis_name, pod_axis = self.axis_name, self.pod_axis
        lane = self._lane_step(worker_fn, stage)

        if pod_size is None:
            mapped = jax.vmap(lane, axis_name=axis_name,
                              in_axes=(0, 0, None, None))

            def step(qs, carry, proportion, ctx):
                return mapped(qs, carry, proportion, ctx)
        else:
            n_pods = self.n_workers // pod_size
            inner = jax.vmap(lane, axis_name=axis_name,
                             in_axes=(0, 0, None, None))
            outer = jax.vmap(inner, axis_name=pod_axis,
                             in_axes=(0, 0, None, None))

            def step(qs, carry, proportion, ctx):
                split = jax.tree_util.tree_map(
                    lambda x: x.reshape((n_pods, pod_size) + x.shape[1:]),
                    (qs, carry))
                qs2, carry2, stats = outer(*split, proportion, ctx)
                merge = jax.tree_util.tree_map(
                    lambda x: x.reshape((self.n_workers,) + x.shape[2:]),
                    (qs2, carry2, stats))
                return merge

        return step

    @staticmethod
    def _donate_argnums() -> tuple:
        return () if jax.default_backend() == "cpu" else (0,)

    def _compile(self, worker_fn: Optional[WorkerFn]) -> Callable:
        return jax.jit(self._make_step(worker_fn),
                       donate_argnums=self._donate_argnums())

    def _compile_fused(self, worker_fn: Optional[WorkerFn], k: int,
                       until_drained: bool = False) -> Callable:
        """One dispatch for k rounds: the superstep scanned on device with
        the adaptive proportion updated as a traced scalar inside the
        carry, telemetry stacked ``(k, ...)`` along the scan axis.  With
        ``until_drained`` the scan becomes a ``lax.while_loop`` over the
        same round body that exits as soon as every lane is empty (checked
        on device, before each round), writing telemetry into
        preallocated ``(k, ...)`` slots and returning the executed round
        count."""
        step = self._make_step(worker_fn)
        policy, controller = self.policy, self.controller
        config = controller.config if controller else None

        def one_round(qs, carry, p, ctx):
            qs, carry, stats = step(qs, carry, p, ctx)
            tele = {"stats": stats, "sizes": qs.size, "proportion": p}
            ctx = resilience.ctx_advance(ctx)
            if controller is not None:
                # Dead lanes advertise the sentinel, never counting as
                # idle thieves (same masking the host controller applies).
                sizes = resilience.mask_sizes(qs.size, ctx, policy)
                p = adaptive_update(p, sizes, policy=policy, config=config)
            return qs, carry, p, ctx, tele

        if not until_drained:
            def fused(qs, carry, p0, ctx0):
                def body(state, _):
                    qs, carry, p, ctx = state
                    qs, carry, p, ctx, tele = one_round(qs, carry, p, ctx)
                    return (qs, carry, p, ctx), tele

                (qs, carry, p, _ctx), tele = lax.scan(
                    body, (qs, carry, p0, ctx0), None, length=k)
                return qs, carry, p, tele, jnp.int32(k)

            return jax.jit(fused, donate_argnums=self._donate_argnums())

        def fused(qs, carry, p0, ctx0):
            tele_sds = jax.eval_shape(
                lambda a, b, c, d: one_round(a, b, c, d)[4], qs, carry, p0,
                ctx0)
            tele0 = jax.tree_util.tree_map(
                lambda s: jnp.zeros((k,) + tuple(s.shape), s.dtype), tele_sds)

            def cond(state):
                qs, _carry, _p, _ctx, r, _tele = state
                return (r < k) & (jnp.sum(qs.size) > 0)

            def body(state):
                qs, carry, p, ctx, r, tele = state
                qs, carry, p, ctx, t = one_round(qs, carry, p, ctx)
                tele = jax.tree_util.tree_map(
                    lambda buf, v: lax.dynamic_update_index_in_dim(
                        buf, v, r, 0), tele, t)
                return (qs, carry, p, ctx, r + 1, tele)

            qs, carry, p, _ctx, r, tele = lax.while_loop(
                cond, body, (qs, carry, p0, ctx0, jnp.int32(0), tele0))
            return qs, carry, p, tele, r

        return jax.jit(fused, donate_argnums=self._donate_argnums())

    # -- observability: the phase probe --------------------------------------

    def attach_phase_probe(self, probe=None, **kwargs):
        """Arm per-round phase attribution (:mod:`repro.obs.phase`):
        subsequent :meth:`round` dispatches time the worker/exchange
        prefix programs directly, :meth:`run_fused` blocks split their
        wall by calibrated fractions, and every
        :class:`~repro.runtime.telemetry.RoundRecord` gains the
        ``t_worker``/``t_exchange``/``t_splice``/``t_adaptive`` fields
        (``Telemetry.phase_summary()`` aggregates them).  Pass an
        existing :class:`~repro.obs.phase.PhaseProbe` or constructor
        kwargs (``enabled=``, ``calibrate_every=``).  Returns the probe
        (also at ``_phase_probe``); set ``probe.enabled = False`` to
        disarm without losing calibrations — the dispatch path is then
        byte-identical to an unprobed runtime."""
        from repro.obs.phase import PhaseProbe

        if probe is None:
            probe = PhaseProbe(**kwargs)
        self._phase_probe = probe
        return probe

    def _probe_enabled(self) -> bool:
        return self._phase_probe is not None and self._phase_probe.enabled

    def metrics(self, registry=None):
        """Poll this runtime into a :class:`repro.obs.metrics.
        MetricsRegistry` (queue depths, steal totals, fault/detector
        census, phase attribution when probed).  Pull-style and
        side-effect free — call it mid-run at any cadence;
        ``registry.to_prometheus()`` / ``.snapshot()`` render it."""
        from repro.obs.metrics import runtime_metrics

        return runtime_metrics(self, registry)

    def _probe_fn(self, worker_fn: Optional[WorkerFn],
                  stage: str) -> Callable:
        """The jitted probe program for one stage: ``"worker"`` /
        ``"exchange"`` truncated prefixes, ``"full"`` the complete round
        re-jitted WITHOUT donation (pure — timing it must not invalidate
        the committed inputs), ``"adaptive"`` the full round plus the
        on-device proportion update (so the calibration sees the same
        adaptive arithmetic the fused carry runs)."""
        key = (worker_fn, stage)
        fn = self._probe_compiled.get(key)
        if fn is not None:
            return fn
        if stage in ("worker", "exchange"):
            fn = jax.jit(self._make_step(worker_fn, stage=stage))
        elif stage == "full":
            fn = jax.jit(self._make_step(worker_fn))
        elif stage == "adaptive":
            step = self._make_step(worker_fn)
            policy, controller = self.policy, self.controller
            config = controller.config if controller else None

            def step_a(qs, carry, p, ctx):
                qs, carry, stats = step(qs, carry, p, ctx)
                sizes = resilience.mask_sizes(
                    qs.size, resilience.ctx_advance(ctx), policy)
                p2 = adaptive_update(p, sizes, policy=policy, config=config)
                return qs, carry, stats, p2

            fn = jax.jit(step_a)
        else:
            raise ValueError(f"unknown probe stage {stage!r}")
        self._probe_compiled[key] = fn
        return fn

    def _probe_time(self, worker_fn: Optional[WorkerFn], stage: str,
                    args) -> float:
        """Wall seconds of one probe program on ``args`` (result
        discarded).  The first call per (worker_fn, stage) runs once
        untimed so compilation never pollutes a measurement."""
        from repro.obs.phase import timed_call

        key = (worker_fn, stage)
        fn = self._probe_fn(worker_fn, stage)
        if key not in self._probe_warmed:
            jax.block_until_ready(fn(*args))
            self._probe_warmed.add(key)
        t, _ = timed_call(fn, args)
        return t

    def _probe_calibrate(self, worker_fn: Optional[WorkerFn], args) -> None:
        """Refresh the fused-attribution fractions for ``worker_fn`` by
        timing the four probe programs on the current state (pure, all
        results discarded)."""
        t_worker = self._probe_time(worker_fn, "worker", args)
        t_exchange = self._probe_time(worker_fn, "exchange", args)
        t_full = self._probe_time(worker_fn, "full", args)
        if self.controller is not None:
            t_adaptive = self._probe_time(worker_fn, "adaptive", args)
        else:
            t_adaptive = t_full
        self._phase_probe.store_calibration(
            worker_fn,
            (t_worker, t_exchange - t_worker, t_full - t_exchange,
             t_adaptive - t_full),
            self.rounds_run)

    def _round_counts(self, stats) -> Tuple[int, int, int]:
        """Exact (n_steals, n_transferred, bytes_moved) for one round's
        stats (numpy leaves, leading axis = lanes) — the shared
        :func:`repro.runtime.telemetry.reduce_round_stats` reduction,
        identical for vmap-stacked lanes and shard_map-gathered shards."""
        return reduce_round_stats(stats, n_workers=self.n_workers,
                                  pod_size=self.pod_size)

    def _pre_dispatch_snapshot(self, worker_fn):
        """When the sanitizer is on and the dispatch is a pure rebalance
        (no worker body creating/consuming items), fingerprint the live
        items so the post-dispatch check can assert exact conservation."""
        if not self._check or worker_fn is not None:
            return None
        from repro.analysis import sanitize

        return sanitize.queues_fingerprint(self.queues)

    def _post_dispatch_checks(self, round_stats, snap, *, context) -> None:
        """Sanitizer checkpoint after a dispatch's host read-back: stats
        arithmetic per round, multiset conservation for pure rebalances,
        then surface anything the in-trace callbacks recorded."""
        from repro.analysis import sanitize

        for stats_r in round_stats:
            sanitize.check_round_stats(
                stats_r, n_workers=self.n_workers, capacity=self.capacity,
                pod_size=self.pod_size, context=context)
        if snap is not None:
            sanitize.check_conserved(
                snap, sanitize.queues_fingerprint(self.queues),
                context=context)
        sanitize.raise_pending(context)

    def round(self, worker_fn: Optional[WorkerFn] = None,
              carry: Optional[Pytree] = None
              ) -> Tuple[Pytree, master_ops.RebalanceStats]:
        """Run one round; feeds telemetry and the adaptive controller.

        ``carry`` is a pytree with a leading ``(n_workers,)`` axis handed
        to ``worker_fn`` per lane (a zero placeholder when omitted).
        Returns ``(carry_out, stats)``.

        The compiled round is cached by ``worker_fn`` *object identity*:
        pass the same function object every round (close over config
        once, outside the loop) — a fresh lambda/partial per call would
        recompile the superstep every round.
        """
        fn = self._compiled.get(worker_fn)
        if fn is None:
            fn = self._compiled[worker_fn] = self._compile(worker_fn)
        if carry is None:
            carry = jnp.zeros((self.n_workers,), jnp.int32)
        snap = self._pre_dispatch_snapshot(worker_fn)
        proportion = self.proportion
        probed = self._probe_enabled()
        args = (self.queues, carry, jnp.float32(proportion),
                self._ctx(self.rounds_run))
        t_worker = t_exchange = 0.0
        if probed:
            # Direct attribution: time the worker and exchange PREFIX
            # programs on the immutable inputs the committed round is
            # about to consume (pure, results discarded), then fence the
            # unchanged full round.
            jax.block_until_ready(args)
            t_worker = self._probe_time(worker_fn, "worker", args)
            t_exchange = self._probe_time(worker_fn, "exchange", args)
        t0 = time.perf_counter()
        self.queues, carry, stats = fn(*args)
        if probed:
            jax.block_until_ready((self.queues, carry, stats))
        sizes = self.sizes()
        wall_s = time.perf_counter() - t0
        n_steals, n_transferred, bytes_moved = self._round_counts(stats)
        if self._check:
            self._post_dispatch_checks(
                [jax.tree_util.tree_map(np.asarray, stats)], snap,
                context="StealRuntime.round")
        t_a0 = time.perf_counter()
        if self.controller is not None:
            self.controller.update(self._controller_sizes(sizes))
        phases = None
        if probed:
            phases = self._phase_probe.direct_sample(
                t_worker=t_worker, t_exchange=t_exchange, t_full=wall_s,
                t_adaptive=time.perf_counter() - t_a0).as_record()
        self.telemetry.record(sizes=sizes, n_steals=n_steals,
                              n_transferred=n_transferred,
                              proportion=proportion,
                              bytes_moved=bytes_moved,
                              phases=phases)
        r0 = self.rounds_run
        self.rounds_run += 1
        self._feed_detector(r0, 1, wall_s=wall_s)
        self._maybe_snapshot()
        return carry, stats

    def run_fused(self, k: int, worker_fn: Optional[WorkerFn] = None,
                  carry: Optional[Pytree] = None, *,
                  until_drained: bool = False):
        """Run up to ``k`` rounds in ONE device dispatch.

        Versus ``k`` calls to :meth:`round`, this removes ``k - 1``
        dispatch + host-sync round trips: the queue state is donated and
        threaded through the on-device loop, the adaptive proportion is
        updated on device as a traced scalar
        (:func:`repro.runtime.adaptive.adaptive_update` — the same
        float32 computation the host controller runs, so the trajectory
        is identical), and per-round telemetry is stacked ``(k, ...)``
        and read back once at the end.

        With ``until_drained=False`` (default) the block is a
        ``lax.scan`` of exactly ``k`` rounds, returning
        ``(carry_out, stats)`` where ``stats`` leaves carry a leading
        ``(k,)`` round axis.  With ``until_drained=True`` the block is a
        ``lax.while_loop`` that exits early once every lane is empty
        (checked on device before each round — a drained workload costs
        zero no-op rounds) and returns ``(carry_out, stats, rounds)``
        where ``rounds <= k`` is the number actually executed and
        ``stats`` leaves carry a leading ``(rounds,)`` axis.

        The same caching rule as :meth:`round` applies: pass the same
        ``worker_fn`` object every call — the compiled block is cached
        by ``(worker_fn, k, until_drained)``.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        key = ("fused", worker_fn, k, until_drained)
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._compiled[key] = self._compile_fused(
                worker_fn, k, until_drained)
        if carry is None:
            carry = jnp.zeros((self.n_workers,), jnp.int32)
        snap = self._pre_dispatch_snapshot(worker_fn)
        p0 = jnp.float32(self.proportion)
        probed = self._probe_enabled()
        args = (self.queues, carry, p0, self._ctx(self.rounds_run))
        if probed:
            # Calibrated attribution: refresh the phase fractions on the
            # current state when stale (four pure prefix dispatches per
            # calibrate_every rounds), fence, then time the one real
            # dispatch end to end.
            jax.block_until_ready(args)
            if self._phase_probe.needs_calibration(worker_fn,
                                                   self.rounds_run):
                self._probe_calibrate(worker_fn, args)
        from repro.obs.phase import trace_span

        t0 = time.perf_counter()
        with trace_span(f"run_fused_k{k}"):
            self.queues, carry, p_final, tele, rounds = fn(*args)
            rounds = int(rounds)
            # ONE host read-back for the whole fused run.
            tele = jax.tree_util.tree_map(np.asarray, tele)
        wall_s = time.perf_counter() - t0
        stats = tele["stats"]
        per_round_s = wall_s / rounds if rounds > 0 else 0.0
        phases = None
        if probed and rounds > 0:
            # One sample reused for every round of the block — the split
            # is the same cached fractions either way, and ``record``
            # copies the values out.
            phases = self._phase_probe.estimated_sample(
                worker_fn, per_round_s, n=rounds).as_record()
        for r in range(rounds):
            stats_r = jax.tree_util.tree_map(lambda x: x[r], stats)
            n_steals, n_transferred, bytes_moved = self._round_counts(stats_r)
            self.telemetry.record(sizes=tele["sizes"][r],
                                  n_steals=n_steals,
                                  n_transferred=n_transferred,
                                  proportion=float(tele["proportion"][r]),
                                  bytes_moved=bytes_moved,
                                  phases=phases)
        if self._check:
            self._post_dispatch_checks(
                [jax.tree_util.tree_map(lambda x, _r=r: x[_r], stats)
                 for r in range(rounds)], snap,
                context=f"StealRuntime.run_fused[{rounds} rounds]")
        if self.controller is not None and rounds > 0:
            self.controller.absorb(tele["proportion"][:rounds],
                                   float(p_final))
        r0 = self.rounds_run
        self.rounds_run += rounds
        self._feed_detector(r0, rounds, wall_s=wall_s)
        self._maybe_snapshot()
        if until_drained:
            stats = jax.tree_util.tree_map(lambda x: x[:rounds], stats)
            return carry, stats, rounds
        return carry, stats

    def run(self, worker_fn: Optional[WorkerFn] = None,
            carry: Optional[Pytree] = None, *,
            max_rounds: int = 10_000,
            stop_when_empty: bool = True,
            fused: int = 1) -> Pytree:
        """Drive rounds until the queues drain (or ``max_rounds``).

        With ``fused > 1`` the loop advances up to ``fused`` rounds per
        device dispatch (:meth:`run_fused`); when ``stop_when_empty`` the
        fused block early-exits on device the moment every lane drains,
        so the trailing block never runs no-op rounds.
        """
        rounds = 0
        while rounds < max_rounds:
            if fused > 1:
                k = min(fused, max_rounds - rounds)
                if stop_when_empty:
                    carry, _, executed = self.run_fused(
                        k, worker_fn, carry, until_drained=True)
                    rounds += max(executed, 1)
                    if executed < k:
                        break
                else:
                    carry, _ = self.run_fused(k, worker_fn, carry)
                    rounds += k
            else:
                carry, _ = self.round(worker_fn, carry)
                rounds += 1
                if stop_when_empty and self.total_size() == 0:
                    break
        return carry
