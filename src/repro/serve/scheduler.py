"""Serving scheduler: per-replica request queues + single-master bulk steal.

The paper's master-worker discipline applied to inference admission:

* each model REPLICA owns a request queue (one owner: the replica's
  engine loop popping work; one stealer: the admission master);
* new requests are admitted in BULK to the least-loaded replica (one
  splice — constant latency in the batch size, Fig. 6's property);
* when a replica drains below the low watermark while another is above
  the high watermark, the master steals ``proportion`` of the busy
  replica's TAIL — the oldest requests, which preserves the busy
  replica's locality with its in-flight wave (the paper's
  locality-aware redistribution argument, §II.B).

Queues are host-level and pluggable behind the
:class:`repro.core.host_queue.HostQueue` protocol — the host analogue of
the device layer's ``BulkOps`` backends; the default is the faithful
paper port (``LinkedWSQueue``), and ``AdmissionMaster(queue_factory=...)``
swaps in any other implementation (the Taskflow-style baselines, a
device-backed ``PagedQueue``) without touching the master.  The steal
proportion and observability come from the same runtime layer the
device executor uses (``repro.runtime.adaptive`` / ``.telemetry``): the
master servos its proportion with the SAME float32 feedback step
(``adaptive_update``) the device executor scans inside
``StealRuntime.run_fused``, and logs per-round steal counts and depth
histograms.  ``rebalance_many(k)`` mirrors the executor's fused
supersteps at host level: k rounds per controller tick, stopping early
once a round moves nothing.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.host_queue import HostQueue, LinkedWSQueue
from repro.core.policy import StealPolicy
from repro.runtime.adaptive import AdaptiveConfig, AdaptiveController
from repro.runtime.telemetry import Telemetry

__all__ = ["Request", "ReplicaQueue", "AdmissionMaster"]

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new: int = 16
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    output: Optional[List[int]] = None


class ReplicaQueue:
    def __init__(self, replica_id: int,
                 queue_factory: Callable[[], HostQueue] = LinkedWSQueue):
        self.replica_id = replica_id
        self.q: HostQueue = queue_factory()
        self.in_flight = 0
        self.completed = 0
        self.evicted = False

    def load(self) -> int:
        return len(self.q) + self.in_flight

    def pop_wave(self, max_wave: int) -> List[Request]:
        wave = []
        while len(wave) < max_wave:
            r = self.q.pop_item()
            if r is None:
                break
            wave.append(r)
        self.in_flight += len(wave)
        return wave

    def finish_wave(self, n: int):
        self.in_flight -= n
        self.completed += n


class AdmissionMaster:
    """The single stealer + admission router."""

    def __init__(self, n_replicas: int, policy: Optional[StealPolicy] = None,
                 adaptive: bool = True,
                 adaptive_config: Optional[AdaptiveConfig] = None,
                 queue_factory: Callable[[], HostQueue] = LinkedWSQueue):
        self.replicas = [ReplicaQueue(i, queue_factory)
                         for i in range(n_replicas)]
        self.policy = policy or StealPolicy(proportion=0.5,
                                            low_watermark=1,
                                            high_watermark=8)
        self.controller = (AdaptiveController(self.policy, adaptive_config)
                           if adaptive else None)
        self.telemetry = Telemetry()  # item_bytes unknown host-side: counts
        self.stolen = 0
        self.rounds = 0
        # Automatic failure detection (attach_detector): None = off.
        self.detector = None

    @property
    def proportion(self) -> float:
        return (self.controller.effective_proportion if self.controller
                else self.policy.proportion)

    # -- admission -----------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> int:
        """Bulk-admit to the least-loaded replica (ONE splice)."""
        live = [r for r in self.replicas if not r.evicted]
        if not live:
            raise RuntimeError("every replica is evicted; nothing can admit")
        target = min(live, key=lambda r: r.load())
        # push_bulk's deque convention (later = newer): the engine pops
        # the newest request first while the oldest sit at the tail —
        # exactly what the master's locality-preserving tail steal wants.
        target.q.push_bulk(list(requests))
        return target.replica_id

    # -- planned eviction ----------------------------------------------------

    def evict(self, replica_id: int) -> int:
        """Planned eviction: drain replica ``replica_id``'s whole queue
        onto the least-loaded live replica (the host analogue of the
        executors' proportion-1.0 recovery plan), then mark it out of
        admission and rebalancing.  The drain is OWNER-side (pop + one
        bulk splice): a stealer-side proportion-1.0 cut skips zero nodes,
        which the §IV interference guard always aborts — and eviction is
        the master acting on a queue it owns, not a racing stealer.
        In-flight requests finish where they are; the engine stops
        handing the replica new waves.  Returns the number of requests
        drained."""
        victim = self.replicas[replica_id]
        live = [r for r in self.replicas
                if not r.evicted and r.replica_id != replica_id]
        if not live:
            raise RuntimeError("cannot evict the last live replica")
        items = []
        while True:
            item = victim.q.pop_item()
            if item is None:
                break
            items.append(item)
        items.reverse()  # pops came newest-first; re-push oldest-first
        if items:
            target = min(live, key=lambda r: r.load())
            target.q.push_bulk(items)
        victim.evicted = True
        self.telemetry.record_fault("evict")
        return len(items)

    def readmit(self, replica_id: int) -> None:
        """Re-admit an evicted replica: it rejoins admission and the
        idle side of rebalancing from the next round, with any detector
        state and straggler penalty cleared (clean bill of health)."""
        self.replicas[replica_id].evicted = False
        if self.detector is not None:
            self.detector.revive(replica_id)
        if self.controller is not None:
            self.controller.clear_straggler(replica_id)
        self.telemetry.record_fault("readmit")

    def note_straggler(self, rounds: int = 4, factor: float = 1.5,
                       lane: Optional[int] = None) -> None:
        """A replica was flagged slow: count it and temporarily boost the
        steal proportion (same response the device runtime applies).
        ``lane`` attributes the boost so :meth:`readmit` can clear it."""
        self.telemetry.record_fault("straggler")
        if self.controller is not None:
            self.controller.flag_straggler(rounds=rounds, factor=factor,
                                           lane=lane)

    def attach_detector(self, policy=None):
        """Arm the shared :class:`repro.runtime.detector.FailureDetector`
        escalation policy on this master: a SUSPECTED replica gets the
        straggler proportion boost, a DEAD one a real :meth:`evict`
        (recorded as ``auto_evict``).  The owner feeds observations
        (``master.detector.observe(rid, slow)``); :meth:`readmit`
        revives.  Returns the detector (also at :attr:`detector`)."""
        from repro.runtime.detector import DetectorPolicy, FailureDetector

        pol = policy or DetectorPolicy()

        def on_suspect(rid: int) -> None:
            self.note_straggler(rounds=pol.boost_rounds,
                                factor=pol.boost_factor, lane=rid)

        def on_dead(rid: int) -> None:
            if not self.replicas[rid].evicted:
                self.evict(rid)
                self.telemetry.record_fault("auto_evict")

        def on_revive(rid: int) -> None:
            if self.controller is not None:
                self.controller.clear_straggler(rid)

        self.detector = FailureDetector(len(self.replicas), pol,
                                        on_suspect=on_suspect,
                                        on_dead=on_dead,
                                        on_revive=on_revive)
        return self.detector

    # -- rebalancing ---------------------------------------------------------

    def rebalance(self) -> int:
        """One master round: pair drained replicas with overloaded ones and
        bulk-steal the victim's tail.  At most one steal per victim per
        round (single-stealer invariant).  Evicted replicas are neither
        thieves nor victims."""
        self.rounds += 1
        pol = self.policy
        proportion = self.proportion
        idle = sorted((r for r in self.replicas
                       if not r.evicted and len(r.q) <= pol.low_watermark),
                      key=lambda r: r.load())
        busy = sorted((r for r in self.replicas
                       if not r.evicted and len(r.q) >= pol.high_watermark),
                      key=lambda r: -len(r.q))
        moved = 0
        n_steals = 0
        for thief, victim in zip(idle, busy):
            stolen = victim.q.steal_bulk(proportion)
            if not stolen:
                continue
            thief.q.push_bulk(stolen)
            moved += len(stolen)
            n_steals += 1
        self.stolen += moved
        sizes = [len(r.q) for r in self.replicas]
        self.telemetry.record(sizes=sizes, n_steals=n_steals,
                              n_transferred=moved, proportion=proportion)
        if self.controller is not None:
            self.controller.update(sizes)
        return moved

    def rebalance_many(self, k: int) -> int:
        """Run up to ``k`` rebalance rounds in one controller tick (the
        host-level analogue of ``StealRuntime.run_fused``), stopping
        early once a round moves nothing — a severely imbalanced cluster
        converges in one tick instead of one round per tick.  Returns
        total requests moved."""
        moved = 0
        for _ in range(k):
            step = self.rebalance()
            moved += step
            if step == 0:
                break
        return moved

    def stats(self) -> Dict:
        return {
            "loads": [r.load() for r in self.replicas],
            "queued": [len(r.q) for r in self.replicas],
            "completed": [r.completed for r in self.replicas],
            "evicted": [r.replica_id for r in self.replicas if r.evicted],
            "stolen": self.stolen,
            "rounds": self.rounds,
            "proportion": self.proportion,
            "telemetry": self.telemetry.summary(),
        }

    def metrics(self, registry=None):
        """Poll this master into a :class:`repro.obs.metrics.
        MetricsRegistry` (per-replica loads, steal totals, SLO
        percentiles, detector census) — pull-style, callable mid-run."""
        from repro.obs.metrics import master_metrics

        return master_metrics(self, registry)
