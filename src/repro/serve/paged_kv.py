"""Paged KV-cache block manager for decode-step serving.

vLLM-style paging adapted to the steal runtime's lane discipline: each
queue LANE owns one fixed page pool per attention layer group
(``(n_pages + 1, NG, page_size, K, hd)`` — the extra page is the trash
page inactive slots point at), a page table ``(n_slots, pages_per_seq)``
of page ids, and an owner vector ``(n_pages,)`` mapping each physical
page back to the slot holding it (-1 = free).  Every operation here is
pure jnp over those arrays, so the allocator runs INSIDE the decode
worker body — under ``jax.vmap`` lanes or per-device under ``shard_map``
— and page pressure becomes a real, traced scheduling signal: a slot
whose next page cannot be allocated this round simply stalls.

Lane ownership invariant: a page is referenced by at most one live slot
of its own lane, pages never alias across lanes, and a finished slot's
pages return to the free list in the SAME round its output record is
pushed (continuous batching: freeing and admission happen in one round).
A bulk steal of QUEUED requests moves no pages (queued items are
KV-free prefill work); migrating an IN-FLIGHT request moves its pages
with it (:func:`repro.serve.decode.DecodeCluster` implements both, see
``DecodePolicy.steal``).

The host-facing helpers build on ``serve/kv_cache.py``:
:func:`cache_to_pages` uses :func:`~repro.serve.kv_cache.pad_cache` to
round a prefill cache up to a page multiple before splitting it into
pages, and :func:`pool_token_count` delegates its accounting convention
to :func:`~repro.serve.kv_cache.cache_tokens`.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.serve.kv_cache import cache_tokens, pad_cache

Pytree = Any

__all__ = ["pages_for", "make_pool", "alloc_pages", "free_pages",
           "gather_slot_caches", "scatter_slot_caches", "cache_to_pages",
           "pages_to_cache", "pool_token_count", "PagedKVError"]

_tmap = jax.tree_util.tree_map


class PagedKVError(ValueError):
    """Raised when a model/policy combination cannot be paged."""


def pages_for(seq_len: int, page_size: int) -> int:
    """Pages needed to hold ``seq_len`` KV rows."""
    return -(-int(seq_len) // int(page_size))


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------


def make_pool(model, *, n_slots: int, n_pages: int, page_size: int,
              pages_per_seq: int) -> Dict[str, Any]:
    """One lane's paged-KV state (no lane axis; stack for W lanes).

    Returns a dict with:
      ``pages``: per layer-group ``{"k"/"v": (n_pages + 1, NG, page_size,
        K, hd)}`` — page ``n_pages`` is the trash page unseated table
        entries point at (its content is never read unmasked).
      ``table``: ``(n_slots, pages_per_seq)`` int32 page ids.
      ``owner``: ``(n_pages,)`` int32 owning slot per page, -1 = free.

    Only linear (global-attention) caches page cleanly — a sliding-window
    ring cache re-layouts slots as ``pos % C`` which breaks the
    page-id -> position mapping — so windowed layer kinds are rejected.
    """
    probe = int(page_size) * max(int(pages_per_seq), 2)
    for kind in model.layer_kinds:
        if model.cache_len(kind, probe) != probe:
            raise PagedKVError(
                f"layer kind {kind!r} uses a ring (windowed) cache; paged "
                f"decode requires linear caches — use a no-window config "
                f"(e.g. configs.reduced drops the window)")
    proto = model.make_cache(1, int(page_size))  # leaves (NG, 1, ps, K, hd)
    pages = {
        g: _tmap(lambda x: jnp.zeros(
            (int(n_pages) + 1, x.shape[0]) + x.shape[2:], x.dtype), kv)
        for g, kv in proto.items() if g != "pos"
    }
    return {
        "pages": pages,
        "table": jnp.full((int(n_slots), int(pages_per_seq)),
                          jnp.int32(n_pages)),
        "owner": jnp.full((int(n_pages),), jnp.int32(-1)),
    }


# ---------------------------------------------------------------------------
# Traced allocator (runs inside the decode worker body)
# ---------------------------------------------------------------------------


def alloc_pages(table: jnp.ndarray, owner: jnp.ndarray,
                n_alloc: jnp.ndarray, need: jnp.ndarray, page_idx: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Grant one page to each needing slot, free list permitting.

    Args:
      table: ``(n_slots, pages_per_seq)`` page ids.
      owner: ``(n_pages,)`` owning slot per page (-1 free).
      n_alloc: ``(n_slots,)`` pages currently held per slot.
      need: ``(n_slots,)`` bool — slot wants one more page this round.
      page_idx: ``(n_slots,)`` the table column the new page fills
        (``pos // page_size``).

    Pure jnp: the i-th needing slot (slot order) takes the i-th free
    page (page order) — a deterministic rank-matching that every
    execution mode computes identically.  Slots beyond the free-page
    supply are simply not granted (their ``n_alloc`` is unchanged, so
    the caller's ``advance`` mask stalls them — page-pressure
    back-pressure, not an error).  Returns ``(table, owner, n_alloc)``.
    """
    n_slots = table.shape[0]
    n_pages = owner.shape[0]
    free = owner < 0
    n_need = jnp.sum(need.astype(jnp.int32))
    n_free = jnp.sum(free.astype(jnp.int32))
    # i-th needing slot <-> i-th free page.
    slot_order = jnp.argsort(~need)                    # needing slots first
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1  # rank among free
    assign = free & (free_rank < n_need)
    slot_of_page = slot_order[jnp.clip(free_rank, 0, n_slots - 1)]
    owner = jnp.where(assign, slot_of_page, owner)
    # Scatter granted page ids into the table; non-assigned rows are
    # routed out of bounds and dropped (duplicate-index safe).
    row = jnp.where(assign, slot_of_page, jnp.int32(n_slots))
    col = page_idx[jnp.clip(slot_of_page, 0, n_slots - 1)]
    table = table.at[row, col].set(jnp.arange(n_pages, dtype=jnp.int32),
                                   mode="drop")
    need_rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    granted = need & (need_rank < n_free)
    n_alloc = n_alloc + granted.astype(jnp.int32)
    return table, owner, n_alloc


def free_pages(table: jnp.ndarray, owner: jnp.ndarray, n_alloc: jnp.ndarray,
               retire: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return every page owned by a retiring slot to the free list, in
    the same round the slot's output record is pushed.  Returns
    ``(table, owner, n_alloc)`` with retired rows pointing at trash."""
    n_slots, _ = table.shape
    n_pages = owner.shape[0]
    retire_pad = jnp.concatenate(
        [retire, jnp.zeros((1,), retire.dtype)])      # guard for owner = -1
    freed = (owner >= 0) & retire_pad[jnp.clip(owner, 0, n_slots)]
    owner = jnp.where(freed, jnp.int32(-1), owner)
    table = jnp.where(retire[:, None], jnp.int32(n_pages), table)
    n_alloc = jnp.where(retire, jnp.int32(0), n_alloc)
    return table, owner, n_alloc


# ---------------------------------------------------------------------------
# Traced gather / scatter between the pool and per-slot caches
# ---------------------------------------------------------------------------


def gather_slot_caches(pages: Dict[str, Any], table: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict[str, Any]:
    """Assemble every slot's contiguous batch-1 cache from its pages.

    Returns ``{"pos": (S,), "g*": {"k"/"v": (S, NG, 1, C, K, hd)}}`` —
    the per-slot cache pytree ``jax.vmap(model.decode_step)`` consumes.
    Rows at positions >= ``pos`` are zeroed: they are either unwritten
    or trash-page garbage, and zeroing them makes the gathered cache a
    deterministic function of the decode history alone (bit-identical
    across execution modes, immune to trash-page write order).
    """
    S, PP = table.shape
    out: Dict[str, Any] = {"pos": pos}

    def one(leaf):  # (n_pages + 1, NG, ps, K, hd)
        ps = leaf.shape[2]
        x = leaf[table]                        # (S, PP, NG, ps, K, hd)
        x = jnp.moveaxis(x, 2, 1)              # (S, NG, PP, ps, K, hd)
        x = x.reshape(x.shape[0], x.shape[1], PP * ps, *x.shape[4:])
        rows = jnp.arange(PP * ps, dtype=jnp.int32)
        valid = rows[None, :] < pos[:, None]   # (S, C)
        x = jnp.where(valid[:, None, :, None, None], x, 0)
        return x[:, :, None]                   # (S, NG, 1, C, K, hd)

    for g, kv in pages.items():
        out[g] = _tmap(one, kv)
    return out


def scatter_slot_caches(pages: Dict[str, Any], table: jnp.ndarray,
                        old: Dict[str, Any], new: Dict[str, Any],
                        select: jnp.ndarray) -> Dict[str, Any]:
    """Write every slot's (possibly updated) cache back into its pages.

    ``old``/``new`` are gather-layout caches (``(S, NG, 1, C, K, hd)``
    leaves); slot s writes ``new`` where ``select[s]`` else ``old``.
    Live slots own disjoint pages so the scatter is order-free there;
    duplicate writes only ever land on the trash page, whose content is
    never read unmasked (see :func:`gather_slot_caches`).
    """
    S, PP = table.shape
    idx = table.reshape(-1)

    def one(pool_leaf, old_leaf, new_leaf):
        ps = pool_leaf.shape[2]
        sel = select.reshape((S,) + (1,) * (old_leaf.ndim - 1))
        x = jnp.where(sel, new_leaf, old_leaf)   # (S, NG, 1, C, K, hd)
        x = x[:, :, 0]                           # (S, NG, C, K, hd)
        x = x.reshape(x.shape[0], x.shape[1], PP, ps, *x.shape[3:])
        x = jnp.moveaxis(x, 1, 2)                # (S, PP, NG, ps, K, hd)
        x = x.reshape((S * PP,) + x.shape[2:])
        return pool_leaf.at[idx].set(x)

    return {
        g: jax.tree_util.tree_map(
            lambda p, o, n: one(p, o, n), kv, old[g], new[g])
        for g, kv in pages.items()
    }


# ---------------------------------------------------------------------------
# Host-facing conversions (the kv_cache.py helpers, used for real)
# ---------------------------------------------------------------------------


def cache_to_pages(cache: Pytree, page_size: int) -> Pytree:
    """Split a batch-1 model cache into page-major arrays.

    Pads the sequence axis up to a page multiple first (via
    :func:`~repro.serve.kv_cache.pad_cache` — zero rows are masked by
    position on read), then reshapes each ``(NG, 1, C, K, hd)`` leaf to
    ``(P, NG, page_size, K, hd)``.  Inverse of :func:`pages_to_cache`.
    """
    leaves = [x for g, kv in cache.items() if g != "pos"
              for x in jax.tree_util.tree_leaves(kv)]
    if not leaves:
        raise PagedKVError("cache has no k/v leaves to page")
    C = leaves[0].shape[2]
    target = pages_for(C, page_size) * int(page_size)
    # pad_cache grows 5-d (NG, B, C, K, hd) leaves on axis 2; here the
    # batch axis is the slot's B=1.
    padded = pad_cache(cache, target)

    def split(x):  # (NG, 1, C', K, hd) -> (P, NG, page_size, K, hd)
        ng = x.shape[0]
        y = x[:, 0]
        y = y.reshape(ng, -1, int(page_size), *y.shape[2:])
        return jnp.moveaxis(y, 1, 0)

    return {g: _tmap(split, kv)
            for g, kv in padded.items() if g != "pos"}


def pages_to_cache(paged: Pytree, pos) -> Pytree:
    """Reassemble a batch-1 model cache from page-major arrays."""

    def join(x):  # (P, NG, page_size, K, hd) -> (NG, 1, C, K, hd)
        y = jnp.moveaxis(x, 0, 1)
        y = y.reshape(y.shape[0], y.shape[1] * y.shape[2], *y.shape[3:])
        return y[:, None]

    out = {g: _tmap(join, kv) for g, kv in paged.items()}
    out["pos"] = jnp.asarray(pos, jnp.int32)
    return out


def pool_token_count(pages: Dict[str, Any], owner: jnp.ndarray,
                     page_size: int) -> int:
    """KV token slots currently HELD by live pages of one lane's pool,
    in :func:`~repro.serve.kv_cache.cache_tokens`' accounting convention
    (k and v counted once).  ``cache_tokens`` supplies the per-(batch,
    row) convention on a probe cache so the two counters can't drift."""
    import numpy as np

    per_page = cache_tokens(pages_to_cache(
        _tmap(lambda x: x[:1], pages), 0))  # one page, batch 1
    held = int(np.sum(np.asarray(owner) >= 0))
    del page_size  # the probe cache already encodes rows-per-page
    return held * per_page
