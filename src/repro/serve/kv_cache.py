"""KV-cache utilities for serving.

Model ``prefill`` returns caches sized to the prompt; decode needs head
room.  ``pad_cache`` grows every attention cache leaf (k/v, layout
(L, B, C, K, hd)) along the sequence axis to ``target_len`` — zero-fill
is safe because decode masks by position validity.  SSM caches (O(1)
state) and enc-dec cross-attn caches (fixed source) are left untouched.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["pad_cache", "cache_tokens"]


def _is_growable(path) -> bool:
    keys = [str(getattr(p, "key", "")) for p in path]
    if "cross" in keys:       # enc-dec source KV never grows
        return False
    return keys[-1] in ("k", "v")


def pad_cache(cache: Pytree, target_len: int) -> Pytree:
    """Grow attention k/v leaves to seq length ``target_len`` (axis 2)."""

    def pad(path, leaf):
        if not _is_growable(path) or leaf.ndim != 5:
            return leaf
        C = leaf.shape[2]
        if C >= target_len:
            return leaf
        pad_widths = [(0, 0)] * leaf.ndim
        pad_widths[2] = (0, target_len - C)
        return jnp.pad(leaf, pad_widths)

    return jax.tree_util.tree_map_with_path(pad, cache)


def cache_tokens(cache: Pytree) -> int:
    """Total KV slots held (for admission/capacity accounting)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if _is_growable(path) and hasattr(leaf, "ndim") and leaf.ndim == 5:
            total += leaf.shape[1] * leaf.shape[2]
    return total // 2  # k and v counted once
