"""Continuous-batching decode: real model execution as a steal workload.

This replaces the wave engine's toy worker body with a decode-step state
machine that runs INSIDE the executor round, under
:func:`repro.runtime.executor.make_lane_step` — so the identical traced
body serves all three execution modes (host-mastered vmap lanes,
device-mastered vmap lanes, one-lane-per-device ``shard_map``).

Each lane owns:

* a ring of QUEUED requests (full prompt payloads — KV-free prefill
  work, which the superstep's bulk steal moves freely between lanes:
  Castañeda & Piña's multiplicity argument licenses this fence-free);
* ``n_slots`` decode SLOTS — in-flight sequences, each holding its
  position, token budget and a page-table row into the lane's paged KV
  pool (:mod:`repro.serve.paged_kv`);
* an OUTPUT ring of finished-request records the host harvests after
  every round.

One round per lane = continuous batching in miniature: bulk-pop as many
queued requests as there are free slots, allocate KV pages (slots stall
under page pressure instead of erroring), advance EVERY active slot by
one token — prompt tokens are teacher-forced one at a time, so prefill
and decode are the same per-slot step and sequences at different phases
batch together — then retire finished sequences, pushing their output
record and freeing their pages in the SAME round their slot reopens.
Per-item cost is genuinely irregular (prompt lengths and sampled output
lengths differ per request), which is the regime the paper's closing
argument claims amplifies bulk stealing.

Per-request greedy tokens depend only on (params, prompt, budget) —
slot assignment, stalls and steals change WHEN a token is produced,
never its value — so the served-token multiset is schedule-invariant
and identical across execution modes (the acceptance gate
``benchmarks/serve_decode.py`` asserts).

Timestamps (admit / first token / finish) are stamped in LOGICAL rounds
— the lane-local round counter all modes advance identically — and
flow into :class:`repro.runtime.telemetry.Telemetry` as
:class:`~repro.runtime.telemetry.RequestRecord` SLO percentiles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as bulk_ops
from repro.core.policy import StealPolicy, plan_transfers
from repro.runtime.adaptive import AdaptiveConfig, AdaptiveController
from repro.serve import paged_kv
from repro.serve.scheduler import Request
from repro.train.fault import StragglerMonitor

Pytree = Any
_tmap = jax.tree_util.tree_map

__all__ = ["DecodePolicy", "DecodeCluster", "request_spec", "output_spec",
           "encode_requests", "init_decode_state", "make_decode_body"]

_NOOP_WATERMARK = 2 ** 30 - 1


@dataclasses.dataclass(frozen=True)
class DecodePolicy:
    """Geometry + steal knobs of the decode subsystem (per lane).

    Attributes:
      n_slots: concurrent in-flight sequences per lane.
      max_prompt / max_new: static per-request bounds (ring item payload
        is ``max_prompt + 4`` int32s; the KV budget per sequence is
        ``max_prompt + max_new`` rows).
      page_size: KV rows per page.
      n_pages: physical pages per lane pool.  ``None`` sizes the pool so
        every slot can always complete (no page pressure); smaller
        values make page pressure a real scheduling signal (slots
        stall until a retirement frees pages).
      out_capacity: finished-record ring size (must cover retirements
        between host harvests; the cluster harvests every round).
      steal: what a steal may move — ``"queue"`` (the cheap path: only
        KV-free queued prefill items ride the superstep exchange) or
        ``"migrate"`` (additionally, the master may move one in-flight
        request per round between lanes, pages and all, when token
        loads diverge past ``migrate_threshold``).
      migrate_threshold: max/min token-load ratio that triggers a
        migration under ``steal="migrate"``.
      load_low / load_high: token-load watermarks for the adaptive
        steal-proportion controller (the decode analogue of the item
        watermarks — ``None`` derives them from one request's worth of
        tokens).
    """

    n_slots: int = 4
    max_prompt: int = 16
    max_new: int = 16
    page_size: int = 8
    n_pages: Optional[int] = None
    out_capacity: Optional[int] = None
    steal: str = "queue"
    migrate_threshold: float = 1.5
    load_low: Optional[int] = None
    load_high: Optional[int] = None

    def __post_init__(self):
        if self.steal not in ("queue", "migrate"):
            raise ValueError(f"steal must be 'queue' or 'migrate', got "
                             f"{self.steal!r}")

    @property
    def pages_per_seq(self) -> int:
        return paged_kv.pages_for(self.max_prompt + self.max_new,
                                  self.page_size)

    @property
    def pool_pages(self) -> int:
        return (self.n_pages if self.n_pages is not None
                else self.n_slots * self.pages_per_seq)

    @property
    def out_ring(self) -> int:
        return (self.out_capacity if self.out_capacity is not None
                else 4 * self.n_slots)

    @property
    def token_low(self) -> int:
        return (self.load_low if self.load_low is not None
                else self.max_prompt + self.max_new)

    @property
    def token_high(self) -> int:
        return (self.load_high if self.load_high is not None
                else 3 * (self.max_prompt + self.max_new))


def request_spec(policy: DecodePolicy) -> Dict[str, jax.ShapeDtypeStruct]:
    """Queue item: one admitted (prefill-pending, KV-free) request."""
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    return {"rid": i32(), "plen": i32(), "max_new": i32(), "admit": i32(),
            "prompt": i32(policy.max_prompt)}


def output_spec(policy: DecodePolicy) -> Dict[str, jax.ShapeDtypeStruct]:
    """Output-ring item: one finished request's tokens + SLO stamps."""
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    return {"rid": i32(), "n": i32(), "admit": i32(), "first": i32(),
            "finish": i32(), "toks": i32(policy.max_new)}


def encode_requests(requests: Sequence[Request], policy: DecodePolicy,
                    admit_round: int) -> Dict[str, jnp.ndarray]:
    """Pad a request batch into the queue-item layout (rows = len)."""
    n = len(requests)
    prompt = np.zeros((n, policy.max_prompt), np.int32)
    plen = np.zeros((n,), np.int32)
    maxn = np.zeros((n,), np.int32)
    rid = np.zeros((n,), np.int32)
    for i, r in enumerate(requests):
        p = list(r.prompt)
        if not 0 < len(p) <= policy.max_prompt:
            raise ValueError(
                f"request {r.rid}: prompt length {len(p)} outside "
                f"(0, {policy.max_prompt}]")
        if not 0 < r.max_new <= policy.max_new:
            raise ValueError(
                f"request {r.rid}: max_new {r.max_new} outside "
                f"(0, {policy.max_new}]")
        prompt[i, : len(p)] = p
        plen[i] = len(p)
        maxn[i] = r.max_new
        rid[i] = r.rid
    return {"rid": jnp.asarray(rid), "plen": jnp.asarray(plen),
            "max_new": jnp.asarray(maxn),
            "admit": jnp.full((n,), jnp.int32(admit_round)),
            "prompt": jnp.asarray(prompt)}


# ---------------------------------------------------------------------------
# Per-lane decode state
# ---------------------------------------------------------------------------


def init_decode_state(model, policy: DecodePolicy, n_lanes: int) -> Pytree:
    """The stacked ``(n_lanes, ...)`` decode carry: slot arrays, the
    paged KV pool and the finished-record output ring, per lane."""
    S, MP, MN = policy.n_slots, policy.max_prompt, policy.max_new
    pool = paged_kv.make_pool(model, n_slots=S, n_pages=policy.pool_pages,
                              page_size=policy.page_size,
                              pages_per_seq=policy.pages_per_seq)
    z = lambda *s: jnp.zeros(s, jnp.int32)
    lane = {
        "pages": pool["pages"], "table": pool["table"],
        "owner": pool["owner"], "n_alloc": z(S),
        "active": jnp.zeros((S,), jnp.bool_),
        "pos": z(S), "plen": z(S), "maxn": z(S),
        "rid": jnp.full((S,), jnp.int32(-1)), "admit": z(S),
        "first": jnp.full((S,), jnp.int32(-1)), "cur": z(S),
        "prompt": z(S, MP), "toks": z(S, MN),
        "round": z(), "stalls": z(), "dropped": z(), "load": z(),
        "out_q": bulk_ops.make_queue(policy.out_ring, output_spec(policy)),
    }
    return _tmap(lambda x: jnp.tile(x[None], (n_lanes,) + (1,) * x.ndim),
                 lane)


def make_decode_body(model, params, policy: DecodePolicy,
                     ops_in: bulk_ops.BulkOps, ops_out: bulk_ops.BulkOps):
    """The decode worker body ``(q, state) -> (q, state)`` for ONE lane.

    Pure traced jnp over the lane's queue ring + decode state; runs
    unmodified under ``jax.vmap`` lanes and per-device ``shard_map``
    (no collectives — the rebalancing superstep that follows it inside
    :func:`~repro.runtime.executor.make_lane_step` has those).
    """
    S, MP, MN, PS = (policy.n_slots, policy.max_prompt, policy.max_new,
                     policy.page_size)
    n_pages = policy.pool_pages
    step_fn = jax.vmap(lambda cache, tok: model.decode_step(
        params, cache, tok))

    PP = policy.pages_per_seq

    def body(q, st):
        r = st["round"]
        active = st["active"]
        # -- continuous admission: bulk-pop one request per free slot,
        # bounded by the page RESERVATION budget.  Every active slot
        # holds a reservation for its full sequence (pages_for(plen +
        # max_new)); a request is only seated while the pool can still
        # cover a worst-case newcomer.  The invariant "sum of active
        # reservations <= n_pages" makes allocation failure transient
        # (a needing slot always finds its reserved page free), so page
        # pressure back-pressures ADMISSION instead of deadlocking
        # seated sequences.
        n_free = jnp.sum((~active).astype(jnp.int32))
        pf = (st["plen"] + st["maxn"] + PS - 1) // PS
        committed = jnp.sum(jnp.where(active, pf, 0))
        budget = jnp.maximum(n_pages - committed, 0) // PP
        n_admit = jnp.minimum(n_free, budget)
        blocked = jnp.maximum(jnp.minimum(n_free, q.size) - n_admit, 0)
        q, batch, n_pop = ops_in.pop_bulk(q, S, n_admit)
        order = jnp.argsort(active)            # free slots first (stable)
        take = jnp.arange(S, dtype=jnp.int32) < n_pop

        def seat(cur_arr, new_rows):
            sel = take.reshape((S,) + (1,) * (new_rows.ndim - 1))
            vals = jnp.where(sel, new_rows, cur_arr[order])
            return cur_arr.at[order].set(vals)

        st = dict(st)
        z = jnp.zeros((S,), jnp.int32)
        st["rid"] = seat(st["rid"], batch["rid"])
        st["plen"] = seat(st["plen"], batch["plen"])
        st["maxn"] = seat(st["maxn"], batch["max_new"])
        st["admit"] = seat(st["admit"], batch["admit"])
        st["prompt"] = seat(st["prompt"], batch["prompt"])
        st["pos"] = seat(st["pos"], z)
        st["cur"] = seat(st["cur"], z)
        st["first"] = seat(st["first"], z - 1)
        st["toks"] = seat(st["toks"], jnp.zeros((S, MN), jnp.int32))
        active = seat(active, jnp.ones((S,), jnp.bool_))
        st["active"] = active

        # -- page allocation; slots stall under page pressure ----------
        pos = st["pos"]
        need = active & (pos // PS >= st["n_alloc"])
        table, owner, n_alloc = paged_kv.alloc_pages(
            st["table"], st["owner"], st["n_alloc"], need, pos // PS)
        advance = active & (pos // PS < n_alloc)
        # Stalls = free slots the page budget refused to fill while
        # requests were queued (admission back-pressure) + seated slots
        # whose page grant was deferred a round (transient only, by the
        # reservation invariant above).
        st["stalls"] = (st["stalls"] + blocked
                        + jnp.sum((active & ~advance).astype(jnp.int32)))

        # -- one decode step for every slot (prompt teacher-forced) ----
        cache_in = paged_kv.gather_slot_caches(st["pages"], table, pos)
        pp = st["prompt"][jnp.arange(S), jnp.clip(pos, 0, MP - 1)]
        feed = jnp.where(pos < st["plen"], pp, st["cur"])
        logits, cache_out = step_fn(cache_in, feed[:, None, None])
        nxt = jnp.argmax(logits[:, 0, 0, :], axis=-1).astype(jnp.int32)

        gidx = pos + 1 - st["plen"]            # generated-token index
        valid_gen = advance & (gidx >= 0) & (gidx < MN)
        srow = jnp.where(valid_gen, jnp.arange(S, dtype=jnp.int32),
                         jnp.int32(S))
        st["toks"] = st["toks"].at[
            srow, jnp.clip(gidx, 0, MN - 1)].set(nxt, mode="drop")
        st["first"] = jnp.where(advance & (gidx == 0), r, st["first"])
        st["cur"] = jnp.where(advance, nxt, st["cur"])
        pos = pos + advance.astype(jnp.int32)
        st["pos"] = pos
        st["pages"] = paged_kv.scatter_slot_caches(
            st["pages"], table,
            {g: cache_in[g] for g in cache_in if g != "pos"},
            {g: cache_out[g] for g in cache_out if g != "pos"},
            advance)

        # -- retire finished sequences; free pages the same round ------
        fin = active & (pos - st["plen"] >= st["maxn"])
        n_fin = jnp.sum(fin.astype(jnp.int32))
        ordf = jnp.argsort(~fin)               # finished slots first
        rec = {"rid": st["rid"][ordf], "n": st["maxn"][ordf],
               "admit": st["admit"][ordf], "first": st["first"][ordf],
               "finish": jnp.full((S,), r), "toks": st["toks"][ordf]}
        out_q, pushed = ops_out.push(st["out_q"], rec, n_fin)
        st["out_q"] = out_q
        st["dropped"] = st["dropped"] + (n_fin - pushed)
        table, owner, n_alloc = paged_kv.free_pages(table, owner, n_alloc,
                                                    fin)
        st["table"], st["owner"], st["n_alloc"] = table, owner, n_alloc
        active = active & ~fin
        st["active"] = active

        # -- true token load: queued work + in-flight remainder --------
        cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
        offs = jnp.arange(cap, dtype=jnp.int32)
        live = ((offs - q.lo) % cap) < q.size
        queued = jnp.sum(jnp.where(live, q.buf["plen"] + q.buf["max_new"],
                                   0))
        inflight = jnp.sum(jnp.where(active,
                                     st["plen"] + st["maxn"] - pos, 0))
        st["load"] = (queued + inflight).astype(jnp.int32)
        st["round"] = r + 1
        return q, st

    return body


# ---------------------------------------------------------------------------
# The cluster driver
# ---------------------------------------------------------------------------


class DecodeCluster:
    """N decode lanes + one admission master, in any execution mode.

    ``execution`` selects where the MASTER lives (the decode body is the
    same traced function everywhere):

    * ``"host"`` — the rebalancing plan runs on the host between rounds
      (the :class:`~repro.serve.scheduler.AdmissionMaster` discipline:
      ``plan_transfers`` on queue sizes, owner-side ``steal_exact`` +
      bulk push per pair), the in-trace superstep is a no-op;
    * ``"vmap"`` / ``"mesh"`` — every round IS a device superstep via
      :class:`repro.distributed.RuntimeAdmissionMaster`: decode body,
      then plan + compact exchange on device (one lane per device under
      ``"mesh"``).

    ``balance=False`` freezes rebalancing entirely (the static baseline
    the benchmark compares against); ``admission`` picks least
    token-load (``"load"``) or static round-robin (``"rr"``) routing.
    The steal proportion is servo'd by an
    :class:`~repro.runtime.adaptive.AdaptiveController` fed TRUE
    per-lane token loads (queued + in-flight tokens, computed in-trace)
    rather than request counts.
    """

    def __init__(self, model, params, *,
                 policy: Optional[DecodePolicy] = None,
                 steal_policy: Optional[StealPolicy] = None,
                 n_lanes: int = 4, capacity: int = 64,
                 execution: str = "vmap",
                 balance: bool = True, admission: str = "load",
                 adaptive: bool = True,
                 adaptive_config: Optional[AdaptiveConfig] = None,
                 mesh=None, backend=None,
                 straggler_threshold: float = 2.0):
        if execution not in ("host", "vmap", "mesh"):
            raise ValueError(f"unknown execution {execution!r}")
        if admission not in ("load", "rr"):
            raise ValueError(f"unknown admission {admission!r}")
        self.model, self.params = model, params
        self.policy = policy or DecodePolicy()
        self.execution = execution
        self.balance = bool(balance)
        self.admission = admission
        self.n_lanes = int(n_lanes)
        # Decode-tuned defaults: queued backlogs are small (slots absorb
        # one request per free seat per round), so even a 2-deep queue
        # next to an idle lane is worth moving.
        spol = steal_policy or StealPolicy(
            proportion=0.5, low_watermark=0, high_watermark=2,
            queue_limit=1, max_steal=min(64, capacity))
        self._steal_policy = spol
        noop = dataclasses.replace(spol, high_watermark=_NOOP_WATERMARK,
                                   queue_limit=_NOOP_WATERMARK)
        # The in-trace superstep rebalances only in device-mastered,
        # balanced mode; host mode (and the static baseline) compiles
        # the no-victim plan, which moves nothing.
        trace_pol = spol if (balance and execution != "host") else noop
        spec = request_spec(self.policy)
        self.master = None
        if execution == "host":
            from repro.runtime.executor import StealRuntime

            self.runtime = StealRuntime(
                self.n_lanes, capacity, spec, policy=trace_pol,
                adaptive=False, max_pop=self.policy.n_slots,
                backend=backend)
        else:
            from repro.distributed.serve import RuntimeAdmissionMaster

            self.master = RuntimeAdmissionMaster(
                self.n_lanes, policy=trace_pol, adaptive=False,
                execution=execution, capacity=capacity, mesh=mesh,
                item_spec=spec, max_pop=self.policy.n_slots,
                elastic=False)
            self.runtime = self.master.runtime
        # Token-load-watermarked proportion servo (decode's analogue of
        # the item-count controller): its output is injected into the
        # compiled round as the traced proportion scalar each step.
        token_pol = dataclasses.replace(
            spol, low_watermark=self.policy.token_low,
            high_watermark=self.policy.token_high)
        self.controller = (AdaptiveController(token_pol, adaptive_config)
                           if (adaptive and self.balance) else None)
        self._ops_out = bulk_ops.make_ops(
            "reference", capacity=self.policy.out_ring,
            max_push=self.policy.n_slots, max_pop=self.policy.out_ring,
            check=False)
        self._worker = make_decode_body(model, params, self.policy,
                                        self.runtime.ops, self._ops_out)
        self.carry = init_decode_state(model, self.policy, self.n_lanes)
        self._requests: Dict[int, Request] = {}
        self.done: List[Request] = []
        self.pending = 0
        self.rounds = 0
        self.stolen = 0
        self.migrated = 0
        self._loads = np.zeros((self.n_lanes,), np.int64)
        self._rr = 0
        self.monitor = StragglerMonitor(threshold=straggler_threshold)

    # -- surface -------------------------------------------------------------

    @property
    def telemetry(self):
        return self.runtime.telemetry

    def note_straggler(self, rounds: int = 4, factor: float = 1.5) -> None:
        """Straggler response: counted in telemetry and, when the token
        controller is on, a temporary steal-proportion boost."""
        self.telemetry.record_fault("straggler")
        if self.controller is not None:
            self.controller.flag_straggler(rounds=rounds, factor=factor)

    # -- admission -----------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        """Admit a request batch: ``admission="load"`` routes each
        request greedily to the currently least token-loaded lane
        (updating the estimate as it assigns, so a burst spreads by
        COST); ``admission="rr"`` spreads by COUNT (the static
        baseline).  Either way, one bulk ring push per target lane."""
        requests = list(requests)
        if not requests:
            return
        for r in requests:
            self._requests[r.rid] = r
        groups: Dict[int, List[Request]] = {}
        if self.admission == "load":
            est = self._loads.copy()
            for r in requests:
                lane = int(np.argmin(est))
                est[lane] += len(r.prompt) + r.max_new
                groups.setdefault(lane, []).append(r)
        else:
            for r in requests:
                lane = self._rr % self.n_lanes
                self._rr += 1
                groups.setdefault(lane, []).append(r)
        for lane, reqs in groups.items():
            batch = encode_requests(reqs, self.policy, self.rounds)
            pushed = self.runtime.push(lane, batch, len(reqs))
            if pushed < len(reqs):
                raise RuntimeError(
                    f"admission ring overflow on lane {lane}: pushed "
                    f"{pushed}/{len(reqs)} (capacity "
                    f"{self.runtime.capacity})")
            self._loads[lane] += sum(
                len(r.prompt) + r.max_new for r in reqs)
        self.pending += len(requests)

    # -- host-mastered rebalancing -------------------------------------------

    def _host_rebalance(self) -> int:
        """One host-master round over the device rings: the same
        ``plan_transfers`` pairing the superstep runs, applied by the
        host via owner-side exact steals + bulk pushes."""
        pol = self._steal_policy
        if self.controller is not None:
            pol = dataclasses.replace(
                pol, proportion=self.controller.effective_proportion)
        sizes = self.runtime.sizes()
        plan = np.asarray(plan_transfers(
            jnp.asarray(sizes, jnp.int32), pol))
        ops, qs = self.runtime.ops, self.runtime.queues
        moved = 0
        for thief in range(self.n_lanes):
            src, n = int(plan[thief, 0]), int(plan[thief, 1])
            if n <= 0 or src == thief:
                continue
            qv = _tmap(lambda x: x[src], qs)
            qv, batch, got = ops.steal_exact(qv, jnp.int32(n),
                                             max_steal=pol.max_steal)
            qt = _tmap(lambda x: x[thief], qs)
            qt, pushed = ops.push(qt, batch, got)
            qs = _tmap(lambda full, one: full.at[src].set(one), qs, qv)
            qs = _tmap(lambda full, one: full.at[thief].set(one), qs, qt)
            moved += int(pushed)
        self.runtime.queues = qs
        self.stolen += moved
        return moved

    # -- in-flight migration (steal="migrate") -------------------------------

    def _maybe_migrate(self) -> int:
        """Move ONE in-flight request — slot state, KV pages and all —
        from the most to the least token-loaded lane when their loads
        diverge past ``migrate_threshold``.  Host-side surgery on the
        carry at a round boundary (the only consistency point); page
        content moves bitwise, so the request's remaining tokens are
        unchanged by the move."""
        c = self.carry
        loads = np.asarray(c["load"])
        d, t_lane = int(np.argmax(loads)), int(np.argmin(loads))
        if d == t_lane:
            return 0
        if loads[d] <= self.policy.migrate_threshold * max(loads[t_lane], 1):
            return 0
        active = np.asarray(c["active"])
        plen = np.asarray(c["plen"])
        maxn = np.asarray(c["maxn"])
        pos = np.asarray(c["pos"])
        donor_slots = np.where(active[d])[0]
        free_slots = np.where(~active[t_lane])[0]
        if donor_slots.size == 0 or free_slots.size == 0:
            return 0
        remaining = (plen[d] + maxn[d] - pos[d])[donor_slots]
        s = int(donor_slots[int(np.argmax(remaining))])
        t = int(free_slots[0])
        n_al = int(np.asarray(c["n_alloc"])[d, s])
        owner = np.asarray(c["owner"])
        free_pages = np.where(owner[t_lane] < 0)[0]
        # Preserve the destination's reservation invariant: the moved
        # sequence's FULL page demand must fit next to the active
        # reservations already there, or admission could deadlock.
        PS = self.policy.page_size
        pf = -(-(plen[t_lane] + maxn[t_lane] - 0) // PS)
        committed = int(pf[active[t_lane]].sum())
        seq_pf = -(-(int(plen[d, s]) + int(maxn[d, s])) // PS)
        if committed + seq_pf > self.policy.pool_pages:
            return 0
        if free_pages.size < n_al:
            return 0
        table = np.asarray(c["table"])
        for name in ("rid", "plen", "maxn", "admit", "first", "cur", "pos",
                     "prompt", "toks", "n_alloc"):
            arr = c[name]
            c[name] = arr.at[t_lane, t].set(arr[d, s])
        c["active"] = c["active"].at[t_lane, t].set(True).at[d, s].set(False)
        new_table = c["table"]
        new_owner = c["owner"]
        for j in range(n_al):
            sp = int(table[d, s, j])
            dp = int(free_pages[j])
            for g, kv in c["pages"].items():
                c["pages"][g] = _tmap(
                    lambda x: x.at[t_lane, dp].set(x[d, sp]), kv)
            new_table = new_table.at[t_lane, t, j].set(dp)
            new_owner = new_owner.at[t_lane, dp].set(t)
            new_owner = new_owner.at[d, sp].set(-1)
        trash = self.policy.pool_pages
        new_table = new_table.at[d, s].set(trash)
        c["table"], c["owner"] = new_table, new_owner
        c["n_alloc"] = c["n_alloc"].at[d, s].set(0)
        moved = int(plen[d, s] + maxn[d, s] - pos[d, s])
        self._loads[d] -= moved
        self._loads[t_lane] += moved
        self.migrated += 1
        return 1

    # -- the round -----------------------------------------------------------

    def _harvest(self) -> List[Dict[str, np.ndarray]]:
        """Pop every finished-request record off each lane's output ring
        (host-side, one bulk pop per lane) and clear the rings in the
        carry."""
        ops, c = self._ops_out, self.carry
        cap = self.policy.out_ring
        records = []
        out_q = c["out_q"]
        for i in range(self.n_lanes):
            qi = _tmap(lambda x: x[i], out_q)
            qi, batch, n = ops.pop_bulk(qi, cap, qi.size)
            out_q = _tmap(lambda full, one: full.at[i].set(one), out_q, qi)
            batch = _tmap(np.asarray, batch)
            for j in range(int(n)):
                records.append(_tmap(lambda x: x[j], batch))
        c["out_q"] = out_q
        return records

    def step(self) -> int:
        """One serving tick = one executor round (decode body + exchange
        superstep), then host harvest, SLO accounting, optional
        migration, and the token-load controller update."""
        self.monitor.start()
        if self.controller is not None:
            self.runtime.policy = dataclasses.replace(
                self.runtime.policy,
                proportion=self.controller.effective_proportion)
        before = self.telemetry.total_transferred
        self.carry, _stats = self.runtime.round(self._worker, self.carry)
        self.stolen += self.telemetry.total_transferred - before
        if self.execution == "host" and self.balance:
            self._host_rebalance()
        if int(np.asarray(self.carry["dropped"]).sum()):
            raise RuntimeError(
                "output ring overflow: finished records were dropped — "
                "raise DecodePolicy.out_capacity")
        served, tokens = 0, 0
        for rec in self._harvest():
            n = int(rec["n"])
            self.telemetry.record_request(
                rid=int(rec["rid"]), admit=int(rec["admit"]),
                first=int(rec["first"]), finish=int(rec["finish"]),
                tokens=n)
            req = self._requests.get(int(rec["rid"]))
            if req is not None:
                req.output = [int(x) for x in rec["toks"][:n]]
                self.done.append(req)
            served += 1
            tokens += n
        self.pending -= served
        migrated = 0
        if self.balance and self.policy.steal == "migrate":
            migrated = self._maybe_migrate()
        self._loads = np.asarray(self.carry["load"]).astype(np.int64)
        stragglers = 0
        if self.monitor.observe():
            stragglers = 1
            self.note_straggler()
        if self.controller is not None:
            self.controller.update(self._loads)
        self.telemetry.record_wave(
            loads=self._loads, served=served, tokens=tokens,
            stragglers=stragglers, migrated=migrated)
        self.rounds += 1
        return served

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.pending <= 0:
                break
            self.step()
        return self.done

    def stats(self) -> Dict:
        c = self.carry
        return {
            "execution": self.execution,
            "balance": self.balance,
            "admission": self.admission,
            "steal": self.policy.steal,
            "loads": [int(x) for x in self._loads],
            "queued": [int(x) for x in self.runtime.sizes()],
            "pending": self.pending,
            "served": len(self.done),
            "stolen": self.stolen,
            "migrated": self.migrated,
            "stalls": int(np.asarray(c["stalls"]).sum()),
            "kv_tokens": [
                paged_kv.pool_token_count(
                    _tmap(lambda x, i=i: x[i], c["pages"]),
                    np.asarray(c["owner"])[i], self.policy.page_size)
                for i in range(self.n_lanes)],
            "proportion": (self.controller.effective_proportion
                           if self.controller else
                           self.runtime.policy.proportion),
            "backend": self.runtime.ops.resolved,
            "telemetry": self.telemetry.summary(),
        }
