"""Wave-batched serving engine.

Each replica runs waves: pop up to ``wave_size`` requests from its queue
(bulk), left-pad prompts to a common length, one batched prefill, then
batched greedy decode until every request hits its ``max_new`` budget.
Between waves the replica yields to the admission master's rebalance
round (serve/scheduler.py).

This is deliberately wave-synchronous (vLLM-style per-token continuous
batching with paged KV is out of scope — see DESIGN.md); the paper's
contribution lives in the QUEUE + MASTER layer, which is identical
either way.  The queues behind the master are pluggable
``HostQueue`` implementations (``AdmissionMaster(queue_factory=...)``).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_cache import pad_cache
from repro.serve.scheduler import AdmissionMaster, Request
from repro.train.fault import StragglerMonitor

__all__ = ["Replica", "ServeCluster"]


class Replica:
    def __init__(self, model, params, *, wave_size: int = 4,
                 max_seq: int = 128):
        self.model = model
        self.params = params
        self.wave_size = wave_size
        self.max_seq = max_seq
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        # ring-aware growth when the model provides it (local/SWA caches)
        grow = getattr(model, "grow_cache", None) or (
            lambda c, t: pad_cache(c, t))
        self._pad = jax.jit(grow, static_argnums=1)
        self.tokens_generated = 0
        self.speed = 1.0   # straggler simulation hook (tests scale this)

    def run_wave(self, wave: List[Request]) -> List[Request]:
        if not wave:
            return []
        B = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):  # left-pad with 0
            toks[i, plen - len(r.prompt):] = r.prompt
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        cache = self._pad(cache, self.max_seq)  # head room for decode
        out = [[] for _ in range(B)]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in wave)
        for _ in range(min(max_new, self.max_seq - plen)):
            for i in range(B):
                out[i].append(int(cur[i]))
            logits, cache = self._decode(self.params, cache, cur[:, None])
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            self.tokens_generated += B
        for i, r in enumerate(wave):
            r.output = out[i][: r.max_new]
        return wave


class ServeCluster:
    """N replicas + one admission master; ``step()`` = each replica runs
    one wave, then the master rebalances (the superstep structure of
    core.master, at host level).  ``rebalance_rounds > 1`` lets the
    master run several steal rounds per wave tick
    (``AdmissionMaster.rebalance_many`` — the host analogue of the
    executor's fused supersteps), which converges a badly skewed cluster
    within one tick.

    Waves flow through the SAME executor-layer telemetry stream the
    master's rebalance rounds use (``runtime.telemetry.Telemetry`` on
    :attr:`telemetry` — the master's instance): each tick appends one
    :class:`~repro.runtime.telemetry.WaveRecord` (requests served,
    tokens generated, post-wave per-replica loads) next to the round
    records, so ``stats()["telemetry"]`` reports rounds and waves from
    one source instead of ad-hoc host counters.

    ``execution`` selects where the admission queues live:
    ``"host"`` (default) keeps the Python
    :class:`~repro.serve.scheduler.AdmissionMaster` over ``HostQueue``
    implementations; ``"vmap"`` / ``"mesh"`` swap in
    :class:`repro.distributed.RuntimeAdmissionMaster` — request IDs on
    executor lanes (one ring per replica; one ring per DEVICE under
    ``"mesh"``), every rebalance a real device superstep through
    :func:`repro.distributed.launch_runtime`."""

    def __init__(self, replicas: List[Replica],
                 master: Optional[AdmissionMaster] = None,
                 rebalance_rounds: int = 1,
                 execution: str = "host",
                 admission_capacity: int = 512,
                 straggler_threshold: float = 2.0,
                 auto_evict_after: Optional[int] = None):
        self.replicas = replicas
        if master is None:
            if execution == "host":
                master = AdmissionMaster(len(replicas))
            else:
                from repro.distributed.serve import RuntimeAdmissionMaster

                master = RuntimeAdmissionMaster(
                    len(replicas), execution=execution,
                    capacity=admission_capacity)
        self.master = master
        self.rebalance_rounds = int(rebalance_rounds)
        self.done: List[Request] = []
        # One wall-clock straggler monitor per replica; its timeout
        # observations feed the shared FailureDetector below.
        self.monitors = [StragglerMonitor(threshold=straggler_threshold)
                         for _ in replicas]
        # Escalation policy, ONE place (runtime/detector.py) instead of
        # an ad-hoc streak counter here: every slow wave SUSPECTS the
        # replica (straggler boost via ``note_straggler``); a replica
        # slow ``auto_evict_after`` waves IN A ROW is declared DEAD —
        # evicted outright, its ring drained onto the others.  ``None``
        # keeps the boost-only behavior (no death escalation).  The
        # detector lives on the master (host AND device masters expose
        # ``attach_detector``), so host/vmap/mesh share one policy.
        self.auto_evict_after = auto_evict_after
        from repro.runtime.detector import DetectorPolicy, FailureDetector

        pol = DetectorPolicy(suspect_after=1, dead_after=auto_evict_after,
                             healthy_after=1)
        attach = getattr(self.master, "attach_detector", None)
        if attach is not None:
            self.detector = attach(pol)
        else:  # duck-typed custom master: boost-only wiring
            self.detector = FailureDetector(
                len(replicas), pol,
                on_suspect=lambda rid: self.master.note_straggler(),
                on_dead=self._auto_evict)

    def _auto_evict(self, replica_id: int) -> None:
        self.evict_replica(replica_id)
        self.telemetry.record_fault("auto_evict")

    def evict_replica(self, replica_id: int) -> int:
        """Planned eviction: the master drains the replica's queued
        requests onto the other lanes (device masters steal the whole
        ring at proportion 1.0 through the recovery superstep); the
        replica receives no further waves until :meth:`readmit_replica`.
        Returns the number of requests drained."""
        return self.master.evict(replica_id)

    def readmit_replica(self, replica_id: int) -> None:
        self.master.readmit(replica_id)
        # Masters with an attached detector revive it in readmit();
        # revive the engine-owned fallback detector ourselves.
        if getattr(self.master, "detector", None) is not self.detector:
            self.detector.revive(replica_id)

    @property
    def telemetry(self):
        """The unified per-round + per-wave telemetry stream (the
        admission master's ``runtime.telemetry.Telemetry``)."""
        return self.master.telemetry

    def metrics(self, registry=None):
        """Poll the cluster into a :class:`repro.obs.metrics.
        MetricsRegistry`: the master's admission metrics (both master
        kinds expose ``metrics``; a duck-typed custom master falls back
        to the generic collector) plus per-replica tokens generated.
        Pull-style — poll mid-run at any cadence."""
        from repro.obs.metrics import MetricsRegistry, master_metrics

        poll = getattr(self.master, "metrics", None)
        if poll is not None:
            reg = poll(registry)
        else:
            reg = master_metrics(self.master, registry or MetricsRegistry())
        tokens = reg.counter("repro_serve_replica_tokens_total",
                             "tokens generated per replica")
        for rid, rep in enumerate(self.replicas):
            tokens.set_total(rep.tokens_generated, replica=rid)
        return reg

    def submit(self, reqs: List[Request]):
        self.master.submit(reqs)

    def step(self) -> int:
        served = 0
        stragglers = 0
        tokens_before = sum(r.tokens_generated for r in self.replicas)
        for rid, rep in enumerate(self.replicas):
            rq = self.master.replicas[rid]
            if getattr(rq, "evicted", False):
                continue  # drained and masked out; no new waves
            # straggler simulation: slow replicas take smaller waves
            wave_n = max(1, int(rep.wave_size * rep.speed))
            mon = self.monitors[rid]
            mon.start()
            wave = rq.pop_wave(wave_n)
            finished = rep.run_wave(wave)
            slow = bool(mon.observe()) and bool(wave)
            if slow:
                stragglers += 1
            # The wave's requests are accounted BEFORE the detector may
            # escalate to eviction — nothing in flight is lost.
            rq.finish_wave(len(finished))
            self.done.extend(finished)
            served += len(finished)
            if wave:  # empty waves say nothing about replica health
                self.detector.observe(rid, slow)
        tokens = sum(r.tokens_generated for r in self.replicas) - tokens_before
        evicted = sum(1 for r in self.master.replicas
                      if getattr(r, "evicted", False))
        self.telemetry.record_wave(
            loads=[r.load() for r in self.master.replicas],
            served=served, tokens=tokens,
            evicted=evicted, stragglers=stragglers)
        self.master.rebalance_many(self.rebalance_rounds)
        return served

    def run_until_drained(self, max_steps: int = 1000) -> List[Request]:
        for _ in range(max_steps):
            pending = sum(r.load() for r in self.master.replicas)
            if pending == 0:
                break
            self.step()
        return self.done
