"""Mesh-native executor: the FULL round loop under ``shard_map``.

:class:`MeshStealRuntime` is :class:`repro.runtime.StealRuntime` with the
execution mode swapped: instead of ``jax.vmap`` lanes on one device, the
whole round — worker body, compact/dense exchange, adaptive proportion
update, telemetry accumulation — compiles as ONE ``shard_map``-ped fused
block over a real mesh worker axis (or a 2-D ``(pod, worker)`` mesh for
hierarchical supersteps).  Each device owns exactly one queue lane:

* **Per-device queue shards** — the stacked :class:`~repro.core.ops.
  QueueState` is placed with a :class:`~jax.sharding.NamedSharding`
  over the lane axis at construction, so lane i's ring buffer lives on
  device i from the first byte and never moves; the fused block donates
  the whole stack, which under shard_map donates each device's shard in
  place (skipped on CPU like the vmapped runtime).
* **The round body is shared, not ported** — both runtimes build on
  :func:`repro.runtime.executor.make_lane_step`; under shard_map the
  superstep's collectives (size all_gather, window all_gather /
  all_to_all) resolve through the mesh axes instead of vmap axes and
  become real ICI/DCN traffic.  The parity suite asserts queues, stats
  and adaptive-proportion trajectories are bit-identical between modes.
* **Device-resident round loop** — ``run_fused(k)`` places the
  ``lax.scan`` (or the ``until_drained`` ``lax.while_loop``) INSIDE the
  shard_map block: k rounds of collectives + adaptive feedback run
  without the host in the loop, and the drain check is a replicated
  cross-shard size reduction (every device takes the same exit branch).
* **Exact cross-host telemetry** — each shard stacks its OWN lane's
  per-round ``RebalanceStats`` counters; shard_map's output specs gather
  them back into the vmapped runtime's exact ``(k, W, ...)`` lane
  layout, so the one shared reduction
  (:func:`repro.runtime.telemetry.reduce_round_stats`) assembles the
  same exact :class:`~repro.runtime.telemetry.RoundRecord`s — including
  ``bytes_moved`` / ``bytes_moved_xpod`` — from per-shard counters.

Host-side surface (``push`` / ``drain`` / ``round`` / ``run_fused`` /
``run`` / telemetry / the adaptive controller) is inherited unchanged:
the mesh runtime overrides only how the round block is built.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import master as master_ops
from repro.core import ops as bulk_ops
from repro.runtime import resilience
from repro.runtime.adaptive import adaptive_update
from repro.runtime.executor import StealRuntime, WorkerFn, make_lane_step

__all__ = ["MeshStealRuntime"]

_tmap = jax.tree_util.tree_map


def _strip_lane(tree):
    """Local shard ``(1, ...)`` -> per-lane view ``(...)``."""
    return _tmap(lambda x: x[0], tree)


def _add_lane(tree):
    """Per-lane view ``(...)`` -> local shard ``(1, ...)``."""
    return _tmap(lambda x: x[None], tree)


class MeshStealRuntime(StealRuntime):
    """Drives adaptive rebalancing rounds with one queue lane per device.

    Args:
      mesh: a 1-axis mesh (flat supersteps over its axis) or a 2-axis
        ``(pod_axis, worker_axis)`` mesh (hierarchical supersteps;
        ``pod_size`` is the worker-axis extent).  Build one with
        :func:`repro.launch.mesh.make_worker_mesh` — the default axis
        names match the vmapped runtime's, so worker bodies written for
        one mode run unmodified in the other.
      capacity / item_spec / policy / adaptive / adaptive_config /
      backend / max_pop: exactly as :class:`~repro.runtime.StealRuntime`.
    """

    def __init__(self, mesh: Mesh, capacity: int, item_spec, **kwargs):
        axes = tuple(mesh.axis_names)
        if len(axes) == 1:
            pod_axis, worker_axis = None, axes[0]
            pod_size = None
        elif len(axes) == 2:
            pod_axis, worker_axis = axes
            pod_size = int(mesh.shape[worker_axis])
        else:
            raise ValueError(
                f"MeshStealRuntime wants a 1-axis (flat) or 2-axis "
                f"(pod, worker) mesh, got axes {axes}")
        for key in ("axis_name", "pod_axis", "pod_size", "n_workers",
                    "queue_sharding"):
            if key in kwargs:
                raise TypeError(
                    f"MeshStealRuntime derives {key!r} from the mesh; "
                    f"don't pass it")
        n_workers = int(np.prod([mesh.shape[a] for a in axes]))
        self.mesh = mesh
        # One PartitionSpec entry shards the leading lane dim over EVERY
        # mesh axis (pod-major, matching the stacked lane order); the
        # trailing ring dims stay replicated-within-the-shard, i.e. each
        # device holds its whole lane.
        self._lane_entry = axes if len(axes) > 1 else axes[0]
        self._lane_spec = P(self._lane_entry)
        self.sharding = NamedSharding(mesh, self._lane_spec)
        # The queue stack is BORN sharded (lane i's ring on device i from
        # the first byte) — never built dense and re-placed.
        super().__init__(n_workers, capacity, item_spec,
                         axis_name=worker_axis, pod_size=pod_size,
                         pod_axis=pod_axis or "pods",
                         queue_sharding=self.sharding, **kwargs)

    # -- the round, shard_mapped --------------------------------------------

    def _axes_tuple(self) -> tuple:
        return tuple(self.mesh.axis_names)

    def _make_step(self, worker_fn: Optional[WorkerFn],
                   stage: Optional[str] = None) -> Callable:
        """Un-jitted ``(qs, carry, proportion, ctx) -> (qs, carry,
        stats)``, identical signature and output layout to the vmapped
        runtime's — but each lane executes on its own device and the
        stats come back gathered into the stacked ``(W, ...)`` lane
        order.  The fault context is replicated (the schedule is the
        virtual master's view, identical on every device).  A non-None
        ``stage`` builds the phase probe's truncated prefix (the stats
        slot is then the per-lane scalar token, gathered to ``(W,)``)."""
        lane_fn = self._lane_step(worker_fn, stage)
        lane = self._lane_spec
        ctx_spec = resilience.ctx_specs(self.fault is not None)

        def local_step(qs, carry, proportion, ctx):
            q, c = _strip_lane(qs), _strip_lane(carry)
            q, c, stats = lane_fn(q, c, proportion, ctx)
            return _add_lane(q), _add_lane(c), _add_lane(stats)

        return shard_map(
            local_step, mesh=self.mesh,
            in_specs=(lane, lane, P(), ctx_spec),
            out_specs=(lane, lane, lane),
            check_rep=False)

    def _fused_round(self, worker_fn: Optional[WorkerFn]) -> Callable:
        """Per-shard ``(q, carry, p) -> (q, carry, p', tele, total)``:
        one round plus the on-device adaptive update and the replicated
        global size total (the drain signal).  ``tele`` leaves carry a
        leading local-lane dim so shard_map's out specs can gather them
        into the vmapped runtime's exact telemetry layout."""
        lane_fn = self._lane_step(worker_fn)
        policy, controller = self.policy, self.controller
        config = controller.config if controller else None
        worker_axis = self.axis_name
        pod_axis = self.pod_axis if self.pod_size is not None else None

        def one_round(q, carry, p, ctx):
            q, carry, stats = lane_fn(q, carry, p, ctx)
            # The master's bookkeeping, re-used twice: the TRUE global
            # size vector feeds the same float32 adaptive step the vmap
            # runtime scans (bit-identical trajectory), and its sum is
            # the replicated drain signal for the while_loop exit.
            sizes_vec = master_ops.gather_sizes(
                q, worker_axis=worker_axis, pod_axis=pod_axis)
            tele = {"stats": _add_lane(stats),
                    "sizes": q.size[None],
                    "proportion": p}
            ctx = resilience.ctx_advance(ctx)
            if controller is not None:
                # Identical dead-lane masking to the vmap fused path, so
                # faulted adaptive trajectories stay bit-identical too.
                masked = resilience.mask_sizes(sizes_vec, ctx, policy)
                p = adaptive_update(p, masked, policy=policy,
                                    config=config)
            return q, carry, p, ctx, tele, jnp.sum(sizes_vec)

        return one_round

    def _tele_slots(self, k: int):
        """Preallocated per-shard ``(k, ...)`` telemetry slots for the
        early-exit loop.  Shapes are written out (not eval_shape'd): the
        superstep's gather widths are static — intra-level stats gather
        over the worker axis (``pod_size`` wide, or W when flat), the
        hierarchical ``sizes_after`` over the pod axis."""
        W, pod = self.n_workers, self.pod_size
        before_w = pod if pod is not None else W
        after_w = (W // pod) if pod is not None else W
        i32 = lambda *s: jnp.zeros((k,) + s, jnp.int32)
        stats = master_ops.RebalanceStats(
            sizes_before=i32(1, before_w), sizes_after=i32(1, after_w),
            n_transferred=i32(1), n_steals=i32(1),
            n_transferred_xpod=i32(1), n_steals_xpod=i32(1),
            bytes_moved=i32(1), bytes_moved_xpod=i32(1))
        return {"stats": stats, "sizes": i32(1),
                "proportion": jnp.zeros((k,), jnp.float32)}

    def _compile_fused(self, worker_fn: Optional[WorkerFn], k: int,
                       until_drained: bool = False) -> Callable:
        """The whole k-round loop INSIDE one shard_map block: scan (or
        early-exit while_loop) over the shared round body, collectives
        and the adaptive carry never leaving the devices; telemetry
        stacked per shard and gathered once at the block edge."""
        one_round = self._fused_round(worker_fn)
        lane, entry = self._lane_spec, self._lane_entry
        axes = self._axes_tuple()
        ctx_spec = resilience.ctx_specs(self.fault is not None)

        def local_fused(qs, carry, p0, ctx0):
            q, c = _strip_lane(qs), _strip_lane(carry)

            if not until_drained:
                def body(state, _):
                    q, c, p, ctx = state
                    q, c, p, ctx, tele, _total = one_round(q, c, p, ctx)
                    return (q, c, p, ctx), tele

                (q, c, p, _ctx), tele = lax.scan(body, (q, c, p0, ctx0),
                                                 None, length=k)
                rounds = jnp.int32(k)
            else:
                tele0 = self._tele_slots(k)

                def cond(state):
                    _q, _c, _p, _ctx, r, _tele, total = state
                    return (r < k) & (total > 0)

                def body(state):
                    q, c, p, ctx, r, tele, _ = state
                    q, c, p, ctx, t, total = one_round(q, c, p, ctx)
                    tele = _tmap(
                        lambda buf, v: lax.dynamic_update_index_in_dim(
                            buf, v, r, 0), tele, t)
                    return (q, c, p, ctx, r + 1, tele, total)

                total0 = lax.psum(q.size, axes)  # replicated global size
                q, c, p, _ctx, rounds, tele, _ = lax.while_loop(
                    cond, body,
                    (q, c, p0, ctx0, jnp.int32(0), tele0, total0))

            return _add_lane(q), _add_lane(c), p, tele, rounds

        tele_spec = {"stats": P(None, entry), "sizes": P(None, entry),
                     "proportion": P(None)}
        fused = shard_map(
            local_fused, mesh=self.mesh,
            in_specs=(lane, lane, P(), ctx_spec),
            out_specs=(lane, lane, P(), tele_spec, P()),
            check_rep=False)
        return jax.jit(fused, donate_argnums=self._donate_argnums())

    # -- resilience: elastic state shardings ---------------------------------

    def _state_shardings(self, template):
        """Elastic restore onto THIS mesh: queue lanes land sharded on
        their owning devices (``self.sharding``), everything else —
        proportion, round counter, fault schedule — replicated.  This is
        what lets a snapshot written under one topology (8-device mesh)
        restore onto another (1 device, or a reshaped mesh): the
        checkpoint holds full host arrays and placement is decided here,
        by the restoring runtime."""
        rep = NamedSharding(self.mesh, P())
        return {
            key: (_tmap(lambda _: self.sharding, template["queues"])
                  if key == "queues"
                  else _tmap(lambda _: rep, template[key]))
            for key in template
        }
