"""Device-resident admission: the serving master's queues AS executor lanes.

The host :class:`~repro.serve.scheduler.AdmissionMaster` keeps request
queues in Python objects and runs the steal plan in a loop — fine for a
handful of replicas, but it is exactly the layer the executors already
implement on device.  :class:`RuntimeAdmissionMaster` swaps the host
queues for executor lanes holding request IDs (4 bytes/request): one
ring per replica, admission is one bulk push, and every rebalance round
is a real ``master.superstep`` through
:func:`repro.distributed.launch_runtime` — vmap lanes on one device
(``execution="vmap"``) or one lane per device under shard_map
(``execution="mesh"``).  Request payloads (prompts, outputs) stay on the
host in an id-keyed table; only the IDs ride the rings, so the device
traffic per moved request is constant and tiny while the plan, the
adaptive proportion and the telemetry are the SAME code paths the DD
solver and the benchmarks exercise.

The class implements the master surface :class:`~repro.serve.engine.
ServeCluster` drives (``replicas`` / ``submit`` / ``rebalance_many`` /
``telemetry`` / ``stats``), so ``ServeCluster(execution="mesh")`` is a
drop-in switch.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import StealPolicy
from repro.distributed.launch import launch_runtime
from repro.runtime.adaptive import AdaptiveConfig
from repro.runtime.resilience import FaultPlan

__all__ = ["RuntimeAdmissionMaster", "DeviceReplicaLane"]

_SPEC = jax.ShapeDtypeStruct((), jnp.int32)


class DeviceReplicaLane:
    """One replica's view of its executor lane: the ``ReplicaQueue``
    surface (``load`` / ``pop_wave`` / ``finish_wave``) over ring slot
    ``replica_id`` of the master's runtime."""

    def __init__(self, master: "RuntimeAdmissionMaster", replica_id: int):
        self._master = master
        self.replica_id = replica_id
        self.in_flight = 0
        self.completed = 0
        self.evicted = False

    def __len__(self) -> int:
        return int(self._master.runtime.sizes()[self.replica_id])

    def load(self) -> int:
        return len(self) + self.in_flight

    def pop_wave(self, max_wave: int) -> List:
        """Pop up to ``max_wave`` newest request IDs off this lane —
        ONE owner-side bulk pop, not per-item dispatches — and resolve
        them to :class:`~repro.serve.scheduler.Request` objects, newest
        first (the host queues' LIFO discipline)."""
        rt = self._master.runtime
        i = self.replica_id
        qi = jax.tree_util.tree_map(lambda x: x[i], rt.queues)
        qi, batch, n = rt.ops.pop_bulk(qi, int(max_wave),
                                       jnp.int32(max_wave))
        rt.queues = jax.tree_util.tree_map(
            lambda full, one: full.at[i].set(one), rt.queues, qi)
        # pop_bulk returns the block oldest-first; reverse for LIFO.
        rids = np.asarray(batch)[: int(n)][::-1]
        wave = [self._master.lookup(int(r)) for r in rids]
        self.in_flight += len(wave)
        return wave

    def finish_wave(self, n: int) -> None:
        self.in_flight -= n
        self.completed += n

    # ``AdmissionMaster.rebalance`` reads ``r.q``; the cluster only ever
    # touches len()/load(), which this object answers itself.
    @property
    def q(self):
        return self


class RuntimeAdmissionMaster:
    """The single stealer + admission router, on executor lanes.

    Args:
      n_replicas: lanes (= devices along the worker mesh axis when
        ``execution="mesh"``).
      policy / adaptive / adaptive_config: as the host master; the
        policy's proportion seeds the runtime's adaptive controller.
      execution: ``"vmap"`` or ``"mesh"`` (see
        :func:`repro.distributed.launch_runtime`).
      capacity: per-lane ring capacity (queued request IDs per replica).
      mesh: optional pinned mesh for ``execution="mesh"``.
      item_spec: per-item ring payload.  The default (a scalar int32)
        is the id-keyed wave mode described above; the decode engine
        (:mod:`repro.serve.decode`) passes the full request-item spec so
        admitted prompts ride the rings and the superstep can steal
        them — when overriding, admit through ``runtime.push`` with
        batches of that spec rather than :meth:`submit`.
      max_pop: owner-side bulk-pop geometry (defaults to the ring
        capacity; the decode engine caps it at its slot count).
      elastic: arm the runtime's fault layer (an empty
        :class:`~repro.runtime.resilience.FaultPlan`) so
        :meth:`evict`/:meth:`readmit` can drain and mask lanes live —
        the default; both execution modes arm it, so vmap/mesh parity
        is preserved.
    """

    def __init__(self, n_replicas: int,
                 policy: Optional[StealPolicy] = None,
                 adaptive: bool = True,
                 adaptive_config: Optional[AdaptiveConfig] = None, *,
                 execution: str = "vmap",
                 capacity: int = 512,
                 mesh=None,
                 item_spec=None,
                 max_pop: Optional[int] = None,
                 elastic: bool = True):
        self.policy = policy or StealPolicy(proportion=0.5,
                                            low_watermark=1,
                                            high_watermark=8,
                                            max_steal=min(256, capacity))
        self.execution = execution
        self.item_spec = _SPEC if item_spec is None else item_spec
        extra = {} if max_pop is None else {"max_pop": max_pop}
        self.runtime = launch_runtime(
            n_replicas, capacity, self.item_spec, execution=execution,
            mesh=mesh, policy=self.policy, adaptive=adaptive,
            adaptive_config=adaptive_config,
            fault_plan=FaultPlan() if elastic else None, **extra)
        self.replicas = [DeviceReplicaLane(self, i)
                         for i in range(n_replicas)]
        self._requests: Dict[int, object] = {}
        self.stolen = 0
        # Automatic failure detection (attach_detector): None = off.
        # Deliberately SEPARATE from any runtime-level detector — this
        # one is fed wall-clock wave observations by the cluster, not
        # the replayed fault schedule.
        self.detector = None

    # -- request table -------------------------------------------------------

    def lookup(self, rid: int):
        return self._requests[rid]

    # -- the AdmissionMaster surface ----------------------------------------

    @property
    def telemetry(self):
        """The runtime's unified round + wave stream (the cluster appends
        ``WaveRecord``s here, next to real executor ``RoundRecord``s)."""
        return self.runtime.telemetry

    @property
    def controller(self):
        return self.runtime.controller

    @property
    def rounds(self) -> int:
        return self.runtime.rounds_run

    @property
    def proportion(self) -> float:
        return self.runtime.proportion

    def submit(self, requests: Sequence) -> int:
        """Bulk-admit to the least-loaded live replica: ONE ring splice
        of the request-id batch (constant latency in the batch size)."""
        requests = list(requests)
        if not requests:
            return -1
        live = [r for r in self.replicas if not r.evicted]
        if not live:
            raise RuntimeError("every replica is evicted; nothing can admit")
        target = min(live, key=lambda r: r.load())
        for r in requests:
            self._requests[r.rid] = r
        rids = jnp.asarray([r.rid for r in requests], jnp.int32)
        pushed = self.runtime.push(target.replica_id, rids, len(requests))
        if pushed < len(requests):
            raise RuntimeError(
                f"admission ring overflow on replica {target.replica_id}: "
                f"pushed {pushed}/{len(requests)} (capacity "
                f"{self.runtime.capacity})")
        return target.replica_id

    # -- planned eviction ----------------------------------------------------

    def evict(self, replica_id: int) -> int:
        """Planned eviction on device: kill the lane in the runtime's
        fault schedule, then run recovery rounds until its ring is empty
        — each round is the ordinary exchange superstep executing the
        proportion-1.0 dead-worker plan, so the drain costs zero new
        kernels.  Returns the number of queued requests drained off the
        lane.  Requires ``elastic=True`` (the default)."""
        from repro.distributed.elastic import evacuate

        lane = self.replicas[replica_id]
        drained = int(len(lane))
        evacuate(self.runtime, [replica_id])
        lane.evicted = True
        self.telemetry.record_fault("evict")
        return drained

    def readmit(self, replica_id: int) -> None:
        """Re-admit an evicted lane: revive it in the fault schedule so
        the next plans may route work back into it.  Detector state and
        straggler penalty for the lane clear (``revive_lane`` clears the
        runtime controller's attribution; the master's own detector is
        revived here)."""
        self.runtime.revive_lane(replica_id)
        if self.detector is not None:
            self.detector.revive(replica_id)
        self.replicas[replica_id].evicted = False
        self.telemetry.record_fault("readmit")

    def note_straggler(self, rounds: int = 4, factor: float = 1.5,
                       lane: Optional[int] = None) -> None:
        """A replica was flagged slow: delegates to the runtime (counter
        + temporary steal-proportion boost, attributed to ``lane``)."""
        self.runtime.note_straggler(rounds=rounds, factor=factor, lane=lane)

    def attach_detector(self, policy=None):
        """Arm the shared :class:`repro.runtime.detector.FailureDetector`
        escalation policy: SUSPECTED -> straggler boost, DEAD -> real
        on-device :meth:`evict` (lane killed, ring drained by recovery
        supersteps; recorded as ``auto_evict``).  The owner feeds
        observations; :meth:`readmit` revives.  Returns the detector."""
        from repro.runtime.detector import DetectorPolicy, FailureDetector

        pol = policy or DetectorPolicy()

        def on_suspect(rid: int) -> None:
            self.note_straggler(rounds=pol.boost_rounds,
                                factor=pol.boost_factor, lane=rid)

        def on_dead(rid: int) -> None:
            if not self.replicas[rid].evicted:
                self.evict(rid)
                self.telemetry.record_fault("auto_evict")

        def on_revive(rid: int) -> None:
            if self.controller is not None:
                self.controller.clear_straggler(rid)

        self.detector = FailureDetector(len(self.replicas), pol,
                                        on_suspect=on_suspect,
                                        on_dead=on_dead,
                                        on_revive=on_revive)
        return self.detector

    def rebalance(self) -> int:
        """One REAL rebalance round through the executor (plan + exchange
        + adaptive update + telemetry on device).  Returns requests
        moved."""
        before = self.runtime.telemetry.total_transferred
        self.runtime.round()
        moved = self.runtime.telemetry.total_transferred - before
        self.stolen += moved
        return moved

    def rebalance_many(self, k: int) -> int:
        """Up to ``k`` rounds per tick, stopping once a round moves
        nothing (the host master's early-exit discipline)."""
        moved = 0
        for _ in range(int(k)):
            step = self.rebalance()
            moved += step
            if step == 0:
                break
        return moved

    def stats(self) -> Dict:
        return {
            "loads": [r.load() for r in self.replicas],
            "queued": [len(r) for r in self.replicas],
            "completed": [r.completed for r in self.replicas],
            "evicted": [r.replica_id for r in self.replicas if r.evicted],
            "stolen": self.stolen,
            "rounds": self.rounds,
            "proportion": self.proportion,
            "execution": self.execution,
            "backend": self.runtime.ops.resolved,
            "telemetry": self.telemetry.summary(),
        }

    def metrics(self, registry=None):
        """Poll this master into a :class:`repro.obs.metrics.
        MetricsRegistry`: the admission surface (per-replica loads,
        steal totals, detector census) PLUS the backing runtime's lane
        metrics — one registry covers both layers of the device
        master."""
        from repro.obs.metrics import collect_runtime, master_metrics

        reg = master_metrics(self, registry)
        return collect_runtime(reg, self.runtime)
