"""Elastic shrink/grow: resize a running executor's worker set.

The runtime's fault layer (:mod:`repro.runtime.resilience`) already
drains a dead lane's ring into the survivors through the ordinary
compact-exchange superstep.  This module turns that primitive into the
fleet operations a production deployment needs:

* :func:`evacuate` — planned eviction of live lanes: kill them, run
  recovery rounds until their rings are empty (each round moves up to
  ``max_steal`` items per dead lane into the least-loaded survivors —
  proportion 1.0, zero new kernels or collectives).
* :func:`shrink` — evacuate, then rebuild the runtime over the smaller
  worker set, carrying the surviving rings, the adaptive proportion, the
  telemetry stream and the global round counter.  Works for both
  execution modes: the vmapped runtime just drops lanes, the mesh
  runtime is rebuilt on a mesh of the remaining devices (queue rows
  re-placed shard-by-shard).
* :func:`grow` — the inverse: rebuild with extra (empty, alive) lanes;
  the next rebalancing rounds feed them through the normal plan, so
  re-admitted capacity starts pulling work immediately.

Shrink and grow return a NEW runtime (lane count is a static shape —
changing it recompiles by construction); everything host-visible
(telemetry object, controller trajectory, ``rounds_run``) carries over
so the stream reads as one continuous run with ``shrink``/``grow``
events recorded in ``telemetry.fault_events``.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.executor import StealRuntime
from repro.runtime.resilience import FaultPlan

__all__ = ["evacuate", "shrink", "grow"]

_tmap = jax.tree_util.tree_map


def evacuate(rt: StealRuntime, lanes: Sequence[int], *,
             max_rounds: Optional[int] = None) -> int:
    """Kill ``lanes`` and run recovery rounds until their rings are
    empty.  Returns the number of rounds it took.  The runtime must have
    its fault layer armed (``fault_plan=`` at construction; an empty
    ``FaultPlan()`` suffices)."""
    lanes = [int(w) for w in lanes]
    if not lanes:
        return 0
    alive = rt.n_workers - int(rt.dead_lanes().sum()) - len(lanes)
    if alive < 1:
        raise ValueError("evacuating would leave no live lane to drain into")
    for w in lanes:
        rt.kill_lane(w)
    # Worst case each round moves max_steal items off one dead ring and
    # the thief-capacity clamp can slow the tail; 2x the naive bound.
    if max_rounds is None:
        per_round = max(int(rt.policy.max_steal), 1)
        max_rounds = 2 * (rt.capacity * len(lanes) // per_round + 2)
    rounds = 0
    while rounds < max_rounds:
        if int(rt.sizes()[lanes].sum()) == 0:
            break
        rt.round()
        rounds += 1
    left = int(rt.sizes()[lanes].sum())
    if left:
        raise RuntimeError(
            f"evacuation of lanes {lanes} incomplete after {rounds} rounds "
            f"({left} items stranded — survivors' rings full?)")
    rt.telemetry.record_fault("evacuate", len(lanes))
    return rounds


def _host_rows(rt: StealRuntime):
    """The stacked queue state as host numpy (one gather)."""
    return _tmap(lambda x: np.asarray(jax.device_get(x)), rt.queues)


def _rebuild(rt: StealRuntime, n_workers: int) -> StealRuntime:
    """A fresh runtime of the same species with ``n_workers`` lanes,
    same policy/backend/adaptive config, fault layer armed (schedules do
    NOT carry over — lane indices just changed meaning)."""
    kwargs: dict = dict(
        policy=rt.policy,
        adaptive=rt.controller is not None,
        adaptive_config=rt.controller.config if rt.controller else None,
        backend=rt.ops,  # the resolved instance: identical routing
        fault_plan=FaultPlan(),
    )
    if type(rt) is StealRuntime:
        return StealRuntime(n_workers, rt.capacity, rt.item_spec,
                            axis_name=rt.axis_name, **kwargs)
    from repro.distributed.executor import MeshStealRuntime
    from repro.launch.mesh import make_worker_mesh

    if not isinstance(rt, MeshStealRuntime):
        raise TypeError(f"don't know how to resize {type(rt).__name__}")
    mesh = make_worker_mesh(n_workers, axis_name=rt.axis_name)
    return MeshStealRuntime(mesh, rt.capacity, rt.item_spec, **kwargs)


def _carry_over(old: StealRuntime, new: StealRuntime, rows) -> StealRuntime:
    new.queues = _tmap(
        lambda tgt, arr: jax.device_put(jnp.asarray(arr), tgt.sharding),
        new.queues, rows)
    new.telemetry = old.telemetry
    new.rounds_run = old.rounds_run
    if new.controller is not None and old.controller is not None:
        new.controller.proportion = old.controller.proportion
        new.controller.history = list(old.controller.history)
    return new


def shrink(rt: StealRuntime, drop_lanes: Sequence[int]) -> StealRuntime:
    """Evacuate ``drop_lanes`` and rebuild the runtime without them.
    Lane ``i`` of the result is the i-th SURVIVING lane of the input (in
    order); the total item multiset is exactly preserved (evacuation is
    just steals).  Returns the new runtime."""
    drop = sorted({int(w) for w in drop_lanes})
    if not drop:
        return rt
    evacuate(rt, drop)
    rows = _tmap(lambda x: np.delete(x, drop, axis=0), _host_rows(rt))
    new = _rebuild(rt, rt.n_workers - len(drop))
    new = _carry_over(rt, new, rows)
    new.telemetry.record_fault("shrink", len(drop))
    return new


def grow(rt: StealRuntime, n_new: int) -> StealRuntime:
    """Rebuild with ``n_new`` extra lanes, empty and alive.  Existing
    lanes keep their rings and indices; the very next rebalancing rounds
    route work into the newcomers through the normal idle-thief plan."""
    n_new = int(n_new)
    if n_new <= 0:
        return rt
    rows = _host_rows(rt)
    new = _rebuild(rt, rt.n_workers + n_new)
    fresh = _tmap(lambda x: np.asarray(jax.device_get(x)), new.queues)

    def splice(old_arr, fresh_arr):
        out = fresh_arr.copy()
        out[: old_arr.shape[0]] = old_arr
        return out

    rows = _tmap(splice, rows, fresh)
    new = _carry_over(rt, new, rows)
    new.telemetry.record_fault("grow", n_new)
    return new
