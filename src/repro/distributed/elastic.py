"""Elastic shrink/grow: resize a running executor's worker set.

The runtime's fault layer (:mod:`repro.runtime.resilience`) already
drains a dead lane's ring into the survivors through the ordinary
compact-exchange superstep.  This module turns that primitive into the
fleet operations a production deployment needs:

* :func:`evacuate` — planned eviction of live lanes: kill them, run
  recovery rounds until their rings are empty (each round moves up to
  ``max_steal`` items per dead lane into the least-loaded survivors —
  proportion 1.0, zero new kernels or collectives).
* :func:`shrink` — evacuate, then rebuild the runtime over the smaller
  worker set, carrying the surviving rings, the adaptive proportion, the
  telemetry stream and the global round counter.  Works for both
  execution modes: the vmapped runtime just drops lanes, the mesh
  runtime is rebuilt on a mesh of the remaining devices (queue rows
  re-placed shard-by-shard).
* :func:`grow` — the inverse: rebuild with extra (empty, alive) lanes;
  the next rebalancing rounds feed them through the normal plan, so
  re-admitted capacity starts pulling work immediately.

Shrink and grow return a NEW runtime (lane count is a static shape —
changing it recompiles by construction); everything host-visible
(telemetry object, controller trajectory, ``rounds_run``) carries over
so the stream reads as one continuous run with ``shrink``/``grow``
events recorded in ``telemetry.fault_events``.

Live resize (no rebuild)
------------------------
The rebuild round-trip re-jits every compiled step — seconds of
compile latency exactly when the fleet is already disrupted.  The live
variant trades a bounded amount of padding memory for ZERO recompiles:

* :func:`padded_runtime` — construct the runtime at a fixed lane
  capacity ``w_max`` with only ``n_active`` lanes alive; the padding
  lanes are born dead (killed at round 0), so they hold no work, leave
  every plan, and cost only their (empty) ring buffers.
* :func:`live_shrink` / :func:`live_grow` — move the live-lane count
  within ``[1, w_max]`` by evacuating into survivors or reviving
  padding lanes.  The compiled step never changes: lane count is the
  SAME static shape, death is a traced schedule value, so resize is a
  host-side array write.  :func:`compile_count` exposes the jit cache
  population so tests can assert the no-retrace invariant.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.executor import StealRuntime
from repro.runtime.resilience import FaultPlan

__all__ = ["evacuate", "shrink", "grow", "padded_runtime", "live_shrink",
           "live_grow", "n_live", "compile_count"]

_tmap = jax.tree_util.tree_map


def evacuate(rt: StealRuntime, lanes: Sequence[int], *,
             max_rounds: Optional[int] = None) -> int:
    """Kill ``lanes`` and run recovery rounds until their rings are
    empty.  Returns the number of rounds it took.  The runtime must have
    its fault layer armed (``fault_plan=`` at construction; an empty
    ``FaultPlan()`` suffices)."""
    lanes = [int(w) for w in lanes]
    if not lanes:
        return 0
    alive = rt.n_workers - int(rt.dead_lanes().sum()) - len(lanes)
    if alive < 1:
        raise ValueError("evacuating would leave no live lane to drain into")
    for w in lanes:
        rt.kill_lane(w)
    # Worst case each round moves max_steal items off one dead ring and
    # the thief-capacity clamp can slow the tail; 2x the naive bound.
    if max_rounds is None:
        per_round = max(int(rt.policy.max_steal), 1)
        max_rounds = 2 * (rt.capacity * len(lanes) // per_round + 2)
    rounds = 0
    while rounds < max_rounds:
        if int(rt.sizes()[lanes].sum()) == 0:
            break
        rt.round()
        rounds += 1
    left = int(rt.sizes()[lanes].sum())
    if left:
        raise RuntimeError(
            f"evacuation of lanes {lanes} incomplete after {rounds} rounds "
            f"({left} items stranded — survivors' rings full?)")
    rt.telemetry.record_fault("evacuate", len(lanes))
    return rounds


def _host_rows(rt: StealRuntime):
    """The stacked queue state as host numpy (one gather)."""
    return _tmap(lambda x: np.asarray(jax.device_get(x)), rt.queues)


def _rebuild(rt: StealRuntime, n_workers: int) -> StealRuntime:
    """A fresh runtime of the same species with ``n_workers`` lanes,
    same policy/backend/adaptive config, fault layer armed (schedules do
    NOT carry over — lane indices just changed meaning)."""
    kwargs: dict = dict(
        policy=rt.policy,
        adaptive=rt.controller is not None,
        adaptive_config=rt.controller.config if rt.controller else None,
        backend=rt.ops,  # the resolved instance: identical routing
        fault_plan=FaultPlan(),
    )
    if type(rt) is StealRuntime:
        return StealRuntime(n_workers, rt.capacity, rt.item_spec,
                            axis_name=rt.axis_name, **kwargs)
    from repro.distributed.executor import MeshStealRuntime
    from repro.launch.mesh import make_worker_mesh

    if not isinstance(rt, MeshStealRuntime):
        raise TypeError(f"don't know how to resize {type(rt).__name__}")
    mesh = make_worker_mesh(n_workers, axis_name=rt.axis_name)
    return MeshStealRuntime(mesh, rt.capacity, rt.item_spec, **kwargs)


def _carry_over(old: StealRuntime, new: StealRuntime, rows) -> StealRuntime:
    new.queues = _tmap(
        lambda tgt, arr: jax.device_put(jnp.asarray(arr), tgt.sharding),
        new.queues, rows)
    new.telemetry = old.telemetry
    new.rounds_run = old.rounds_run
    if new.controller is not None and old.controller is not None:
        new.controller.proportion = old.controller.proportion
        new.controller.history = list(old.controller.history)
    return new


def shrink(rt: StealRuntime, drop_lanes: Sequence[int]) -> StealRuntime:
    """Evacuate ``drop_lanes`` and rebuild the runtime without them.
    Lane ``i`` of the result is the i-th SURVIVING lane of the input (in
    order); the total item multiset is exactly preserved (evacuation is
    just steals).  Returns the new runtime."""
    drop = sorted({int(w) for w in drop_lanes})
    if not drop:
        return rt
    evacuate(rt, drop)
    rows = _tmap(lambda x: np.delete(x, drop, axis=0), _host_rows(rt))
    new = _rebuild(rt, rt.n_workers - len(drop))
    new = _carry_over(rt, new, rows)
    new.telemetry.record_fault("shrink", len(drop))
    return new


def grow(rt: StealRuntime, n_new: int) -> StealRuntime:
    """Rebuild with ``n_new`` extra lanes, empty and alive.  Existing
    lanes keep their rings and indices; the very next rebalancing rounds
    route work into the newcomers through the normal idle-thief plan."""
    n_new = int(n_new)
    if n_new <= 0:
        return rt
    rows = _host_rows(rt)
    new = _rebuild(rt, rt.n_workers + n_new)
    fresh = _tmap(lambda x: np.asarray(jax.device_get(x)), new.queues)

    def splice(old_arr, fresh_arr):
        out = fresh_arr.copy()
        out[: old_arr.shape[0]] = old_arr
        return out

    rows = _tmap(splice, rows, fresh)
    new = _carry_over(rt, new, rows)
    new.telemetry.record_fault("grow", n_new)
    return new


# ---------------------------------------------------------------------------
# Live resize: fixed W_max, dead-masked padding lanes, zero recompiles


def padded_runtime(n_active: int, capacity: int, item_spec: Any, *,
                   w_max: int, execution: str = "vmap",
                   fault_plan: Optional[FaultPlan] = None,
                   **kwargs) -> StealRuntime:
    """A runtime built at lane capacity ``w_max`` with ``n_active`` live
    lanes: lanes ``[n_active, w_max)`` are PADDING — killed at round 0,
    empty, masked out of every plan.  Because the compiled step's shapes
    are fixed by ``w_max`` and liveness is a traced schedule value,
    later :func:`live_shrink`/:func:`live_grow` calls move the live
    count without a single recompile (the rebuild path re-jits; this
    path writes one host array).

    ``fault_plan`` schedules ADDITIONAL failures on the active lanes
    (indices below ``n_active``); padding kills are merged in.  All
    other ``kwargs`` (policy, backend, pod_size, ...) pass through to
    :func:`repro.distributed.launch.launch_runtime`."""
    n_active, w_max = int(n_active), int(w_max)
    if not (1 <= n_active <= w_max):
        raise ValueError(
            f"n_active={n_active} must be in [1, w_max={w_max}]")
    base = fault_plan or FaultPlan()
    for w, _ in base.kills:
        if w >= n_active:
            raise ValueError(
                f"fault_plan kills lane {w}, which is a padding lane "
                f"(>= n_active={n_active})")
    pad_kills = tuple((w, 0) for w in range(n_active, w_max))
    plan = FaultPlan(kills=base.kills + pad_kills, delays=base.delays,
                     drops=base.drops)
    from repro.distributed.launch import launch_runtime

    rt = launch_runtime(w_max, capacity, item_spec, execution=execution,
                        fault_plan=plan, **kwargs)
    rt.telemetry.record_fault("padded_launch", w_max - n_active)
    return rt


def n_live(rt: StealRuntime) -> int:
    """Live lanes as of the next round (W minus the dead mask)."""
    return rt.n_workers - int(rt.dead_lanes().sum())


def live_shrink(rt: StealRuntime, drop_lanes: Sequence[int]) -> int:
    """Shrink IN PLACE: evacuate ``drop_lanes`` into the survivors and
    leave them dead-masked (they become padding).  The compiled step is
    untouched — same runtime, same jit cache.  Returns the number of
    recovery rounds the evacuation took."""
    rounds = evacuate(rt, drop_lanes)
    rt.telemetry.record_fault("shrink_live", len(list(drop_lanes)))
    return rounds


def live_grow(rt: StealRuntime, n_new: int) -> List[int]:
    """Grow IN PLACE: revive ``n_new`` dead (padding) lanes, empty and
    alive — the next rounds feed them through the normal idle-thief
    plan.  Raises if fewer than ``n_new`` dead lanes exist (the ``w_max``
    headroom is spent — a bigger fleet needs :func:`grow`'s rebuild).
    Returns the lane indices revived (lowest-index-first)."""
    n_new = int(n_new)
    if n_new <= 0:
        return []
    dead = np.flatnonzero(rt.dead_lanes())
    if len(dead) < n_new:
        raise ValueError(
            f"live_grow({n_new}) needs {n_new} dead lanes but only "
            f"{len(dead)} exist — w_max headroom exhausted; use grow()")
    lanes = [int(w) for w in dead[:n_new]]
    for w in lanes:
        rt.revive_lane(w)
    rt.telemetry.record_fault("grow_live", n_new)
    return lanes


def compile_count(rt: StealRuntime) -> int:
    """Total jit-cache population across the runtime's compiled steps —
    the no-retrace assertion primitive: capture before a live resize,
    compare after (equal = zero recompiles)."""
    total = 0
    for fn in rt._compiled.values():
        try:
            total += int(fn._cache_size())
        except AttributeError:  # non-jit callable (test stub)
            total += 1
    return total
