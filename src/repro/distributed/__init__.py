"""repro.distributed — mesh-native execution of the full round loop.

The vmapped :class:`repro.runtime.StealRuntime` simulates W worker lanes
on one device; this package runs the SAME round body with one queue lane
per device of a real mesh axis, which is the paper's deployment shape:
each worker owns its queue, the (virtual) master is replicated, and at
most one stealer touches a victim per round — now with the rings
physically resident on their owners and the exchange collectives riding
ICI/DCN instead of vmap lanes.

  executor  :class:`MeshStealRuntime` — the whole fused round loop
            (worker bodies, exchange, adaptive update, telemetry) as one
            ``shard_map`` block with per-device donated queue shards
  launch    :func:`launch_runtime` — ``execution="vmap" | "mesh"`` in
            one factory, integrated with ``repro.launch.mesh``
  serve     :class:`RuntimeAdmissionMaster` — the serving cluster's
            admission/rebalance on executor lanes (request IDs on
            device, payloads on host), with planned eviction riding the
            fault layer's recovery supersteps
  elastic   :func:`evacuate` / :func:`shrink` / :func:`grow` — resize a
            running executor's worker set; dead rings drain through the
            ordinary exchange at proportion 1.0 before lanes are dropped

Parity contract: for identical seeds and policies, the mesh executor's
queues, stats and adaptive-proportion trajectory are bit-identical to
the vmapped executor's (asserted by ``tests/test_distributed.py`` on 8
fake host devices; the telemetry reduction is shared, not duplicated).
"""

from repro.distributed.elastic import evacuate, grow, shrink
from repro.distributed.executor import MeshStealRuntime
from repro.distributed.launch import launch_runtime
from repro.distributed.serve import RuntimeAdmissionMaster

__all__ = ["MeshStealRuntime", "launch_runtime", "RuntimeAdmissionMaster",
           "evacuate", "grow", "shrink"]
