"""One entry point for "give me an executor": vmap lanes or a real mesh.

``launch_runtime`` is how consumers (the DD solver's ``parallel_solve``,
the serving cluster, the benchmarks) select the execution mode without
knowing either runtime class: ``execution="vmap"`` builds the
single-device lane simulation (:class:`repro.runtime.StealRuntime`),
``execution="mesh"`` builds the device-per-lane
:class:`~repro.distributed.executor.MeshStealRuntime` on a worker mesh
from :func:`repro.launch.mesh.make_worker_mesh` (or a mesh you pass in).
Both return the same object surface — ``push`` / ``round`` /
``run_fused`` / ``run`` / ``telemetry`` — with the same axis names, so
worker bodies and driving code are mode-agnostic.
"""

from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.distributed.executor import MeshStealRuntime
from repro.launch.mesh import make_worker_mesh
from repro.runtime.executor import StealRuntime

__all__ = ["launch_runtime"]

EXECUTIONS = ("vmap", "mesh")


def launch_runtime(n_workers: int, capacity: int, item_spec, *,
                   execution: str = "mesh",
                   mesh: Optional[Mesh] = None,
                   pod_size: Optional[int] = None,
                   axis_name: str = "workers",
                   pod_axis: str = "pods",
                   **kwargs) -> StealRuntime:
    """Construct the executor for ``execution`` in ``("vmap", "mesh")``.

    ``pod_size`` selects hierarchical supersteps in either mode (a 2-D
    ``(pod, worker)`` mesh when ``execution="mesh"``).  ``mesh``
    optionally pins the mesh instead of building one over the first
    ``n_workers`` process devices; it must agree with ``n_workers`` /
    ``pod_size``.  Remaining keywords (``policy`` / ``adaptive`` /
    ``adaptive_config`` / ``backend`` / ``max_pop`` / ``fault_plan``)
    pass through to the runtime unchanged.
    """
    if execution == "vmap":
        if mesh is not None:
            raise ValueError("execution='vmap' takes no mesh")
        return StealRuntime(n_workers, capacity, item_spec,
                            axis_name=axis_name, pod_size=pod_size,
                            pod_axis=pod_axis, **kwargs)
    if execution != "mesh":
        raise ValueError(
            f"unknown execution {execution!r}; expected one of {EXECUTIONS}")
    if mesh is None:
        mesh = make_worker_mesh(n_workers, pod_size=pod_size,
                                axis_name=axis_name, pod_axis=pod_axis)
    else:
        if int(mesh.devices.size) != n_workers:
            raise ValueError(
                f"mesh has {mesh.devices.size} devices but n_workers="
                f"{n_workers}")
        # A pinned mesh must agree with the requested hierarchy — a flat
        # mesh with pod_size (or vice versa) would silently run the
        # OTHER superstep mode.
        mesh_pod = (int(mesh.shape[mesh.axis_names[-1]])
                    if len(mesh.axis_names) == 2 else None)
        if pod_size != mesh_pod:
            raise ValueError(
                f"mesh implies pod_size={mesh_pod} (axes "
                f"{tuple(mesh.axis_names)}) but pod_size={pod_size} was "
                f"requested")
    return MeshStealRuntime(mesh, capacity, item_spec, **kwargs)
