"""Host-side data pipeline on the paper's queue: per-host shard queues
with bulk refill and straggler bulk-steal.

Concurrency model is EXACTLY the paper's: each host queue has one owner
(the host's feeder) and at most one stealer (the pipeline master).  The
queue is any ``core.host_queue.HostQueue`` implementation (default: the
faithful paper port, LinkedWSQueue): bulk push of prefetched batches,
single pop by the training step, and the master's proportional steal(p)
when a host falls behind.

A "task" here is a (shard, step) descriptor — regenerating any batch is
deterministic (data.synthetic), so stolen descriptors are recomputed by
the thief host with zero data movement (locality: only 8 bytes/task
travel, the paper's cheap-bulk-transfer property taken to its limit).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.host_queue import HostQueue, LinkedWSQueue
from repro.core.policy import StealPolicy, adaptive_chunk
from repro.train.fault import StragglerMonitor

__all__ = ["HostShardQueue", "PipelineMaster", "WorkStealingPipeline"]

Task = Tuple[int, int]  # (shard, step)


class HostShardQueue:
    """Owner side: prefetch task descriptors in bulk; pop per train step."""

    def __init__(self, shard: int, prefetch: int = 64,
                 queue_factory: Callable[[], HostQueue] = LinkedWSQueue):
        self.shard = shard
        self.q: HostQueue = queue_factory()
        self.prefetch = prefetch
        self._next_step = 0
        self.monitor = StragglerMonitor()

    def refill(self) -> int:
        """Bulk push the next `prefetch` task descriptors (one splice)."""
        tasks = [(self.shard, self._next_step + i)
                 for i in range(self.prefetch)]
        self._next_step += self.prefetch
        # push_bulk's deque convention (later = newer): the owner pops
        # the newest step first while the oldest steps sit at the steal
        # side for the master.
        self.q.push_bulk(tasks)
        return len(tasks)

    def pop(self) -> Optional[Task]:
        if len(self.q) == 0:
            self.refill()
        return self.q.pop_item()


class PipelineMaster:
    """The single stealer: watches per-host consumption, bulk-steals task
    descriptors from stragglers, and re-assigns them to fast hosts."""

    def __init__(self, queues: List[HostShardQueue],
                 policy: Optional[StealPolicy] = None):
        self.queues = queues
        self.policy = policy or StealPolicy(proportion=0.5)
        self.stolen_total = 0
        self.rounds = 0

    def rebalance(self, slow: List[int], fast: List[int]) -> int:
        """One master round: steal from each slow host, splice into fast
        hosts round-robin.  Returns tasks moved."""
        self.rounds += 1
        moved = 0
        if not slow or not fast:
            return 0
        p = adaptive_chunk(len(fast), len(slow), self.policy.proportion)
        grabbed: List[Task] = []
        for s in slow:
            stolen = self.queues[s].q.steal_bulk(p)
            grabbed.extend(stolen)
            moved += len(stolen)
        for i, task in enumerate(grabbed):
            tq = self.queues[fast[i % len(fast)]]
            tq.q.push_bulk([task])
        self.stolen_total += moved
        return moved


class WorkStealingPipeline:
    """Drives H host queues + master; ``next_batch(host)`` is what a
    training loop calls.  Generation happens on the popping host via the
    deterministic ``make_batch`` (no payload movement on steal)."""

    def __init__(self, n_hosts: int, make_batch: Callable[[int, int], Dict],
                 prefetch: int = 64, policy: Optional[StealPolicy] = None):
        self.queues = [HostShardQueue(h, prefetch) for h in range(n_hosts)]
        self.master = PipelineMaster(self.queues, policy)
        self.make_batch = make_batch
        self._lock = threading.Lock()

    def next_batch(self, host: int) -> Dict:
        self.queues[host].monitor.start()
        task = self.queues[host].pop()
        if task is None:  # stolen dry: refill own shard
            self.queues[host].refill()
            task = self.queues[host].pop()
        batch = self.make_batch(*task)
        straggler = self.queues[host].monitor.observe()
        if straggler:
            with self._lock:
                fast = [h for h in range(len(self.queues)) if h != host]
                self.master.rebalance([host], fast)
        return batch

    def stats(self) -> Dict:
        return {
            "stolen_total": self.master.stolen_total,
            "rounds": self.master.rounds,
            "sizes": [len(q.q) for q in self.queues],
            "straggler_steps": [q.monitor.straggler_steps
                                for q in self.queues],
        }
