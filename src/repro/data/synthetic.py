"""Deterministic synthetic LM data (seeded, shardable, restartable).

Shards are indexed (shard_id, step) -> batch, so the iterator state is
just two integers — exactly what rides in checkpoint meta for exact
resume — and any host can regenerate any other host's shard (which is
what makes bulk-stealing shards between hosts trivially consistent).
"""

from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np

__all__ = ["synth_batch", "SynthDataset"]


def synth_batch(seed: int, shard: int, step: int, batch: int, seq: int,
                vocab: int) -> Dict[str, np.ndarray]:
    """Markov-ish token stream: deterministic in (seed, shard, step)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, shard, step]))
    # zipf-flavored marginals so the loss curve is non-trivial
    base = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


class SynthDataset:
    """Per-host shard view with explicit, checkpointable state."""

    def __init__(self, *, seed: int, shard: int, n_shards: int, batch: int,
                 seq: int, vocab: int, step: int = 0):
        self.seed, self.shard, self.n_shards = seed, shard, n_shards
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.step = step

    def state(self) -> dict:
        return {"seed": self.seed, "shard": self.shard, "step": self.step}

    @classmethod
    def from_state(cls, state: dict, **kw) -> "SynthDataset":
        return cls(seed=state["seed"], shard=state["shard"],
                   step=state["step"], **kw)

    def next(self) -> Dict[str, np.ndarray]:
        b = synth_batch(self.seed, self.shard, self.step, self.batch,
                        self.seq, self.vocab)
        self.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()
