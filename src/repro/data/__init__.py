from repro.data.synthetic import SynthDataset, synth_batch
from repro.data.pipeline import WorkStealingPipeline

__all__ = ["SynthDataset", "synth_batch", "WorkStealingPipeline"]
