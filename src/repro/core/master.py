"""The virtual master: SPMD bulk work-stealing rebalancing.

The paper's master thread is the *single stealer* for every worker queue and
decides when/from whom/to whom work moves (§II.B).  On a TPU mesh there is no
shared memory to steal through; the equivalent construction is:

  1. ``all_gather`` the per-worker queue sizes (4 bytes/worker — the master's
     "bookkeeping").
  2. Every device runs :func:`repro.core.policy.plan_transfers` on the
     identical size vector, producing the identical ``(victim -> thief, n)``
     plan — a **replicated virtual master**.  At most one steal per victim
     per round preserves the paper's single-stealer invariant, now at
     superstep granularity.
  3. The stolen blocks move in one collective **exchange** and each thief
     splices its block with one bulk push.  Two exchange implementations
     share the plan (``StealPolicy.exchange``):

     ``"compact"`` (default)
         Each lane contributes ONE raw ``(max_steal, ...)`` tail window
         to an ``all_gather``; the victim's detach is a pure cursor bump
         (no masked block is materialized) and the thief cuts its
         victim's segment straight out of the gathered stack and splices
         it — one fused ``kernels.queue_transfer.ring_transfer`` kernel
         on a kernel-routed backend.  Injected payload is
         **O(max_steal)** per lane per round, independent of W.  A
         replicated ``lax.cond`` on the plan skips the window build, the
         collective and the splice entirely on rounds that move nothing
         (the plan is identical on all lanes, so every device takes the
         same branch).
     ``"dense"``
         The original construction: a ``(W, max_steal, ...)`` outbox per
         lane (only the thief's row populated) moved by ``all_to_all``,
         inbox collapsed by summation.  Injected payload is
         **O(W * max_steal)** per lane per round — kept as the exchange
         oracle the compact path is property-tested against, and as the
         baseline column of the Fig. 10 scaling benchmark.

Because the whole round is one deterministic collective schedule, the
paper's consistency re-checks (drain detection) are provably unnecessary
here: owner pops and master steals can never interleave within a round.
That argument is tested (property tests assert no task is lost or
duplicated across arbitrary rounds, and that both exchanges produce
identical queues).

Scaling: the compact exchange keeps the per-round collective payload flat
in W (``RebalanceStats.bytes_moved`` reports it; ``benchmarks/
fig10_scaling.py`` sweeps W x max_steal x exchange), so the flat
superstep now scales to W >= 256 without the O(W * max_steal) payload
blow-up the dense exchange pays.  For multi-pod meshes
:func:`hierarchical_superstep` still composes the same plan within each
pod and then across pod representatives — that matches the paper's
planned MPI extension (single coordinator per machine group, §II.B) and
keeps DCN traffic at one block per pod.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ops as bulk_ops
from repro.core.ops import QueueState
from repro.core.policy import StealPolicy, plan_transfers

__all__ = ["RebalanceStats", "superstep", "hierarchical_superstep",
           "gather_sizes", "exchange_probe", "probe_token"]

Pytree = Any


class RebalanceStats(NamedTuple):
    """Per-round observability (replicated values). NamedTuple => pytree.

    ``n_transferred`` / ``n_steals`` count the transfers planned at THIS
    level's axis (replicated across the lanes that computed the plan).
    Under :func:`hierarchical_superstep` they hold the intra-pod share
    only (distinct per pod, replicated within a pod) while
    ``n_transferred_xpod`` / ``n_steals_xpod`` hold the cross-pod share
    — nonzero only on each pod's lane-0 representative and replicated
    across pods there, so an exact global total is
    ``sum_over_pods(intra at lane 0) + xpod at any lane 0`` with no
    double counting (the flat superstep reports zeros for the xpod
    fields).

    ``bytes_moved`` is the payload this lane injected into the block
    exchange collective this round (items x item bytes; the 4-byte/lane
    size gathers and counts are excluded): ``W * max_steal * item_bytes``
    for the dense exchange — unconditionally, the outbox moves even when
    the plan is empty — vs ``max_steal * item_bytes`` for the compact
    exchange on rounds that transfer and 0 on rounds the fast path
    skips.  Unlike the transfer counters this field stays PER-LANE
    (saturated at INT32_MAX).  Under :func:`hierarchical_superstep`,
    ``bytes_moved`` holds the intra-pod injection and
    ``bytes_moved_xpod`` the pod-level one — which, exchange semantics
    being physical, is nonzero on every lane for the dense exchange
    (all lane groups pay the pod-level outbox) but only on transferring
    representatives for the compact one; the executor reports the
    busiest lane's total (max intra + xpod).
    """

    sizes_before: jnp.ndarray
    sizes_after: jnp.ndarray
    n_transferred: jnp.ndarray
    n_steals: jnp.ndarray
    n_transferred_xpod: jnp.ndarray
    n_steals_xpod: jnp.ndarray
    bytes_moved: jnp.ndarray
    bytes_moved_xpod: jnp.ndarray


def gather_sizes(q: QueueState, *, worker_axis: str,
                 pod_axis: str | None = None) -> jnp.ndarray:
    """The master's bookkeeping as ONE flat vector: every lane's true
    queue size, gathered over the worker axis (and, when two-level, the
    pod axis), in lane order ``pod * pod_size + worker`` — the same
    order the executors stack lanes in.  4 bytes per lane per level;
    replicated on every lane, so callers may feed it to the adaptive
    controller or a drain check and every device takes the same branch.
    Works identically under ``vmap(axis_name=...)`` and ``shard_map``.
    """
    sizes = lax.all_gather(q.size, worker_axis)  # (pod_size,) or (W,)
    if pod_axis is None:
        return sizes
    return lax.all_gather(sizes, pod_axis).reshape(-1)  # (n_pods*pod_size,)


def _resolve_ops(policy: StealPolicy, q: QueueState) -> bulk_ops.BulkOps:
    """Resolve the BulkOps backend from ``policy.backend`` and the queue
    geometry — at trace time, once per compilation (this is where
    ``"auto"`` consults the kernel geometry predicates; the master's
    push is the thief splice, bounded by ``max_steal``)."""
    cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
    return bulk_ops.make_ops(policy.backend, capacity=cap,
                             max_push=policy.max_steal,
                             max_steal=policy.max_steal)


def _item_nbytes(q: QueueState) -> int:
    """Static per-item payload bytes (trace-time): the ring leaves minus
    their leading capacity dimension, through the one shared
    ``ops.item_nbytes`` accounting."""
    return bulk_ops.item_nbytes(jax.tree_util.tree_map(
        lambda b: jax.ShapeDtypeStruct(b.shape[1:], b.dtype), q.buf))


def _payload_i32(nbytes: int) -> jnp.ndarray:
    """Static payload byte count as int32, saturated at INT32_MAX —
    ``bytes_moved`` is telemetry, and a >2 GiB/lane/round dense payload
    (huge items x large W) must not turn into a trace-time
    OverflowError."""
    return jnp.int32(min(int(nbytes), 2**31 - 1))


def _dense_exchange(q, ops, policy, axis_name, n_workers, me, idx, src, amt
                    ) -> Tuple[QueueState, jnp.ndarray]:
    """The O(W * max_steal)-payload exchange: per-lane outbox +
    ``all_to_all`` + summed inbox.  Kept as the oracle the compact path
    is tested against and as the Fig. 10 baseline column."""
    # Who steals from me, and how much?  (at most one thief per victim)
    steals_me = (src == me) & (amt > 0) & (idx != me)
    stolen_amt = jnp.sum(jnp.where(steals_me, amt, 0))
    thief_id = jnp.argmax(steals_me).astype(jnp.int32)  # 0 when none (amt==0)

    # Victim severs its tail block — single cursor bump linearizes.
    # With a kernel-routed backend the detach is the Pallas ring-gather.
    q, block, n_out = ops.steal_exact(q, stolen_amt,
                                      max_steal=policy.max_steal)

    # Outbox: one row per peer, only the thief's row is populated.
    def _outbox(x):
        out = jnp.zeros((n_workers,) + x.shape, x.dtype)
        return out.at[thief_id].set(jnp.where(n_out > 0, x, jnp.zeros_like(x)))

    outbox = jax.tree_util.tree_map(_outbox, block)
    counts = jnp.zeros((n_workers,), jnp.int32).at[thief_id].set(n_out)

    # One bulk exchange: row j of the inbox is what peer j sent to me.
    inbox = jax.tree_util.tree_map(
        lambda x: lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0),
        outbox,
    )
    counts_in = lax.all_to_all(counts, axis_name, split_axis=0, concat_axis=0)

    # Thief splices: at most one row is non-empty, blocks are pre-masked
    # so a sum collapses the inbox without a gather.  With a kernel-routed
    # backend the splice is the Pallas ring-scatter kernel.
    recv_n = jnp.sum(counts_in)
    recv = jax.tree_util.tree_map(lambda x: jnp.sum(x, axis=0), inbox)
    q, _ = ops.push(q, recv, recv_n)

    bytes_moved = _payload_i32(n_workers * policy.max_steal
                               * _item_nbytes(q))
    return q, bytes_moved


def _compact_exchange(q, ops, policy, axis_name, me, idx, sizes, src, amt
                      ) -> Tuple[QueueState, jnp.ndarray]:
    """The O(max_steal)-payload exchange: one raw window all_gather +
    thief-side fused cut-and-splice, with a replicated zero-transfer
    fast path."""
    max_steal = policy.max_steal
    cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
    any_transfer = jnp.any(amt > 0)

    def move(q):
        # Victim side: how much the plan severs from me.  The detach is
        # the cursor bump alone — the collective carries my raw window,
        # so no masked intermediate block is ever materialized.
        steals_me = (src == me) & (amt > 0) & (idx != me)
        stolen_amt = jnp.sum(jnp.where(steals_me, amt, 0))
        n_out = jnp.clip(stolen_amt, 0,
                         jnp.minimum(q.size, jnp.int32(max_steal)))
        window = ops.window(q, max_steal=max_steal)
        gathered = jax.tree_util.tree_map(
            lambda x: lax.all_gather(x, axis_name), window)
        q = QueueState(buf=q.buf, lo=(q.lo + n_out) % cap,
                       size=q.size - n_out)

        # Thief side: my row of the replicated plan names my victim; the
        # count is re-derived from the same replicated inputs the victim
        # clamped against (sizes gathered BEFORE any cursor moved), so
        # victim and thief agree exactly.
        my_src = src[me]
        my_amt = amt[me]
        is_thief = (my_amt > 0) & (my_src != me)
        recv_n = jnp.where(
            is_thief,
            jnp.clip(my_amt, 0,
                     jnp.minimum(sizes[my_src], jnp.int32(max_steal))),
            0,
        )
        q, _ = ops.transfer(q, gathered, my_src, recv_n,
                            max_steal=max_steal)
        return q

    # Replicated fast path: the plan is identical on every lane, so all
    # devices take the same branch and rounds that move nothing skip the
    # window build, the collective and the splice entirely.
    q = lax.cond(any_transfer, move, lambda q: q, q)
    bytes_moved = jnp.where(any_transfer,
                            _payload_i32(max_steal * _item_nbytes(q)),
                            jnp.int32(0))
    return q, bytes_moved


def superstep(
    q: QueueState,
    policy: StealPolicy,
    *,
    axis_name: str,
    ops: bulk_ops.BulkOps | None = None,
    exchange: str | None = None,
    plan: jnp.ndarray | None = None,
) -> Tuple[QueueState, RebalanceStats]:
    """One rebalancing round.  Must run inside ``shard_map`` (or
    ``vmap(axis_name=...)`` for host-side testing) over ``axis_name`` where
    each lane owns one :class:`QueueState`.

    ``ops`` is the :class:`~repro.core.ops.BulkOps` backend serving the
    victim-side detach and the thief-side splice; when omitted it is
    resolved from ``policy.backend`` and the queue geometry ONCE at trace
    time (``"auto"`` consults the kernel geometry predicates here, never
    per call).  ``exchange`` overrides ``policy.exchange``
    (``"compact"`` / ``"dense"`` — see the module docstring).

    ``plan`` optionally substitutes the replicated transfer plan (int32
    ``(W, 2)``, the :func:`~repro.core.policy.plan_transfers` layout) for
    the one computed here.  The caller must have derived it from the SAME
    replicated inputs every lane sees (the gathered size vector before
    any cursor moved), so victim- and thief-side clamps still agree —
    this is how the resilience layer routes recovery steals (a dead
    lane's ring at proportion 1.0) through the existing exchange without
    new collectives or kernels.
    """
    if ops is None:
        ops = _resolve_ops(policy, q)
    if exchange is None:
        exchange = policy.exchange
    # psum of a literal folds to the static axis size (jax<0.5 has no
    # lax.axis_size).
    n_workers = lax.psum(1, axis_name)
    me = lax.axis_index(axis_name)
    idx = jnp.arange(n_workers, dtype=jnp.int32)

    # (1) master bookkeeping: gather sizes.
    sizes = lax.all_gather(q.size, axis_name)  # (W,) identical on all lanes

    # (2) replicated plan.
    if plan is None:
        plan = plan_transfers(sizes, policy)  # (W, 2): row t = (victim, n)
    src, amt = plan[:, 0], plan[:, 1]

    # (3) the block exchange.
    if exchange == "dense":
        q, bytes_moved = _dense_exchange(q, ops, policy, axis_name,
                                         n_workers, me, idx, src, amt)
    elif exchange == "compact":
        q, bytes_moved = _compact_exchange(q, ops, policy, axis_name,
                                           me, idx, sizes, src, amt)
    else:
        raise ValueError(
            f"unknown exchange {exchange!r}; expected 'compact' or 'dense'")

    sizes_after = lax.all_gather(q.size, axis_name)
    if bulk_ops._env_check():
        # Sanitizer on (REPRO_CHECK=1, decided at trace time): assert in
        # trace that this level's exchange conserved its gathered sizes.
        from repro.analysis import sanitize

        cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
        sanitize.trace_check_superstep(sizes, sizes_after, capacity=cap)
    stats = RebalanceStats(
        sizes_before=sizes,
        sizes_after=sizes_after,
        n_transferred=jnp.sum(jnp.where(amt > 0, amt, 0)),
        n_steals=jnp.sum((amt > 0).astype(jnp.int32)),
        n_transferred_xpod=jnp.int32(0),
        n_steals_xpod=jnp.int32(0),
        bytes_moved=bytes_moved,
        bytes_moved_xpod=jnp.int32(0),
    )
    return q, stats


def probe_token(q: QueueState) -> jnp.ndarray:
    """Collapse a queue into one float32 scalar that data-depends on its
    cursors AND its buffer contents — the phase probe's anti-DCE sink
    (XLA cannot eliminate work whose result feeds the returned token).
    One element per ring leaf is enough: a collective or a splice cannot
    be partially computed, so keeping any element live keeps the whole
    producing op live."""
    token = q.size.astype(jnp.float32) + q.lo.astype(jnp.float32)
    for leaf in jax.tree_util.tree_leaves(q.buf):
        token = token + leaf.reshape(-1)[0].astype(jnp.float32)
    return token


def exchange_probe(
    q: QueueState,
    policy: StealPolicy,
    *,
    axis_name: str,
    ops: bulk_ops.BulkOps | None = None,
    exchange: str | None = None,
    plan: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """The superstep's gather + plan + block-exchange PREFIX, reduced to one
    DCE-proof float32 token — the phase probe's "exchange" programs
    (``repro.obs.phase``) end here.

    Runs the exact collective schedule :func:`superstep` runs up to and
    including the block exchange (same size gather, same replicated plan,
    same compact fast path / dense outbox), then collapses the resulting
    queue into a scalar that data-depends on the spliced buffer contents
    and the moved cursors, so XLA cannot dead-code-eliminate any of the
    exchange work.  The queue itself is discarded — callers time this
    program on immutable inputs and throw the result away; it never
    commits state.  Stats, the sanitizer hook and the post-exchange size
    gather are deliberately omitted: those belong to the ``splice``/
    bookkeeping tail the probe attributes by subtraction.
    """
    if ops is None:
        ops = _resolve_ops(policy, q)
    if exchange is None:
        exchange = policy.exchange
    n_workers = lax.psum(1, axis_name)
    me = lax.axis_index(axis_name)
    idx = jnp.arange(n_workers, dtype=jnp.int32)

    sizes = lax.all_gather(q.size, axis_name)
    if plan is None:
        plan = plan_transfers(sizes, policy)
    src, amt = plan[:, 0], plan[:, 1]

    if exchange == "dense":
        q, _ = _dense_exchange(q, ops, policy, axis_name,
                               n_workers, me, idx, src, amt)
    elif exchange == "compact":
        q, _ = _compact_exchange(q, ops, policy, axis_name,
                                 me, idx, sizes, src, amt)
    else:
        raise ValueError(
            f"unknown exchange {exchange!r}; expected 'compact' or 'dense'")

    return probe_token(q)


def hierarchical_superstep(
    q: QueueState,
    policy: StealPolicy,
    *,
    worker_axis: str,
    pod_axis: str,
    ops: bulk_ops.BulkOps | None = None,
) -> Tuple[QueueState, RebalanceStats]:
    """Two-level rebalancing for multi-pod meshes: first the flat superstep
    within each pod (cheap ICI), then one superstep across pods where each
    pod's lane-0 worker acts as the pod representative (DCN-scale traffic is
    one block per pod, not per worker).  ``ops`` as in :func:`superstep`
    (resolved once, shared by both levels; the exchange routing follows
    ``policy.exchange`` at both levels)."""
    if ops is None:
        ops = _resolve_ops(policy, q)
    q, stats = superstep(q, policy, axis_name=worker_axis, ops=ops)

    # Cross-pod: only lane 0 of each pod participates with its real size;
    # other lanes advertise "full enough not to be idle, small enough not
    # to be a victim" so the plan ignores them.
    me = lax.axis_index(worker_axis)
    sentinel = jnp.int32(policy.low_watermark + 1)
    eff_size = jnp.where(me == 0, q.size, sentinel)
    q_eff = QueueState(buf=q.buf, lo=q.lo, size=eff_size)
    q_eff, pod_stats = superstep(q_eff, policy, axis_name=pod_axis, ops=ops)
    # Restore true size accounting for what moved at pod level.
    delta = q_eff.size - eff_size
    q = QueueState(buf=q_eff.buf, lo=q_eff.lo, size=q.size + delta)

    # Exact per-level accounting: the intra-pod share stays in
    # n_transferred/n_steals; the pod-level plan's counts go in the xpod
    # fields.  Lanes l > 0 gathered sentinel sizes at pod level, so their
    # pod_stats are zero — the xpod fields are nonzero only on lane-0
    # representatives, where they are replicated across pods.
    stats = stats._replace(
        n_transferred_xpod=pod_stats.n_transferred,
        n_steals_xpod=pod_stats.n_steals,
        bytes_moved_xpod=pod_stats.bytes_moved,
        sizes_after=pod_stats.sizes_after,
    )
    return q, stats
