"""The virtual master: SPMD bulk work-stealing rebalancing.

The paper's master thread is the *single stealer* for every worker queue and
decides when/from whom/to whom work moves (§II.B).  On a TPU mesh there is no
shared memory to steal through; the equivalent construction is:

  1. ``all_gather`` the per-worker queue sizes (4 bytes/worker — the master's
     "bookkeeping").
  2. Every device runs :func:`repro.core.policy.plan_transfers` on the
     identical size vector, producing the identical ``(victim -> thief, n)``
     plan — a **replicated virtual master**.  At most one steal per victim
     per round preserves the paper's single-stealer invariant, now at
     superstep granularity.
  3. Victims sever their tail block locally (``steal_exact`` — a single
     cursor bump is the linearization point) and the blocks move in **one**
     ``all_to_all``.  Thieves splice the received block with one bulk
     ``push``.

Because the whole round is one deterministic collective schedule, the
paper's consistency re-checks (drain detection) are provably unnecessary
here: owner pops and master steals can never interleave within a round.
That argument is tested (property tests assert no task is lost or
duplicated across arbitrary rounds).

Scaling note (1000+ workers): the flat ``all_to_all`` moves
``n_workers * max_steal`` items per lane per round.  For multi-pod meshes use
:func:`hierarchical_superstep`, which runs the same plan within each pod and
then across pod representatives — this matches the paper's planned MPI
extension (single coordinator per machine group, §II.B).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ops as bulk_ops
from repro.core.ops import QueueState
from repro.core.policy import StealPolicy, plan_transfers

__all__ = ["RebalanceStats", "superstep", "hierarchical_superstep"]

Pytree = Any


class RebalanceStats(NamedTuple):
    """Per-round observability (replicated values). NamedTuple => pytree.

    ``n_transferred`` / ``n_steals`` count the transfers planned at THIS
    level's axis (replicated across the lanes that computed the plan).
    Under :func:`hierarchical_superstep` they hold the intra-pod share
    only (distinct per pod, replicated within a pod) while
    ``n_transferred_xpod`` / ``n_steals_xpod`` hold the cross-pod share
    — nonzero only on each pod's lane-0 representative and replicated
    across pods there, so an exact global total is
    ``sum_over_pods(intra at lane 0) + xpod at any lane 0`` with no
    double counting (the flat superstep reports zeros for the xpod
    fields).
    """

    sizes_before: jnp.ndarray
    sizes_after: jnp.ndarray
    n_transferred: jnp.ndarray
    n_steals: jnp.ndarray
    n_transferred_xpod: jnp.ndarray
    n_steals_xpod: jnp.ndarray


def _resolve_ops(policy: StealPolicy, q: QueueState) -> bulk_ops.BulkOps:
    """Resolve the BulkOps backend from ``policy.backend`` and the queue
    geometry — at trace time, once per compilation (this is where
    ``"auto"`` consults the kernel geometry predicates; the master's
    push is the thief splice, bounded by ``max_steal``)."""
    cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
    return bulk_ops.make_ops(policy.backend, capacity=cap,
                             max_push=policy.max_steal,
                             max_steal=policy.max_steal)


def _mask_rows(batch: Pytree, live: jnp.ndarray) -> Pytree:
    def _m(x):
        shape = (live.shape[0],) + (1,) * (x.ndim - 1)
        return jnp.where(live.reshape(shape), x, jnp.zeros_like(x))

    return jax.tree_util.tree_map(_m, batch)


def superstep(
    q: QueueState,
    policy: StealPolicy,
    *,
    axis_name: str,
    ops: bulk_ops.BulkOps | None = None,
) -> Tuple[QueueState, RebalanceStats]:
    """One rebalancing round.  Must run inside ``shard_map`` (or
    ``vmap(axis_name=...)`` for host-side testing) over ``axis_name`` where
    each lane owns one :class:`QueueState`.

    ``ops`` is the :class:`~repro.core.ops.BulkOps` backend serving the
    victim-side detach and the thief-side splice; when omitted it is
    resolved from ``policy.backend`` and the queue geometry ONCE at trace
    time (``"auto"`` consults the kernel geometry predicates here, never
    per call).
    """
    if ops is None:
        ops = _resolve_ops(policy, q)
    # psum of a literal folds to the static axis size (jax<0.5 has no
    # lax.axis_size).
    n_workers = lax.psum(1, axis_name)
    me = lax.axis_index(axis_name)
    idx = jnp.arange(n_workers, dtype=jnp.int32)

    # (1) master bookkeeping: gather sizes.
    sizes = lax.all_gather(q.size, axis_name)  # (W,) identical on all lanes

    # (2) replicated plan.
    plan = plan_transfers(sizes, policy)  # (W, 2): row t = (victim, n)
    src, amt = plan[:, 0], plan[:, 1]

    # Who steals from me, and how much?  (at most one thief per victim)
    steals_me = (src == me) & (amt > 0) & (idx != me)
    stolen_amt = jnp.sum(jnp.where(steals_me, amt, 0))
    thief_id = jnp.argmax(steals_me).astype(jnp.int32)  # 0 when none (amt==0)

    # (3) victim severs its tail block — single cursor bump linearizes.
    # With a kernel-routed backend the detach is the Pallas ring-gather.
    q, block, n_out = ops.steal_exact(q, stolen_amt,
                                      max_steal=policy.max_steal)

    # Outbox: one row per peer, only the thief's row is populated.
    def _outbox(x):
        out = jnp.zeros((n_workers,) + x.shape, x.dtype)
        return out.at[thief_id].set(jnp.where(n_out > 0, x, jnp.zeros_like(x)))

    outbox = jax.tree_util.tree_map(_outbox, block)
    counts = jnp.zeros((n_workers,), jnp.int32).at[thief_id].set(n_out)

    # One bulk exchange: row j of the inbox is what peer j sent to me.
    inbox = jax.tree_util.tree_map(
        lambda x: lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0),
        outbox,
    )
    counts_in = lax.all_to_all(counts, axis_name, split_axis=0, concat_axis=0)

    # (4) thief splices: at most one row is non-empty, blocks are pre-masked
    # so a sum collapses the inbox without a gather.  With a kernel-routed
    # backend the splice is the Pallas ring-scatter kernel.
    recv_n = jnp.sum(counts_in)
    recv = jax.tree_util.tree_map(lambda x: jnp.sum(x, axis=0), inbox)
    q, _ = ops.push(q, recv, recv_n)

    sizes_after = lax.all_gather(q.size, axis_name)
    stats = RebalanceStats(
        sizes_before=sizes,
        sizes_after=sizes_after,
        n_transferred=jnp.sum(jnp.where(amt > 0, amt, 0)),
        n_steals=jnp.sum((amt > 0).astype(jnp.int32)),
        n_transferred_xpod=jnp.int32(0),
        n_steals_xpod=jnp.int32(0),
    )
    return q, stats


def hierarchical_superstep(
    q: QueueState,
    policy: StealPolicy,
    *,
    worker_axis: str,
    pod_axis: str,
    ops: bulk_ops.BulkOps | None = None,
) -> Tuple[QueueState, RebalanceStats]:
    """Two-level rebalancing for multi-pod meshes: first the flat superstep
    within each pod (cheap ICI), then one superstep across pods where each
    pod's lane-0 worker acts as the pod representative (DCN-scale traffic is
    one block per pod, not per worker).  ``ops`` as in :func:`superstep`
    (resolved once, shared by both levels)."""
    if ops is None:
        ops = _resolve_ops(policy, q)
    q, stats = superstep(q, policy, axis_name=worker_axis, ops=ops)

    # Cross-pod: only lane 0 of each pod participates with its real size;
    # other lanes advertise "full enough not to be idle, small enough not
    # to be a victim" so the plan ignores them.
    me = lax.axis_index(worker_axis)
    sentinel = jnp.int32(policy.low_watermark + 1)
    eff_size = jnp.where(me == 0, q.size, sentinel)
    q_eff = QueueState(buf=q.buf, lo=q.lo, size=eff_size)
    q_eff, pod_stats = superstep(q_eff, policy, axis_name=pod_axis, ops=ops)
    # Restore true size accounting for what moved at pod level.
    delta = q_eff.size - eff_size
    q = QueueState(buf=q_eff.buf, lo=q_eff.lo, size=q.size + delta)

    # Exact per-level accounting: the intra-pod share stays in
    # n_transferred/n_steals; the pod-level plan's counts go in the xpod
    # fields.  Lanes l > 0 gathered sentinel sizes at pod level, so their
    # pod_stats are zero — the xpod fields are nonzero only on lane-0
    # representatives, where they are replicated across pods.
    stats = stats._replace(
        n_transferred_xpod=pod_stats.n_transferred,
        n_steals_xpod=pod_stats.n_steals,
        sizes_after=pod_stats.sizes_after,
    )
    return q, stats
