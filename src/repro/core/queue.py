"""Lock-free bulk work-stealing queue — JAX/TPU adaptation.

This is the paper's core data structure (Kataru et al., Listings 1-4)
re-thought for a functional, static-shape SPMD runtime:

* The linked list becomes a **ring buffer** over a pytree of payload arrays
  with a physical cursor ``lo`` (oldest element / steal side) and a ``size``
  counter.  The owner pushes and pops at the ``lo+size`` end (LIFO), the
  stealer detaches a contiguous block from the ``lo`` end — exactly the
  deque discipline of the paper (owner at head, stealer at tail).
* Every operation is a **pure state transition** ``state -> state'``.  The
  functional analogue of the paper's linearization point (the single
  ``start->next = null`` write) is the single returned-cursor update: a
  ``steal`` is linearized at the ``lo += n`` bump, a ``push`` at the
  ``size += n`` bump.  Because states are immutable there are no data races
  by construction; the paper's acquire/release reasoning does not transfer
  and is not needed (see DESIGN.md §2).
* Bulk operations are O(batch) *vectorized* copies that fuse into a single
  XLA kernel — per-item cost is constant and latency is flat in the batch
  size, reproducing the paper's Fig. 6 claim natively.  With
  ``use_kernel=True`` every hot-path op is a hand-written Pallas kernel:
  the steal-side detach (``kernels.queue_steal.ring_gather``), the push
  splice (``kernels.queue_push.ring_scatter`` — in-place aliased, never an
  O(capacity) copy) and the owner-side bulk pop
  (``kernels.queue_push.ring_slice``).
* The paper's **optimized steal** (skip the tail re-traversal when the owner
  is idle) is the TPU-native default: the stolen count is always known from
  cursors.  ``steal_counted`` additionally performs the sequential traversal
  the paper's baseline variant pays for, so benchmarks can reproduce Fig. 8.
* Unbounded growth without resizing maps to **host paging**
  (:class:`PagedQueue`): the device ring spills/refills whole pages to host
  memory in bulk, analogous to the block granularity of BWoS (cited by the
  paper) — the device-side shapes stay static.

Payloads are arbitrary pytrees whose leaves share a leading ``capacity``
(in the queue) / ``batch`` (in flight) dimension.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "QueueState",
    "make_queue",
    "queue_size",
    "push",
    "pop",
    "pop_bulk",
    "steal",
    "steal_exact",
    "steal_counted",
    "kernel_steal_available",
    "kernel_push_available",
    "kernel_pop_available",
    "inplace_ops",
    "push_inplace",
    "pop_bulk_inplace",
    "steal_exact_inplace",
    "PagedQueue",
]

Pytree = Any

# Default abort threshold, mirroring the paper's ``_queue_limit_``.
DEFAULT_QUEUE_LIMIT = 2


class QueueState(NamedTuple):
    """Immutable queue state.

    Attributes:
      buf:  pytree of ``(capacity, ...)`` arrays holding payloads.
      lo:   int32 physical index of the oldest element (steal side).
      size: int32 number of live elements; owner side is ``(lo+size) % cap``.
    """

    buf: Pytree
    lo: jnp.ndarray
    size: jnp.ndarray


def _capacity(q: QueueState) -> int:
    return jax.tree_util.tree_leaves(q.buf)[0].shape[0]


def _batch_size(batch: Pytree) -> int:
    return jax.tree_util.tree_leaves(batch)[0].shape[0]


def make_queue(capacity: int, item_spec: Pytree) -> QueueState:
    """Create an empty queue.

    Args:
      capacity: static ring capacity.
      item_spec: pytree of ``jax.ShapeDtypeStruct`` (or arrays) describing a
        single item — leaves get a leading ``capacity`` dimension.
    """
    buf = jax.tree_util.tree_map(
        lambda s: jnp.zeros((capacity,) + tuple(s.shape), dtype=s.dtype),
        item_spec,
    )
    return QueueState(buf=buf, lo=jnp.int32(0), size=jnp.int32(0))


def queue_size(q: QueueState) -> jnp.ndarray:
    return q.size


# ---------------------------------------------------------------------------
# Owner operations
# ---------------------------------------------------------------------------


def kernel_push_available(capacity: int, max_push: int) -> bool:
    """Whether the Pallas ring-scatter kernel can serve a push of this
    geometry (the kernel module owns the block-tiling rule)."""
    from repro.kernels.queue_push.kernel import ring_scatter_supported

    return ring_scatter_supported(capacity, max_push)


def kernel_pop_available(capacity: int, max_n: int) -> bool:
    """Whether the Pallas ring-slice kernel can serve a bulk pop of this
    geometry."""
    from repro.kernels.queue_push.kernel import ring_slice_supported

    return ring_slice_supported(capacity, max_n)


def push(q: QueueState, batch: Pytree, n: jnp.ndarray, *,
         use_kernel: bool = False) -> Tuple[QueueState, jnp.ndarray]:
    """Bulk push ``n`` items (owner side).

    ``batch`` leaves have static leading dim ``B >= n``; only the first ``n``
    rows are enqueued.  Returns ``(new_state, n_pushed)`` where ``n_pushed``
    is clamped to the available space (callers wanting unbounded semantics
    wrap the queue in :class:`PagedQueue`).

    Cost: one masked ring-scatter — O(B) vectorized, constant per item.
    The ``size + n`` update is the linearization point.  ``use_kernel=True``
    routes the splice through
    :func:`repro.kernels.queue_push.ops.push_scatter` (the Pallas
    ring-scatter on TPU — an in-place aliased splice that never copies the
    full ring — and the jnp oracle elsewhere); the generic XLA scatter
    below remains the fallback for unsupported geometries.
    """
    cap = _capacity(q)
    bsz = _batch_size(batch)
    n = jnp.minimum(jnp.asarray(n, jnp.int32), jnp.int32(cap) - q.size)
    n = jnp.maximum(n, 0)
    if use_kernel and kernel_push_available(cap, bsz):
        from repro.kernels.queue_push.ops import push_scatter

        buf = push_scatter(
            q.buf, batch, (q.lo + q.size) % cap, n,
            use_pallas=jax.default_backend() == "tpu",
        )
        return QueueState(buf=buf, lo=q.lo, size=q.size + n), n
    offs = jnp.arange(bsz, dtype=jnp.int32)
    phys = (q.lo + q.size + offs) % cap
    # Rows beyond ``n`` are routed out of bounds and dropped.
    phys = jnp.where(offs < n, phys, cap)
    buf = jax.tree_util.tree_map(
        lambda b, x: b.at[phys].set(x, mode="drop"), q.buf, batch
    )
    return QueueState(buf=buf, lo=q.lo, size=q.size + n), n


def pop(q: QueueState) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Pop the newest item (owner side, LIFO).

    Returns ``(new_state, item, valid)``; ``item`` is arbitrary when
    ``valid`` is False (queue empty) — the null-pointer analogue.
    """
    cap = _capacity(q)
    valid = q.size > 0
    idx = (q.lo + jnp.maximum(q.size - 1, 0)) % cap
    item = jax.tree_util.tree_map(lambda b: b[idx], q.buf)
    new_size = jnp.where(valid, q.size - 1, q.size)
    return QueueState(buf=q.buf, lo=q.lo, size=new_size), item, valid


def pop_bulk(
    q: QueueState, max_n: int, n: jnp.ndarray, *, use_kernel: bool = False
) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Bulk pop up to ``n`` newest items (owner side).

    Returns ``(new_state, batch, n_popped)``; ``batch`` leaves have static
    leading dim ``max_n`` with valid rows ``[0, n_popped)`` in queue order
    (oldest of the popped block first) and rows ``>= n_popped`` zeroed
    (safe for summing collectives, and identical across the kernel and
    fallback paths).  Used by vectorized explorers that consume several
    tasks per superstep.  ``use_kernel=True`` routes the detach through
    :func:`repro.kernels.queue_push.ops.pop_slice` (Pallas ring-slice on
    TPU, the jnp oracle elsewhere).
    """
    cap = _capacity(q)
    n = jnp.minimum(jnp.minimum(jnp.asarray(n, jnp.int32), q.size), max_n)
    n = jnp.maximum(n, 0)
    if use_kernel and kernel_pop_available(cap, max_n):
        from repro.kernels.queue_push.ops import pop_slice

        batch = pop_slice(
            q.buf, q.lo, q.size, n, max_n=max_n,
            use_pallas=jax.default_backend() == "tpu",
        )
        return QueueState(buf=q.buf, lo=q.lo, size=q.size - n), batch, n
    offs = jnp.arange(max_n, dtype=jnp.int32)
    start = q.size - n  # logical offset of the popped block
    phys = (q.lo + start + offs) % cap
    batch = jax.tree_util.tree_map(lambda b: b[phys], q.buf)
    live = offs < n

    def _mask(x):
        shape = (max_n,) + (1,) * (x.ndim - 1)
        return jnp.where(live.reshape(shape), x, jnp.zeros_like(x))

    batch = jax.tree_util.tree_map(_mask, batch)
    return QueueState(buf=q.buf, lo=q.lo, size=q.size - n), batch, n


# ---------------------------------------------------------------------------
# Stealer operations
# ---------------------------------------------------------------------------


def kernel_steal_available(capacity: int, max_steal: int) -> bool:
    """Whether the Pallas ring-gather kernel can serve a steal of this
    geometry (the kernel module owns the block-tiling rule)."""
    from repro.kernels.queue_steal.kernel import ring_gather_supported

    return ring_gather_supported(capacity, max_steal)


def _gather_block(q: QueueState, n: jnp.ndarray, max_steal: int,
                  use_kernel: bool) -> Pytree:
    """Detach ``max_steal`` rows starting at ``lo`` (rows >= ``n`` zeroed).

    ``use_kernel=True`` routes the copy through
    :func:`repro.kernels.queue_steal.ops.steal_gather`: the Pallas TPU
    kernel on TPU backends, the jnp oracle (``ref.py``) everywhere else —
    the production steal hot path.  ``use_kernel=False`` keeps the
    original inline gather (still used by the counted baseline so Fig. 8
    measures what it claims to).
    """
    cap = _capacity(q)
    if use_kernel and kernel_steal_available(cap, max_steal):
        from repro.kernels.queue_steal.ops import steal_gather

        return steal_gather(
            q.buf, q.lo, n, max_steal=max_steal,
            use_pallas=jax.default_backend() == "tpu",
        )
    offs = jnp.arange(max_steal, dtype=jnp.int32)
    phys = (q.lo + offs) % cap
    batch = jax.tree_util.tree_map(lambda b: b[phys], q.buf)
    live = offs < n

    def _mask(x):
        shape = (max_steal,) + (1,) * (x.ndim - 1)
        return jnp.where(live.reshape(shape), x, jnp.zeros_like(x))

    return jax.tree_util.tree_map(_mask, batch)


def _steal_plan(
    size: jnp.ndarray, proportion, queue_limit: int, max_steal: int
) -> jnp.ndarray:
    """Number of items to steal, following the paper's Listing 4 arithmetic.

    ``n_skip = floor(size * (1 - proportion))`` items remain with the owner;
    ``size - n_skip`` are stolen, clamped to the static transfer buffer.
    Aborts (returns 0) when ``size < queue_limit``.
    """
    size = jnp.asarray(size, jnp.int32)
    keep = jnp.asarray(
        jnp.floor(size.astype(jnp.float32) * (1.0 - proportion)), jnp.int32
    )
    n = size - keep
    n = jnp.minimum(n, jnp.int32(max_steal))
    return jnp.where(size < queue_limit, jnp.int32(0), n)


def steal(
    q: QueueState,
    proportion,
    *,
    max_steal: int,
    queue_limit: int = DEFAULT_QUEUE_LIMIT,
    use_kernel: bool = False,
) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Bulk steal of ``~proportion`` of the queue from the tail (oldest side).

    This is the paper's *optimized* variant, which on TPU is the natural
    one: the stolen count is fully determined by the size snapshot and the
    cut arithmetic, so no tail traversal is ever needed.  The single
    ``lo += n`` cursor bump is the linearization point (the analogue of the
    ``start->next = null`` severing write).

    Returns ``(new_state, stolen_batch, n_stolen)``; leaves of
    ``stolen_batch`` have static leading dim ``max_steal`` with valid rows
    ``[0, n_stolen)`` in queue order (oldest first); rows ``>= n_stolen``
    are zeroed.  ``use_kernel=True`` moves the block through the Pallas
    ring-gather kernel (see :func:`_gather_block`).
    """
    cap = _capacity(q)
    n = _steal_plan(q.size, proportion, queue_limit, max_steal)
    batch = _gather_block(q, n, max_steal, use_kernel)
    new_lo = (q.lo + n) % cap
    return QueueState(buf=q.buf, lo=new_lo, size=q.size - n), batch, n


def steal_exact(
    q: QueueState,
    n: jnp.ndarray,
    *,
    max_steal: int,
    use_kernel: bool = False,
) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Steal exactly ``n`` items (clamped to size / ``max_steal``) from the
    tail.  Used by the virtual master once the plan has fixed per-victim
    amounts; rows ``>= n`` of the returned batch are zeroed so the batch can
    be moved through summing collectives safely.  ``use_kernel=True``
    routes the block detach through the Pallas ring-gather kernel."""
    n = jnp.clip(jnp.asarray(n, jnp.int32), 0, jnp.minimum(q.size, max_steal))
    cap = _capacity(q)
    batch = _gather_block(q, n, max_steal, use_kernel)
    new_lo = (q.lo + n) % cap
    return QueueState(buf=q.buf, lo=new_lo, size=q.size - n), batch, n


def steal_counted(
    q: QueueState,
    proportion,
    *,
    max_steal: int,
    queue_limit: int = DEFAULT_QUEUE_LIMIT,
) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Paper-faithful *non-optimized* steal: pays an explicit sequential
    traversal over the stolen segment to (re)count it, mirroring the second
    list walk in Listing 4 lines 30-37.  Semantically identical to
    :func:`steal`; exists so benchmarks can reproduce Fig. 8's gap.
    """
    new_q, batch, n = steal(
        q, proportion, max_steal=max_steal, queue_limit=queue_limit
    )
    # Sequential dependent chain emulating pointer-chasing: each step reads
    # a payload element gated by the previous counter value, so XLA cannot
    # vectorize or elide it.
    lead = jax.tree_util.tree_leaves(batch)[0]
    flat = lead.reshape(lead.shape[0], -1)

    def body(i, carry):
        count, acc = carry
        live = i < n
        probe = flat[i, 0].astype(jnp.float32)
        acc = acc + jnp.where(live, probe * 0.0 + 1.0, 0.0) * (count + 1.0) * 0.0
        count = count + jnp.where(live, 1, 0)
        return count, acc

    count, acc = lax.fori_loop(0, max_steal, body, (jnp.int32(0), jnp.float32(0.0)))
    # ``count == n`` always; fold the dead value in so the loop is not DCE'd.
    n = count + jnp.asarray(acc, jnp.int32) * 0
    return new_q, batch, n


# ---------------------------------------------------------------------------
# In-place (donating) entry points
# ---------------------------------------------------------------------------
#
# The functional ops above copy-on-write the full-capacity ring every call
# when used as plain host-called jits.  These wrappers jit them with the
# queue state DONATED, so XLA aliases the input ring buffer to the output
# ring buffer and the update lowers to an in-place scatter/cursor bump —
# no full-capacity copy per superstep.  Semantics are identical (tests
# assert equivalence); the only behavioural difference is that the caller
# must not reuse the donated input state afterwards.  Donation is a no-op
# (with identical results) on backends that don't implement it (CPU).


class InPlaceOps(NamedTuple):
    push: Any
    pop: Any
    pop_bulk: Any
    steal: Any
    steal_exact: Any


@functools.lru_cache(maxsize=None)
def inplace_ops() -> InPlaceOps:
    """Jitted, donation-enabled variants of the queue ops (cached)."""
    donate = () if jax.default_backend() == "cpu" else (0,)
    return InPlaceOps(
        push=jax.jit(push, static_argnames=("use_kernel",),
                     donate_argnums=donate),
        pop=jax.jit(pop, donate_argnums=donate),
        pop_bulk=jax.jit(pop_bulk, static_argnums=(1,),
                         static_argnames=("use_kernel",),
                         donate_argnums=donate),
        steal=jax.jit(steal,
                      static_argnames=("max_steal", "queue_limit",
                                       "use_kernel"),
                      donate_argnums=donate),
        steal_exact=jax.jit(steal_exact,
                            static_argnames=("max_steal", "use_kernel"),
                            donate_argnums=donate),
    )


def push_inplace(q: QueueState, batch: Pytree, n, *,
                 use_kernel: bool = False) -> Tuple[QueueState, jnp.ndarray]:
    return inplace_ops().push(q, batch, n, use_kernel=use_kernel)


def pop_bulk_inplace(q: QueueState, max_n: int, n, *,
                     use_kernel: bool = False
                     ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    return inplace_ops().pop_bulk(q, max_n, n, use_kernel=use_kernel)


def steal_exact_inplace(q: QueueState, n, *, max_steal: int,
                        use_kernel: bool = False):
    return inplace_ops().steal_exact(q, n, max_steal=max_steal,
                                     use_kernel=use_kernel)


# ---------------------------------------------------------------------------
# Unbounded growth: host paging
# ---------------------------------------------------------------------------


class PagedQueue:
    """Device ring + host overflow pages = unbounded growth, static shapes.

    The device-resident :class:`QueueState` keeps the hot working set; when a
    bulk push would overflow, the *oldest* half of the ring is spilled to a
    host page in one bulk transfer (the steal-side block — exactly the block
    a stealer would have taken).  When the ring drains below the low
    watermark, pages are refilled in bulk.  The master may also steal whole
    host pages directly, which is the cheapest possible bulk steal.

    This class is host-level orchestration (not jittable); the device ops it
    calls are the jitted pure functions above.
    """

    def __init__(self, capacity: int, item_spec: Pytree, *, low_watermark: int | None = None):
        self.capacity = int(capacity)
        self.low_watermark = int(low_watermark if low_watermark is not None else capacity // 4)
        self.state = make_queue(capacity, item_spec)
        self.pages: list[Tuple[Pytree, int]] = []  # host-side (batch, n) blocks
        self._spill_n = self.capacity // 2

        self._jit_push = jax.jit(push)
        self._jit_pop = jax.jit(pop)
        self._jit_pop_bulk = jax.jit(pop_bulk, static_argnums=1)
        self._jit_steal = jax.jit(
            functools.partial(steal, max_steal=self._spill_n, queue_limit=0)
        )

    # -- owner side ---------------------------------------------------------

    def push(self, batch: Pytree, n: int) -> None:
        size = int(self.state.size)
        if size + n > self.capacity:
            # Spill the oldest block to a host page (bulk, one transfer).
            self.state, spilled, n_sp = self._jit_steal(
                self.state, self._spill_n / max(size, 1)
            )
            n_sp = int(n_sp)
            if n_sp:
                self.pages.append((jax.device_get(spilled), n_sp))
        self.state, pushed = self._jit_push(self.state, batch, n)
        if int(pushed) < n:  # ring still too small for this batch: page the rest
            rest = jax.tree_util.tree_map(lambda x: x[int(pushed):], batch)
            self.pages.append((jax.device_get(rest), n - int(pushed)))

    def pop(self):
        self._maybe_refill()
        self.state, item, valid = self._jit_pop(self.state)
        return (item, bool(valid))

    def _maybe_refill(self) -> None:
        if int(self.state.size) <= self.low_watermark and self.pages:
            batch, n = self.pages.pop()
            dev = jax.device_put(batch)
            self.state, pushed = push(self.state, dev, n)
            pushed = int(pushed)
            if pushed < n:
                # Page larger than the ring's free space: keep the
                # un-spliced tail as a (smaller) host page instead of
                # silently dropping it.
                rest = jax.tree_util.tree_map(lambda x: x[pushed:], batch)
                self.pages.append((rest, n - pushed))

    # -- stealer side -------------------------------------------------------

    def total_size(self) -> int:
        return int(self.state.size) + sum(n for _, n in self.pages)

    def steal(self, proportion: float):
        """Bulk steal: prefer whole host pages (zero device traffic), fall
        back to a device-ring steal."""
        want = int(self.total_size() * proportion)
        got: list[Tuple[Pytree, int]] = []
        while self.pages and want > 0:
            batch, n = self.pages.pop(0)  # oldest pages first (tail side)
            got.append((batch, n))
            want -= n
        if want > 0 and int(self.state.size) >= DEFAULT_QUEUE_LIMIT:
            self.state, batch, n = self._jit_steal(
                self.state, want / max(int(self.state.size), 1)
            )
            if int(n):
                got.append((jax.device_get(batch), int(n)))
        return got
