"""Lock-free bulk work-stealing queue — JAX/TPU adaptation.

This is the paper's core data structure (Kataru et al., Listings 1-4)
re-thought for a functional, static-shape SPMD runtime:

* The linked list becomes a **ring buffer** over a pytree of payload arrays
  with a physical cursor ``lo`` (oldest element / steal side) and a ``size``
  counter.  The owner pushes and pops at the ``lo+size`` end (LIFO), the
  stealer detaches a contiguous block from the ``lo`` end — exactly the
  deque discipline of the paper (owner at head, stealer at tail).
* Every operation is a **pure state transition** ``state -> state'``.  The
  functional analogue of the paper's linearization point (the single
  ``start->next = null`` write) is the single returned-cursor update: a
  ``steal`` is linearized at the ``lo += n`` bump, a ``push`` at the
  ``size += n`` bump.  Because states are immutable there are no data races
  by construction (see DESIGN.md §2).
* The operations live behind the :class:`repro.core.ops.BulkOps` backend
  contract — ``"reference"`` (jnp oracle), ``"pallas"`` (hand-written
  Pallas ring kernels) or ``"auto"`` (geometry-resolved at construction).
  Bulk operations are O(batch) vectorized copies whose per-item cost is
  constant and whose latency is flat in the batch size, reproducing the
  paper's Fig. 6 claim natively.
* The paper's **optimized steal** (skip the tail re-traversal when the owner
  is idle) is the TPU-native default: the stolen count is always known from
  cursors.  ``steal_counted`` additionally performs the sequential traversal
  the paper's baseline variant pays for, so benchmarks can reproduce Fig. 8.
* Unbounded growth without resizing maps to **host paging**
  (:class:`PagedQueue`): the device ring spills/refills whole pages to host
  memory in bulk, analogous to the block granularity of BWoS (cited by the
  paper) — the device-side shapes stay static.

Payloads are arbitrary pytrees whose leaves share a leading ``capacity``
(in the queue) / ``batch`` (in flight) dimension.

(The pre-BulkOps module-level op functions and their ``use_kernel=`` /
``*_inplace`` dialect had their one deprecation release at PR 3 and are
removed; every consumer constructs a backend with
:func:`repro.core.ops.make_ops` and calls its methods, with
``donate=True`` for the in-place call shape.)
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ops import (  # noqa: F401  (re-exported)
    DEFAULT_QUEUE_LIMIT,
    BulkOps,
    QueueState,
    kernel_pop_available,
    kernel_push_available,
    kernel_steal_available,
    make_ops,
    make_queue,
    queue_size,
    steal_counted,
)
from repro.core.ops import _pop  # single-item pop has no backend dialect

__all__ = [
    "QueueState",
    "make_queue",
    "queue_size",
    "pop",
    "steal_counted",
    "kernel_steal_available",
    "kernel_push_available",
    "kernel_pop_available",
    "PagedQueue",
]

Pytree = Any


def pop(q: QueueState) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Pop the newest item (owner side, LIFO).

    Returns ``(new_state, item, valid)``; ``item`` is arbitrary when
    ``valid`` is False (queue empty) — the null-pointer analogue.
    (Backend-independent: there is no kernel dialect to choose.)
    """
    return _pop(q)


# ---------------------------------------------------------------------------
# Unbounded growth: host paging
# ---------------------------------------------------------------------------


class PagedQueue:
    """Device ring + host overflow pages = unbounded growth, static shapes.

    The device-resident :class:`QueueState` keeps the hot working set; when a
    bulk push would overflow, the *oldest* half of the ring is spilled to a
    host page in one bulk transfer (the steal-side block — exactly the block
    a stealer would have taken).  When the ring drains below the low
    watermark, pages are refilled in bulk.  The master may also steal whole
    host pages directly, which is the cheapest possible bulk steal.

    This class is host-level orchestration (not jittable); the device ops
    run through a :class:`~repro.core.ops.BulkOps` backend (``donate=True``
    — jitted, ring donated where the platform supports it).  ``backend``
    accepts a registry name or an existing ``BulkOps``; ``"auto"``
    resolves from the ring geometry once, here.
    """

    def __init__(self, capacity: int, item_spec: Pytree, *,
                 low_watermark: int | None = None,
                 backend: str | BulkOps = "auto"):
        self.capacity = int(capacity)
        self.low_watermark = int(low_watermark if low_watermark is not None else capacity // 4)
        self.state = make_queue(capacity, item_spec)
        self.pages: list[Tuple[Pytree, int]] = []  # host-side (batch, n) blocks
        self._spill_n = self.capacity // 2
        # "auto" resolves from the ring geometry here: spill/refill moves
        # are bounded by _spill_n on both the steal and the push side
        # (larger caller batches fall back per-call via the op's guard).
        self.ops = make_ops(backend, capacity=self.capacity,
                            max_push=self._spill_n,
                            max_steal=self._spill_n)
        # Spill/refill accounting (the sanitizer's PagedQueue contract):
        # paging moves items between ring and host pages, so the net
        # external flow pushed - popped - stolen must equal total_size()
        # after every public op.  Armed exactly when make_ops wrapped the
        # backend (REPRO_CHECK=1 / check=True).
        from repro.analysis.sanitize import CheckedBulkOps

        self._check = isinstance(self.ops, CheckedBulkOps)
        self._net_in = 0
        # Paging traffic counters (read by repro.obs.metrics): one spill
        # per host page written, one refill per page spliced back, with
        # the item counts each way.
        self.spills = 0
        self.spilled_items = 0
        self.refills = 0
        self.refilled_items = 0

    def _audit(self, context: str) -> None:
        if not self._check:
            return
        from repro.analysis import sanitize

        size = int(self.state.size)
        if not 0 <= size <= self.capacity:
            sanitize.record_violation(
                f"PagedQueue.{context}: ring size {size} outside "
                f"[0, {self.capacity}]", eager=True)
        for batch, n in self.pages:
            rows = jax.tree_util.tree_leaves(batch)[0].shape[0]
            if n <= 0 or n > rows:
                sanitize.record_violation(
                    f"PagedQueue.{context}: host page count {n} outside "
                    f"(0, rows={rows}]", eager=True)
        if self.total_size() != self._net_in:
            sanitize.record_violation(
                f"PagedQueue.{context}: spill/refill accounting broken — "
                f"total_size()={self.total_size()} but net external flow "
                f"is {self._net_in} (items lost or duplicated while "
                f"paging)", eager=True)

    # -- owner side ---------------------------------------------------------

    def push(self, batch: Pytree, n: int) -> None:
        size = int(self.state.size)
        if size + n > self.capacity:
            # Spill the oldest block to a host page (bulk, one transfer).
            # Proportion capped at 1.0: a nearly-empty ring spills
            # everything it has, never more (_steal_plan also clamps).
            self.state, spilled, n_sp = self.ops.steal(
                self.state, min(1.0, self._spill_n / max(size, 1)),
                max_steal=self._spill_n, queue_limit=0, donate=True)
            n_sp = int(n_sp)
            if n_sp:
                self.pages.append((jax.device_get(spilled), n_sp))
                self.spills += 1
                self.spilled_items += n_sp
        self.state, pushed = self.ops.push(self.state, batch, jnp.int32(n),
                                           donate=True)
        if int(pushed) < n:  # ring still too small for this batch: page the rest
            rest = jax.tree_util.tree_map(lambda x: x[int(pushed):], batch)
            self.pages.append((jax.device_get(rest), n - int(pushed)))
            self.spills += 1
            self.spilled_items += n - int(pushed)
        self._net_in += int(n)
        self._audit("push")

    def pop(self):
        self._maybe_refill()
        self.state, item, valid = self.ops.pop(self.state, donate=True)
        if bool(valid):
            self._net_in -= 1
        self._audit("pop")
        return (item, bool(valid))

    def _maybe_refill(self) -> None:
        if int(self.state.size) <= self.low_watermark and self.pages:
            batch, n = self.pages.pop()
            dev = jax.device_put(batch)
            self.state, pushed = self.ops.push(self.state, dev, jnp.int32(n),
                                               donate=True)
            pushed = int(pushed)
            self.refills += 1
            self.refilled_items += pushed
            if pushed < n:
                # Page larger than the ring's free space: keep the
                # un-spliced tail as a (smaller) host page instead of
                # silently dropping it.
                rest = jax.tree_util.tree_map(lambda x: x[pushed:], batch)
                self.pages.append((rest, n - pushed))

    # -- stealer side -------------------------------------------------------

    def total_size(self) -> int:
        return int(self.state.size) + sum(n for _, n in self.pages)

    def steal(self, proportion: float):
        """Bulk steal: prefer whole host pages (zero device traffic), fall
        back to a device-ring steal."""
        want = int(self.total_size() * proportion)
        got: list[Tuple[Pytree, int]] = []
        while self.pages and want > 0:
            batch, n = self.pages.pop(0)  # oldest pages first (tail side)
            got.append((batch, n))
            want -= n
        if want > 0 and int(self.state.size) >= DEFAULT_QUEUE_LIMIT:
            self.state, batch, n = self.ops.steal(
                self.state, want / max(int(self.state.size), 1),
                max_steal=self._spill_n, queue_limit=0, donate=True)
            if int(n):
                got.append((jax.device_get(batch), int(n)))
        self._net_in -= sum(n for _, n in got)
        self._audit("steal")
        return got

    # -- HostQueue protocol adapters (int payload convenience) --------------

    def push_bulk(self, items) -> None:
        """Protocol adapter: push a python list of int items (single-int32
        item_spec rings only — what the benchmark harness sweeps)."""
        self.push_batch(self.make_batch(items))

    def make_batch(self, items):
        """Producer-side prep: host list -> device array (untimed in the
        benchmark harness, like the paper's pre-linked llist)."""
        items = list(items)
        return jnp.asarray(items, jnp.int32), len(items)

    def push_batch(self, prepared) -> None:
        batch, n = prepared
        if n:
            self.push(batch, n)

    def pop_item(self):
        item, valid = self.pop()
        return int(item) if valid else None

    def steal_bulk(self, proportion: float) -> list:
        """Protocol adapter over :meth:`steal`.  Page-granular: whole
        host pages move first (the documented cheapest bulk steal), so
        the stolen amount rounds up to page boundaries and the stolen
        set approximates — rather than guarantees — the oldest-side
        discipline (overflow pages hold NEWEST items; see the
        :class:`~repro.core.host_queue.HostQueue` docstring)."""
        out: list = []
        for batch, n in self.steal(proportion):
            out.extend(int(x) for x in np.asarray(batch).reshape(-1)[:n])
        return out

    def __len__(self) -> int:
        return self.total_size()
