"""Containers and drivers for per-worker queues across a mesh axis.

``ShardedQueues`` stacks W independent :class:`QueueState`s along a leading
axis.  Two execution modes share the exact same superstep code:

* ``run_vmapped`` — ``jax.vmap(..., axis_name=...)`` over the stacked axis:
  runs on a single device; used by unit/property tests and the CPU solver.
* ``run_sharded`` — ``shard_map`` over a real mesh axis: each device owns its
  lane; used by the production launcher and the dry-run.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ops as q_ops
from repro.core.policy import StealPolicy
from repro.core import master as master_ops

Pytree = Any

__all__ = ["make_sharded_queues", "vmapped_superstep", "sharded_superstep"]


def make_sharded_queues(n_workers: int, capacity: int, item_spec: Pytree,
                        *, sharding: NamedSharding | None = None
                        ) -> q_ops.QueueState:
    """A stacked pytree of W empty queues (leading axis = worker).

    ``sharding`` optionally places every leaf with a
    :class:`~jax.sharding.NamedSharding` over the leading worker axis
    (one ring shard per device along the mesh's worker axes) — what the
    mesh executor passes so each device OWNS its lane's ring from the
    first byte; omitted, the stack lives wherever jax defaults (single
    device), which is what the vmap-lane executor wants."""
    buf = jax.tree_util.tree_map(
        lambda s: jnp.zeros((n_workers, capacity) + tuple(s.shape), dtype=s.dtype),
        item_spec,
    )
    qs = q_ops.QueueState(
        buf=buf,
        lo=jnp.zeros((n_workers,), jnp.int32),
        size=jnp.zeros((n_workers,), jnp.int32),
    )
    if sharding is not None:
        qs = jax.device_put(qs, sharding)
    return qs


def vmapped_superstep(policy: StealPolicy, axis_name: str = "workers",
                      ops: q_ops.BulkOps | None = None) -> Callable:
    """Single-device driver: the superstep vmapped over the worker axis with
    collectives resolved through the vmap axis name.  ``ops`` optionally
    pins the :class:`~repro.core.ops.BulkOps` backend (otherwise it is
    resolved from ``policy.backend`` at trace time)."""

    def step(qs: q_ops.QueueState):
        return jax.vmap(
            functools.partial(master_ops.superstep, policy=policy,
                              axis_name=axis_name, ops=ops),
            axis_name=axis_name,
        )(qs)

    return jax.jit(step)


def sharded_superstep(
    mesh: Mesh,
    policy: StealPolicy,
    worker_axis: str = "data",
    pod_axis: str | None = None,
    ops: q_ops.BulkOps | None = None,
) -> Callable:
    """Production driver: shard_map over the mesh's worker axis (one queue
    per device along that axis); optionally hierarchical over a pod axis.

    Returns ``(queues, stats)`` with the FULL
    :class:`~repro.core.master.RebalanceStats` (replicated leaves
    returned once, scalar counters as shape ``(1,)`` arrays), exactly
    like the vmapped driver — not just ``sizes_after``.  In flat mode
    every field is replicated so the single copy is exact; in
    hierarchical mode the copy is the lane-(pod 0, worker 0) view (pod
    0's intra-pod share plus the xpod share, which is what the
    representatives see — the same element the executor's exact
    aggregation reads first).  ``ops``
    optionally pins the :class:`~repro.core.ops.BulkOps` backend shared
    by both levels; when omitted it is resolved from ``policy.backend``
    and the queue geometry at trace time, so a pinned
    ``StealPolicy(backend=...)`` selects the same implementation here as
    everywhere else.
    """
    from jax.experimental.shard_map import shard_map

    axes = (pod_axis, worker_axis) if pod_axis else (worker_axis,)
    spec = P(axes)

    if pod_axis is None:
        def inner(qs):
            q = jax.tree_util.tree_map(lambda x: x[0], qs)  # strip lane dim
            q, stats = master_ops.superstep(q, policy,
                                            axis_name=worker_axis, ops=ops)
            return (
                jax.tree_util.tree_map(lambda x: x[None], q),
                jax.tree_util.tree_map(jnp.atleast_1d, stats),
            )
    else:
        def inner(qs):
            q = jax.tree_util.tree_map(lambda x: x[0], qs)
            q, stats = master_ops.hierarchical_superstep(
                q, policy, worker_axis=worker_axis, pod_axis=pod_axis,
                ops=ops
            )
            return (
                jax.tree_util.tree_map(lambda x: x[None], q),
                jax.tree_util.tree_map(jnp.atleast_1d, stats),
            )

    stats_spec = master_ops.RebalanceStats(
        *([P(None)] * len(master_ops.RebalanceStats._fields)))
    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(q_ops.QueueState(buf=spec, lo=spec, size=spec),),
        out_specs=(
            q_ops.QueueState(buf=spec, lo=spec, size=spec),
            stats_spec,
        ),
        check_rep=False,
    )
    return jax.jit(fn)
