"""Knapsack instances (the paper's running example, Eq. 1) + DP oracle.

The DD machinery (diagram.py / bnb.py) treats states generically; the
knapsack transition is the canonical separable CNP used throughout the
paper's Section I-A figures.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["Knapsack", "paper_example", "random_instance", "dp_solve"]


@dataclasses.dataclass(frozen=True)
class Knapsack:
    weights: Tuple[int, ...]
    profits: Tuple[int, ...]
    capacity: int

    @property
    def n(self) -> int:
        return len(self.weights)


def paper_example() -> Knapsack:
    """max 8x1+5x2+7x3+6x4  s.t. 3x1+2x2+4x3+6x4 <= 7 — optimum 15
    (Figure 2: x = (1, 0, 1, 0))."""
    return Knapsack(weights=(3, 2, 4, 6), profits=(8, 5, 7, 6), capacity=7)


def random_instance(n: int, seed: int = 0, max_w: int = 50,
                    max_p: int = 100, tightness: float = 0.5) -> Knapsack:
    rng = np.random.default_rng(seed)
    w = rng.integers(1, max_w + 1, n)
    p = rng.integers(1, max_p + 1, n)
    cap = max(int(w.sum() * tightness), int(w.max()))
    return Knapsack(weights=tuple(int(x) for x in w),
                    profits=tuple(int(x) for x in p), capacity=cap)


def dp_solve(inst: Knapsack) -> int:
    """Exact DP oracle, O(n * capacity)."""
    dp = np.zeros(inst.capacity + 1, dtype=np.int64)
    for w, p in zip(inst.weights, inst.profits):
        if w <= inst.capacity:
            dp[w:] = np.maximum(dp[w:], dp[:-w] + p)
    return int(dp.max())
