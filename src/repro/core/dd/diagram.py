"""Vectorized decision-diagram layer expansion: exact / restricted / relaxed.

A DD layer is a fixed-width node pool (static shapes for jit):
  states (W,) int32   — remaining capacity (-1 = dead slot)
  values (W,) int32   — longest path value into the node

``expand_layer`` generates both arcs for every node (the bulk node
generation the paper's queues absorb — kernels/dd_expand is the Pallas
version of this hot spot).  Reduction policies:

  exact:      merge duplicate states (keep max value); FAILS (reports
              overflow) when distinct states exceed the pool width.
  restricted: keep the top-W nodes by value, drop the rest (primal bound;
              paper Fig. 3).
  relaxed:    keep the top W-1 by value, MERGE the rest into one node
              with state = max(states) (a valid relaxation for knapsack's
              monotone transition) and value = max(values) (dual bound;
              paper Fig. 4).

All functions are pure jnp and vmap/batch cleanly over a leading
subproblem axis.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Pool", "expand_layer", "reduce_restricted", "reduce_relaxed",
           "reduce_exact", "build_bounds"]

DEAD = jnp.int32(-1)
NEG = jnp.int32(-(2 ** 30))


class Pool(NamedTuple):
    states: jnp.ndarray   # (W,) int32, -1 = dead
    values: jnp.ndarray   # (W,) int32


def expand_layer(pool: Pool, w: jnp.ndarray, p: jnp.ndarray) -> Pool:
    """One DD layer: each live node spawns the 0-arc child (state, value)
    and the 1-arc child (state - w, value + p) when feasible.
    Returns a (2W,) pool (children may be dead)."""
    live = pool.states >= 0
    s0 = jnp.where(live, pool.states, DEAD)
    v0 = jnp.where(live, pool.values, NEG)
    feas = live & (pool.states >= w)
    s1 = jnp.where(feas, pool.states - w, DEAD)
    v1 = jnp.where(feas, pool.values + p, NEG)
    return Pool(states=jnp.concatenate([s0, s1]),
                values=jnp.concatenate([v0, v1]))


def _dedup_max(states: jnp.ndarray, values: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge duplicate states keeping the max value (exact DD reduction).
    Sorts by (state, value) and masks all but the best copy of each state."""
    order = jnp.lexsort((values, states))  # state asc, value asc within
    s = states[order]
    v = values[order]
    is_last = jnp.concatenate([s[1:] != s[:-1], jnp.array([True])])
    keep = is_last & (s >= 0)
    return jnp.where(keep, s, DEAD), jnp.where(keep, v, NEG)


def reduce_exact(children: Pool, width: int) -> Tuple[Pool, jnp.ndarray]:
    """Dedup; returns (pool (W,), overflow flag) — overflow set when more
    than ``width`` distinct states survive (exact DD exceeded the pool)."""
    s, v = _dedup_max(children.states, children.values)
    n_live = jnp.sum(s >= 0)
    topv, idx = jax.lax.top_k(jnp.where(s >= 0, v, NEG), width)
    keep_s = s[idx]
    dead = topv <= NEG
    return (Pool(states=jnp.where(dead, DEAD, keep_s),
                 values=jnp.where(dead, NEG, topv)),
            n_live > width)


def reduce_restricted(children: Pool, width: int) -> Pool:
    """Top-W by value (after dedup) — primal-side restricted DD."""
    s, v = _dedup_max(children.states, children.values)
    topv, idx = jax.lax.top_k(jnp.where(s >= 0, v, NEG), width)
    dead = topv <= NEG
    return Pool(states=jnp.where(dead, DEAD, s[idx]),
                values=jnp.where(dead, NEG, topv))


def reduce_relaxed(children: Pool, width: int) -> Pool:
    """Top-(W-1) by value; the remainder merges into one relaxed node with
    state = max(rest states), value = max(rest values)."""
    s, v = _dedup_max(children.states, children.values)
    masked_v = jnp.where(s >= 0, v, NEG)
    topv, idx = jax.lax.top_k(masked_v, width - 1)
    kept = jnp.zeros(s.shape, bool).at[idx].set(topv > NEG)
    rest = (s >= 0) & ~kept
    any_rest = jnp.any(rest)
    merged_s = jnp.max(jnp.where(rest, s, DEAD))
    merged_v = jnp.max(jnp.where(rest, v, NEG))
    dead = topv <= NEG
    states = jnp.concatenate([jnp.where(dead, DEAD, s[idx]),
                              jnp.where(any_rest, merged_s, DEAD)[None]])
    values = jnp.concatenate([jnp.where(dead, NEG, topv),
                              jnp.where(any_rest, merged_v, NEG)[None]])
    return Pool(states=states, values=values)


@functools.partial(jax.jit, static_argnames=("width", "n_vars"))
def build_bounds(root_state: jnp.ndarray, root_value: jnp.ndarray,
                 start_layer: jnp.ndarray, weights: jnp.ndarray,
                 profits: jnp.ndarray, *, width: int, n_vars: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build restricted + relaxed DDs from a subproblem root.

    Scans all n_vars layers; layers before ``start_layer`` are skipped
    (masked no-op) so batches of subproblems rooted at different depths
    vectorize.  Returns (primal, dual) bounds for root_value + completion.
    """

    def init(wd):
        s = jnp.full((wd,), DEAD, jnp.int32).at[0].set(root_state)
        v = jnp.full((wd,), NEG, jnp.int32).at[0].set(root_value)
        return Pool(s, v)

    res0 = init(width)
    rel0 = init(width)

    def step(carry, inp):
        res, rel = carry
        i, w, p = inp
        active = i >= start_layer
        res_c = expand_layer(res, w, p)
        res_n = reduce_restricted(res_c, width)
        rel_c = expand_layer(rel, w, p)
        rel_n = reduce_relaxed(rel_c, width)
        res = jax.tree_util.tree_map(
            lambda new, old: jnp.where(active, new, old), res_n, res)
        rel = jax.tree_util.tree_map(
            lambda new, old: jnp.where(active, new, old), rel_n, rel)
        return (res, rel), None

    idx = jnp.arange(n_vars, dtype=jnp.int32)
    (res, rel), _ = jax.lax.scan(step, (res0, rel0), (idx, weights, profits))
    primal = jnp.max(jnp.where(res.states >= 0, res.values, NEG))
    dual = jnp.max(jnp.where(rel.states >= 0, rel.values, NEG))
    return primal, dual
