"""Master-worker parallel DD branch-and-bound on the lock-free bulk queues.

This is the paper's target system end-to-end: W workers each own a
private subproblem queue; exploring a subproblem generates children in
BULK (one vectorized push); the virtual master (core.master.superstep)
observes queue sizes and bulk-steals proportionally from busy workers to
feed drained ones — the single-stealer, watermark-gated policy of §II.B.

One solver superstep (jitted, vmapped over the worker axis — the same
code shard_maps onto a mesh axis):

  1. pop_bulk(E)           — owner-side bulk pop
  2. explore_batch         — restricted/relaxed DD bounds + exact frontier
  3. pmax incumbent        — global bound (the master's bookkeeping)
  4. prune + compact       — children of dominated nodes are dropped
  5. push(children)        — owner-side bulk push
  6. master.superstep      — proportional bulk-steal rebalancing

The incumbent is monotone and every subproblem is either solved exactly,
pruned, or partitioned by its children, so the parallel solver returns
the same optimum as the sequential oracle (tests assert this).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import master as master_ops
from repro.core import queue as q_ops
from repro.core.dd.bnb import Subproblem, explore_batch
from repro.core.dd.diagram import NEG
from repro.core.dd.knapsack import Knapsack
from repro.core.policy import StealPolicy
from repro.core.sharded_queue import make_sharded_queues

__all__ = ["parallel_solve", "SolverState"]


class SolverState(NamedTuple):
    queues: q_ops.QueueState     # stacked (W, ...) per-worker queues
    incumbent: jnp.ndarray       # (W,) replicated scalar per worker
    explored: jnp.ndarray        # (W,) counters
    transferred: jnp.ndarray     # (W,) rebalance volume


def _item_spec():
    z = jnp.zeros((), jnp.int32)
    return {"layer": z, "state": z, "value": z}


def _superstep(state: SolverState, weights, profits, *, explore_width: int,
               batch: int, n_vars: int, policy: StealPolicy,
               axis_name: str) -> SolverState:
    """One worker's slice of the solver superstep (runs under vmap)."""
    q = state.queues
    # 1. bulk pop up to `batch` subproblems
    q, items, n_popped = q_ops.pop_bulk(q, batch, jnp.int32(batch))
    valid = jnp.arange(batch, dtype=jnp.int32) < n_popped
    subs = Subproblem(layer=items["layer"], state=items["state"],
                      value=items["value"])

    # 2. explore
    out = explore_batch(subs, valid, weights, profits,
                        width=explore_width, n_vars=n_vars)

    # 3. global incumbent via the master's bookkeeping (all-reduce max)
    local_best = jnp.maximum(state.incumbent, jnp.max(out["primal"]))
    incumbent = lax.pmax(local_best, axis_name)

    # 4. prune: a subproblem's children survive iff dual > incumbent
    keep = (out["dual"] > incumbent)[:, None]
    ch = out["children"]
    live = keep & (ch.layer >= 0)                  # (batch, width)
    flat = {
        "layer": ch.layer.reshape(-1),
        "state": ch.state.reshape(-1),
        "value": ch.value.reshape(-1),
    }
    flive = live.reshape(-1)
    # compact live children to the front (single sort — bulk, no per-node op)
    order = jnp.argsort(~flive, stable=True)
    flat = jax.tree_util.tree_map(lambda x: x[order], flat)
    n_children = jnp.sum(flive.astype(jnp.int32))

    # 5. bulk push
    q, _ = q_ops.push(q, flat, n_children)

    # 6. master rebalancing round
    q, stats = master_ops.superstep(q, policy, axis_name=axis_name)

    return SolverState(
        queues=q,
        incumbent=incumbent,
        explored=state.explored + n_popped,
        transferred=state.transferred + stats.n_transferred,
    )


def parallel_solve(inst: Knapsack, *, n_workers: int = 8,
                   explore_width: int = 16, batch: int = 8,
                   capacity: int = 4096, policy: StealPolicy | None = None,
                   max_supersteps: int = 10_000) -> Tuple[int, dict]:
    """Solve on W vmapped workers (same superstep shard_maps onto a mesh).

    Returns (optimum, stats).
    """
    policy = policy or StealPolicy(proportion=0.5, high_watermark=4,
                                   low_watermark=0,
                                   max_steal=min(capacity, 1024))
    w = jnp.asarray(inst.weights, jnp.int32)
    p = jnp.asarray(inst.profits, jnp.int32)

    queues = make_sharded_queues(n_workers, capacity, _item_spec())
    # seed: root subproblem on worker 0
    root = {"layer": jnp.zeros((n_workers, 1), jnp.int32),
            "state": jnp.full((n_workers, 1), inst.capacity, jnp.int32),
            "value": jnp.zeros((n_workers, 1), jnp.int32)}
    seed_n = jnp.zeros((n_workers,), jnp.int32).at[0].set(1)
    queues, _ = jax.vmap(q_ops.push)(queues, root, seed_n)

    state = SolverState(
        queues=queues,
        incumbent=jnp.full((n_workers,), NEG, jnp.int32),
        explored=jnp.zeros((n_workers,), jnp.int32),
        transferred=jnp.zeros((n_workers,), jnp.int32),
    )

    step = jax.jit(jax.vmap(
        functools.partial(_superstep, explore_width=explore_width,
                          batch=batch, n_vars=inst.n, policy=policy,
                          axis_name="workers"),
        axis_name="workers",
        in_axes=(0, None, None),
    ), static_argnums=())

    supersteps = 0
    while supersteps < max_supersteps:
        state = step(state, w, p)
        supersteps += 1
        if int(jnp.sum(state.queues.size)) == 0:
            break

    stats = {
        "supersteps": supersteps,
        "explored": int(jnp.sum(state.explored)),
        "transferred": int(jnp.sum(state.transferred)) // max(n_workers, 1),
        "per_worker_explored": [int(x) for x in state.explored],
    }
    return int(state.incumbent[0]), stats
