"""Master-worker parallel DD branch-and-bound on the lock-free bulk queues.

This is the paper's target system end-to-end: W workers each own a
private subproblem queue; exploring a subproblem generates children in
BULK (one vectorized push); the virtual master (core.master.superstep)
observes queue sizes and bulk-steals proportionally from busy workers to
feed drained ones — the single-stealer, watermark-gated policy of §II.B.

The solver runs on :class:`repro.runtime.StealRuntime` — the unified
executor — so its steal hot path is the same backend-routed, adaptively
tuned path the benchmarks and the serving scheduler exercise.  The
per-worker body (vmapped over the worker axis; the same code shard_maps
onto a mesh axis) drives the runtime's resolved
:class:`~repro.core.ops.BulkOps` backend for its owner-side ops:

  1. ops.pop_bulk(E)       — owner-side bulk pop
  2. explore_batch         — restricted/relaxed DD bounds + exact frontier
  3. pmax incumbent        — global bound (the master's bookkeeping)
  4. prune + compact       — children of dominated nodes are dropped
  5. ops.push(children)    — owner-side bulk push

and the runtime appends 6. master.superstep (proportional bulk-steal
rebalancing with the adaptive proportion) and records telemetry.  By
default the solver advances ``fused_rounds`` supersteps per device
dispatch (``StealRuntime.run_fused``): explore, rebalance and the
adaptive update are one on-device loop that early-exits at drain, so the
hot loop never leaves the device between supersteps.

The incumbent is monotone and every subproblem is either solved exactly,
pruned, or partitioned by its children, so the parallel solver returns
the same optimum as the sequential oracle (tests assert this).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.dd.bnb import Subproblem, explore_batch
from repro.core.dd.diagram import NEG
from repro.core.dd.knapsack import Knapsack
from repro.core.ops import BulkOps, QueueState
from repro.core.policy import StealPolicy

__all__ = ["parallel_solve"]

AXIS = "workers"


def _item_spec():
    z = jnp.zeros((), jnp.int32)
    return {"layer": z, "state": z, "value": z}


def _make_worker_body(weights, profits, ops: BulkOps, *, explore_width: int,
                      batch: int, n_vars: int):
    """One worker's slice of the solver superstep (runs under vmap with
    the runtime's axis name in scope).  ``ops`` is the runtime's resolved
    BulkOps backend, so the owner-side bulk pop and push run the same
    routing (Pallas ring-slice / ring-scatter when resolved) as the
    master's steal."""

    def body(q: QueueState, carry):
        # 1. bulk pop up to `batch` subproblems
        q, items, n_popped = ops.pop_bulk(q, batch, jnp.int32(batch))
        valid = jnp.arange(batch, dtype=jnp.int32) < n_popped
        subs = Subproblem(layer=items["layer"], state=items["state"],
                          value=items["value"])

        # 2. explore
        out = explore_batch(subs, valid, weights, profits,
                            width=explore_width, n_vars=n_vars)

        # 3. global incumbent via the master's bookkeeping (all-reduce max)
        local_best = jnp.maximum(carry["incumbent"], jnp.max(out["primal"]))
        incumbent = lax.pmax(local_best, AXIS)

        # 4. prune: a subproblem's children survive iff dual > incumbent
        keep = (out["dual"] > incumbent)[:, None]
        ch = out["children"]
        live = keep & (ch.layer >= 0)                  # (batch, width)
        flat = {
            "layer": ch.layer.reshape(-1),
            "state": ch.state.reshape(-1),
            "value": ch.value.reshape(-1),
        }
        flive = live.reshape(-1)
        # compact live children to the front (single sort — bulk, no
        # per-node op)
        order = jnp.argsort(~flive, stable=True)
        flat = jax.tree_util.tree_map(lambda x: x[order], flat)
        n_children = jnp.sum(flive.astype(jnp.int32))

        # 5. bulk push (step 6, the rebalancing superstep, is appended by
        # the runtime)
        q, _ = ops.push(q, flat, n_children)
        return q, {"incumbent": incumbent,
                   "explored": carry["explored"] + n_popped}

    return body


def parallel_solve(inst: Knapsack, *, n_workers: int = 8,
                   explore_width: int = 16, batch: int = 8,
                   capacity: int = 4096, policy: StealPolicy | None = None,
                   max_supersteps: int = 10_000, adaptive: bool = True,
                   backend: str | BulkOps | None = None,
                   fused_rounds: int = 8,
                   execution: str = "vmap") -> Tuple[int, dict]:
    """Solve on W executor lanes — vmapped on one device by default, or
    one lane per device of a worker mesh with ``execution="mesh"`` (the
    solver body is mode-agnostic; both modes come from
    :func:`repro.distributed.launch_runtime` and run the identical
    fused round loop).

    ``backend`` optionally overrides the :class:`~repro.core.ops.BulkOps`
    routing for every queue op (master steal/splice AND the worker
    body's bulk pop/push); when omitted, ``policy.backend`` (default
    ``"auto"``) decides, resolved from the geometry at runtime
    construction.  ``fused_rounds > 1`` advances up to that many
    supersteps per device dispatch (``StealRuntime.run_fused`` — worker
    explore, rebalance and the adaptive proportion update all in one
    on-device loop, early-exiting at drain).

    Returns (optimum, stats); ``stats["telemetry"]`` carries the
    runtime's per-round rebalancing summary.
    """
    from repro.distributed.launch import launch_runtime

    policy = policy or StealPolicy(proportion=0.5, high_watermark=4,
                                   low_watermark=0,
                                   max_steal=min(capacity, 1024))
    w = jnp.asarray(inst.weights, jnp.int32)
    p = jnp.asarray(inst.profits, jnp.int32)

    runtime = launch_runtime(n_workers, capacity, _item_spec(),
                             execution=execution, policy=policy,
                             adaptive=adaptive, backend=backend,
                             max_pop=batch, axis_name=AXIS)
    # seed: root subproblem on worker 0
    runtime.push(0, {"layer": jnp.zeros((1,), jnp.int32),
                     "state": jnp.full((1,), inst.capacity, jnp.int32),
                     "value": jnp.zeros((1,), jnp.int32)}, 1)

    body = _make_worker_body(w, p, runtime.ops, explore_width=explore_width,
                             batch=batch, n_vars=inst.n)
    carry = {"incumbent": jnp.full((n_workers,), NEG, jnp.int32),
             "explored": jnp.zeros((n_workers,), jnp.int32)}

    carry = runtime.run(body, carry, max_rounds=max_supersteps,
                        fused=fused_rounds)

    stats = {
        "supersteps": runtime.rounds_run,
        "explored": int(jnp.sum(carry["explored"])),
        "transferred": runtime.telemetry.total_transferred,
        "per_worker_explored": [int(x) for x in carry["explored"]],
        "telemetry": runtime.telemetry.summary(),
        "backend": runtime.ops.resolved,
        "execution": execution,
    }
    return int(carry["incumbent"][0]), stats
