"""DD-based branch-and-bound (Bergman et al. [18], as described in the
paper's Section I-A): each subproblem is a DD node (layer, state, value);
exploring it builds a restricted DD (primal bound), a relaxed DD (dual
bound), and — when the exact DD overflows the width budget — an exact
frontier whose nodes become the child subproblems (bulk generation:
up to ``width`` children per explore, the workload the paper's queue is
built for).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dd.diagram import (
    DEAD, NEG, Pool, build_bounds, expand_layer, reduce_exact,
)
from repro.core.dd.knapsack import Knapsack

__all__ = ["Subproblem", "explore", "explore_batch", "solve"]


class Subproblem(NamedTuple):
    layer: jnp.ndarray   # int32 — next variable to decide
    state: jnp.ndarray   # int32 — remaining capacity
    value: jnp.ndarray   # int32 — accumulated profit


def exact_frontier(root: Subproblem, weights, profits, *, width: int,
                   n_vars: int):
    """Expand EXACTLY until the pool would exceed ``width``.

    Returns (frontier Pool (W,), frontier_layer, was_exact, exact_value):
    if the exact DD completes (never overflows), was_exact=True and
    exact_value is the optimum of this subtree; otherwise the frontier
    nodes at ``frontier_layer`` partition the subtree exactly.
    """
    s0 = jnp.full((width,), DEAD, jnp.int32).at[0].set(root.state)
    v0 = jnp.full((width,), NEG, jnp.int32).at[0].set(root.value)
    pool0 = Pool(s0, v0)

    def step(carry, inp):
        pool, done, frontier, f_layer = carry
        i, w, p = inp
        active = (i >= root.layer) & ~done
        children = expand_layer(pool, w, p)
        new_pool, overflow = reduce_exact(children, width)
        overflow = overflow & active
        # On overflow: freeze the PARENT pool as the frontier at layer i.
        frontier = jax.tree_util.tree_map(
            lambda f, pp: jnp.where(overflow, pp, f), frontier, pool)
        f_layer = jnp.where(overflow, i, f_layer)
        done = done | overflow
        pool = jax.tree_util.tree_map(
            lambda new, old: jnp.where(active & ~overflow, new, old),
            new_pool, pool)
        return (pool, done, frontier, f_layer), None

    idx = jnp.arange(n_vars, dtype=jnp.int32)
    (pool, done, frontier, f_layer), _ = jax.lax.scan(
        step, (pool0, jnp.bool_(False), pool0, jnp.int32(-1)),
        (idx, weights, profits))
    was_exact = ~done
    exact_value = jnp.max(jnp.where(pool.states >= 0, pool.values, NEG))
    return frontier, f_layer, was_exact, exact_value


@functools.partial(jax.jit, static_argnames=("width", "n_vars"))
def explore(sub: Subproblem, weights, profits, *, width: int, n_vars: int):
    """Explore one subproblem.  Returns dict:
      primal: restricted-DD bound (a feasible completion value)
      dual:   relaxed-DD bound (upper bound on the subtree)
      exact:  bool — subtree solved exactly (no children)
      children: Subproblem batch (W,) (dead slots layer = -1)
    """
    primal, dual = build_bounds(sub.state, sub.value, sub.layer,
                                weights, profits, width=width, n_vars=n_vars)
    frontier, f_layer, was_exact, exact_value = exact_frontier(
        sub, weights, profits, width=width, n_vars=n_vars)
    primal = jnp.where(was_exact, exact_value, primal)
    dual = jnp.where(was_exact, exact_value, dual)
    live = (frontier.states >= 0) & ~was_exact
    children = Subproblem(
        layer=jnp.where(live, f_layer, -1).astype(jnp.int32),
        state=jnp.where(live, frontier.states, DEAD),
        value=jnp.where(live, frontier.values, NEG),
    )
    return {"primal": primal, "dual": dual, "exact": was_exact,
            "children": children}


@functools.partial(jax.jit, static_argnames=("width", "n_vars"))
def explore_batch(subs: Subproblem, valid: jnp.ndarray, weights, profits, *,
                  width: int, n_vars: int):
    """vmapped explore over a (E,) batch; invalid rows produce nothing."""
    out = jax.vmap(lambda s: explore(s, weights, profits, width=width,
                                     n_vars=n_vars))(subs)
    primal = jnp.where(valid, out["primal"], NEG)
    dual = jnp.where(valid, out["dual"], NEG)
    ch = out["children"]
    live = valid[:, None] & (ch.layer >= 0)
    children = Subproblem(
        layer=jnp.where(live, ch.layer, -1),
        state=jnp.where(live, ch.state, DEAD),
        value=jnp.where(live, ch.value, NEG),
    )
    return {"primal": primal, "dual": dual,
            "exact": out["exact"] & valid, "children": children}


def solve(inst: Knapsack, width: int = 32, batch: int = 16,
          max_steps: int = 10_000) -> Tuple[int, dict]:
    """Sequential (single-queue) DD branch-and-bound — the oracle the
    parallel master-worker solver must agree with."""
    w = jnp.asarray(inst.weights, jnp.int32)
    p = jnp.asarray(inst.profits, jnp.int32)
    stack = [(0, inst.capacity, 0)]
    incumbent = -(2 ** 30)
    stats = {"explored": 0, "pruned": 0, "generated": 1, "supersteps": 0}

    while stack and stats["explored"] < max_steps:
        take = stack[:batch]
        stack = stack[batch:]
        E = len(take)
        arr = np.full((batch, 3), -1, np.int32)
        arr[:E] = np.asarray(take, np.int32)
        subs = Subproblem(layer=jnp.asarray(arr[:, 0]),
                          state=jnp.asarray(arr[:, 1]),
                          value=jnp.asarray(arr[:, 2]))
        valid = jnp.arange(batch) < E
        out = explore_batch(subs, valid, w, p, width=width, n_vars=inst.n)
        stats["explored"] += E
        stats["supersteps"] += 1
        incumbent = max(incumbent, int(jnp.max(out["primal"])))
        duals = np.asarray(out["dual"])
        ch = jax.tree_util.tree_map(np.asarray, out["children"])
        for e in range(E):
            if duals[e] <= incumbent and not bool(out["exact"][e]):
                stats["pruned"] += 1
                continue
            for j in range(ch.layer.shape[1]):
                if ch.layer[e, j] >= 0:
                    stack.append((int(ch.layer[e, j]), int(ch.state[e, j]),
                                  int(ch.value[e, j])))
                    stats["generated"] += 1
    return incumbent, stats
