"""``"relaxed"`` — a fence-free, multiplicity-tolerant BulkOps backend.

Castañeda & Piña's relaxed work-stealing queues drop the store-load
fence on the steal path by letting a steal *over-report*: the stealer
optimistically claims a block from a possibly-stale view of the queue,
bounded multiplicity means at most a fixed window of entries can be
claimed beyond what the owner still agrees exists, and the owner
reconciles the discrepancy on its next take.  The payoff is a
fence-free hot path at the cost of a bounded repair.

The functional translation (states are immutable, so a *torn* read is
impossible — what survives is the fenced-vs-optimistic DATAFLOW):

* the ``"reference"`` steal is **fenced**: it first fixes the stolen
  count ``n`` from a coherent size snapshot (``n = clip(req, 0,
  min(size, max_steal))``) and only then gathers + masks exactly that
  block — count before data, the analogue of fencing the size read
  against the copy;
* the ``"relaxed"`` steal is **optimistic**: it reads the ENTIRE static
  ``max_steal`` window at the tail first — the multiplicity window, up
  to ``max_steal - n`` rows beyond what the claim will settle at, rows
  the owner may well still consider its own — and *then* reconciles the
  over-report against the owner's size in a separate posterior step
  that withdraws (zero-masks) the over-claimed rows and settles the
  cursor bump.  Data before count: no ordering between the size read
  and the window copy is required, which is exactly the fence the
  relaxed design deletes.

The observable contract is IDENTICAL to the reference backend (the
parametrized queue/runtime/master suites sweep ``"relaxed"`` alongside
``"reference"`` and ``"auto"`` and assert it): over-reporting is always
repaired before anything escapes, and the multiplicity is bounded by
the static window.  Note the compact superstep's victim side already
works this way for everyone — ``BulkOps.window`` ships the raw unmasked
tail window through the all_gather and the thief discards the dead rows
— so the relaxed backend simply extends the same optimistic discipline
to the owner-facing steal ops.

Registry drop-in: ``make_ops("relaxed", capacity=..., max_steal=...)``.
The geometry predicate :func:`relaxed_supported` gates the optimistic
dataflow exactly like the kernel predicates gate the Pallas routing —
an unsupported/unknown geometry falls back to the fenced reference
routing (still named ``"relaxed"``, still observationally identical).
"""

from __future__ import annotations

import functools
import types
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as bulk_ops
from repro.core.ops import QueueState

__all__ = ["RelaxedBulkOps", "relaxed_supported", "optimistic_read",
           "reconcile"]

Pytree = object


def relaxed_supported(capacity: Optional[int],
                      max_steal: Optional[int]) -> bool:
    """Whether the optimistic full-window steal can serve this geometry:
    the multiplicity window must be real rows, i.e. fit the ring
    (``max_steal <= capacity``), else the unmasked window read would
    wrap onto itself and a single over-reported row could alias a live
    one.  Unknown geometry is conservatively unsupported (the backend
    then keeps the fenced reference routing, mirroring ``"auto"``)."""
    return (capacity is not None and max_steal is not None
            and 0 < int(max_steal) <= int(capacity))


def _optimistic_window(q: QueueState, max_steal: int) -> Pytree:
    """The fence-free bulk read: ALL ``max_steal`` tail rows, unmasked —
    no count is consulted, so nothing orders this copy against the size
    read that follows it."""
    cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
    offs = jnp.arange(max_steal, dtype=jnp.int32)
    phys = (q.lo + offs) % cap
    return jax.tree_util.tree_map(lambda b: b[phys], q.buf)


def _reconcile(q: QueueState, window: Pytree, claim: jnp.ndarray,
               max_steal: int, *, floor: Optional[jnp.ndarray] = None
               ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """The posterior repair (the owner-side reconcile of the paper's
    design, folded into the steal's return because states are values):
    settle the over-reported ``claim`` against the owner's size, withdraw
    the over-claimed rows from the window, bump the cursor by the
    settled count only.

    ``floor`` is the *stable-prefix* bound for the genuinely concurrent
    (split-step) protocol: the minimum owner-visible size observed at
    any point since the optimistic read.  The first ``floor`` rows of
    the window are a stable prefix — no owner push or pop since the read
    can have touched those physical slots — so a settle clamped to
    ``min(claim, floor, size)`` extracts exactly live, current rows.
    Without the clamp a dip-and-refill owner schedule (pop below the
    claim, then push into the reused slots) would let the settle hand
    out stale bytes while losing the refilled items.  The atomic
    single-step path (``floor=None``) needs no clamp: nothing can run
    between read and reconcile, so ``size`` itself is the stable prefix.
    ``repro.analysis.linearize`` model-checks both claims exhaustively.
    """
    cap = jax.tree_util.tree_leaves(q.buf)[0].shape[0]
    n = jnp.minimum(jnp.clip(jnp.asarray(claim, jnp.int32), 0,
                             jnp.int32(max_steal)),
                    q.size)
    if floor is not None:
        n = jnp.minimum(n, jnp.maximum(jnp.asarray(floor, jnp.int32),
                                       jnp.int32(0)))
    offs = jnp.arange(max_steal, dtype=jnp.int32)

    def _withdraw(x):
        shape = (max_steal,) + (1,) * (x.ndim - 1)
        return jnp.where((offs < n).reshape(shape), x, jnp.zeros_like(x))

    batch = jax.tree_util.tree_map(_withdraw, window)
    new_q = QueueState(buf=q.buf, lo=(q.lo + n) % cap, size=q.size - n)
    return new_q, batch, n


def optimistic_read(q: QueueState, max_steal: int) -> Pytree:
    """Step one of the split-step steal: the fence-free unmasked window
    read.  Public so the model checker and the adversarial property
    tests can interleave owner mutations between the two steps."""
    return _optimistic_window(q, max_steal)


def reconcile(q: QueueState, window: Pytree, claim, max_steal: int, *,
              floor=None) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Step two of the split-step steal: settle ``claim`` against the
    CURRENT owner state ``q``, clamped to the stable-prefix ``floor``
    (min owner-visible size since the read — see :func:`_reconcile`).
    Returns ``(new_state, batch, n)`` with over-claimed rows zeroed."""
    return _reconcile(q, window, claim, max_steal, floor=floor)


def _relaxed_steal_exact(q: QueueState, n, *, max_steal: int
                         ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    window = _optimistic_window(q, max_steal)  # data first (no fence) ...
    return _reconcile(q, window, n, max_steal)  # ... count + repair after


def _relaxed_steal(q: QueueState, proportion, *, max_steal: int,
                   queue_limit: int
                   ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    # The claim uses the paper's Listing-4 arithmetic unclamped by the
    # coherent-read fence: keep floor(size * (1-p)), claim the rest.
    size = jnp.asarray(q.size, jnp.int32)
    keep = jnp.asarray(
        jnp.floor(size.astype(jnp.float32) * (1.0 - proportion)), jnp.int32)
    claim = jnp.where(size < queue_limit, jnp.int32(0), size - keep)
    return _relaxed_steal_exact(q, claim, max_steal=max_steal)


@functools.lru_cache(maxsize=None)
def _donating() -> types.SimpleNamespace:
    donate = () if jax.default_backend() == "cpu" else (0,)
    return types.SimpleNamespace(
        steal=jax.jit(_relaxed_steal,
                      static_argnames=("max_steal", "queue_limit"),
                      donate_argnums=donate),
        steal_exact=jax.jit(_relaxed_steal_exact,
                            static_argnames=("max_steal",),
                            donate_argnums=donate),
    )


class RelaxedBulkOps(bulk_ops.BulkOps):
    """The fence-free backend: optimistic steal ops, reference routing
    for everything else (push/pop/pop_bulk/window/transfer are the
    owner/thief sides, which the relaxed design leaves fenced)."""

    def __init__(self):
        super().__init__("relaxed")

    @property
    def resolved(self) -> str:
        return "relaxed"

    def __eq__(self, other) -> bool:
        return type(other) is RelaxedBulkOps

    def __hash__(self) -> int:
        return hash((RelaxedBulkOps, self._flags()))

    def multiplicity_bound(self, max_steal: int) -> int:
        """The most rows a steal may transiently over-report before the
        reconcile withdraws them: the whole static window (a claim can
        settle as low as 0) — the bounded-multiplicity guarantee."""
        return int(max_steal)

    def steal(self, q: QueueState, proportion, *, max_steal: int,
              queue_limit: int = bulk_ops.DEFAULT_QUEUE_LIMIT,
              donate: bool = False
              ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
        if donate:
            return _donating().steal(q, proportion, max_steal=max_steal,
                                     queue_limit=queue_limit)
        return _relaxed_steal(q, proportion, max_steal=max_steal,
                              queue_limit=queue_limit)

    def steal_exact(self, q: QueueState, n, *, max_steal: int,
                    donate: bool = False
                    ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
        if donate:
            return _donating().steal_exact(q, n, max_steal=max_steal)
        return _relaxed_steal_exact(q, n, max_steal=max_steal)


def _relaxed_factory(*, capacity: Optional[int] = None,
                     max_push: Optional[int] = None,
                     max_pop: Optional[int] = None,
                     max_steal: Optional[int] = None) -> bulk_ops.BulkOps:
    if relaxed_supported(capacity, max_steal):
        return RelaxedBulkOps()
    # Geometry unknown or window > ring: fenced reference routing under
    # the same name (the predicate-gated fallback every backend family
    # uses), so a consumer can always ask for "relaxed" safely.
    if capacity is None or max_steal is None:
        reason = (f"geometry unknown (capacity={capacity}, "
                  f"max_steal={max_steal})")
    else:
        reason = (f"the multiplicity window does not fit the ring "
                  f"(max_steal={max_steal} > capacity={capacity})")
    bulk_ops._warn_fallback(
        ("relaxed", capacity, max_steal),
        f"relaxed falls back to the fenced reference routing: {reason}")
    return bulk_ops.BulkOps("relaxed")


bulk_ops.register_backend("relaxed", _relaxed_factory)
