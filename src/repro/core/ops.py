"""`BulkOps` — the single queue-operation contract, with pluggable backends.

The paper's core contribution is ONE bulk-operation interface
(push / pop / steal as batch ops) whose implementations can be swapped
and benchmarked against each other.  This module is that contract for
the reproduction: every consumer (the virtual master, the unified
executor, the DD solver, the benchmarks) talks to a :class:`BulkOps`
backend object instead of threading ``use_kernel`` booleans through
call sites.

Backends are named and live in a registry:

``"reference"``
    The jnp oracle: plain XLA gathers/scatters, no hand-written
    kernels.  The semantics baseline every other backend is tested
    against, and the path the ``REPRO_QUEUE_BACKEND=reference`` CI lane
    pins to prove independence from Pallas.
``"pallas"``
    Every hot-path op routed through the hand-written Pallas kernels
    (``kernels.queue_steal.ring_gather``, ``kernels.queue_push.
    ring_scatter`` / ``ring_slice``) — Pallas lowering on TPU, the
    kernel modules' jnp oracles elsewhere.  Per-call geometry predicates
    still gate each op (an unsupported geometry silently uses the
    reference path for that op, as before).
``"auto"``
    Resolves the kernel routing ONCE at construction from the queue
    geometry via the kernel modules' predicates
    (``ring_scatter_supported`` / ``ring_slice_supported`` /
    ``ring_gather_supported``): ops whose geometry the kernels support
    become kernel-backed, the rest stay reference.  No per-call
    branching.
``"relaxed"``
    The fence-free multiplicity-tolerant steal path per Castañeda &
    Piña (``repro.core.relaxed``, registered when ``repro.core``
    imports): optimistic full-window read, bounded over-report,
    posterior reconcile — observationally identical, gated by its own
    geometry predicate.

Operation contract
------------------
Every operation takes the :class:`QueueState` first and returns the new
state first — ``(state, ...) -> (state, batch, n)`` — with the detached
batch (static leading dim, dead rows zeroed) and the dynamic count
following where the op produces them (``push`` returns ``(state,
n_pushed)``: there is no detached batch).  Two exchange-side ops serve
the compact superstep: ``window`` (the victim's raw tail window for the
all_gather — a pure read) and ``transfer`` (the thief's fused
cut-and-splice out of the gathered window stack).  Each op accepts
``donate=True``, which routes through a cached jitted variant whose
input state is donated (XLA aliases the ring buffer input -> output, so
the update is an in-place scatter/cursor bump instead of a full-capacity
copy).  ``donate=False`` (default) composes the pure op inline into the
caller's trace — what ``master.superstep`` does.  This subsumes the old
``*_inplace`` triplets.

``REPRO_QUEUE_BACKEND`` (environment) overrides what ``"auto"``
resolves to — set it to ``reference`` to run any auto-configured
consumer (the executor, the solver, the benchmarks) on the oracle path.
Explicitly requested backends are never overridden.
"""

from __future__ import annotations

import functools
import os
import types
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "QueueState",
    "make_queue",
    "queue_size",
    "item_nbytes",
    "BulkOps",
    "make_ops",
    "register_backend",
    "available_backends",
    "steal_counted",
    "kernel_steal_available",
    "kernel_push_available",
    "kernel_pop_available",
    "kernel_transfer_available",
    "DEFAULT_QUEUE_LIMIT",
    "BACKEND_ENV_VAR",
    "BackendFallbackWarning",
    "reset_fallback_warnings",
]

Pytree = Any

# Default abort threshold, mirroring the paper's ``_queue_limit_``.
DEFAULT_QUEUE_LIMIT = 2

# Environment override for what "auto" resolves to (CI's oracle lane).
BACKEND_ENV_VAR = "REPRO_QUEUE_BACKEND"

# Environment switch for the runtime sanitizer (repro.analysis.sanitize):
# REPRO_CHECK=1 makes make_ops wrap every backend in invariant checks.
CHECK_ENV_VAR = "REPRO_CHECK"


class BackendFallbackWarning(UserWarning):
    """A requested routing silently downgraded — ``"auto"`` resolved a
    kernel op to the reference path because a geometry predicate rejected
    the bound, ``"relaxed"`` fell back to the fenced reference routing,
    or ``REPRO_QUEUE_BACKEND`` redirected ``"auto"`` wholesale.  Emitted
    at most once per distinct reason per process (the downgrade is safe —
    observationally identical — but should not be invisible)."""


_FALLBACK_WARNED: set = set()


def _warn_fallback(key: Tuple, message: str) -> None:
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    warnings.warn(message, BackendFallbackWarning, stacklevel=4)


def reset_fallback_warnings() -> None:
    """Forget which one-shot fallback warnings already fired (tests)."""
    _FALLBACK_WARNED.clear()


class QueueState(NamedTuple):
    """Immutable queue state.

    Attributes:
      buf:  pytree of ``(capacity, ...)`` arrays holding payloads.
      lo:   int32 physical index of the oldest element (steal side).
      size: int32 number of live elements; owner side is ``(lo+size) % cap``.
    """

    buf: Pytree
    lo: jnp.ndarray
    size: jnp.ndarray


def _capacity(q: QueueState) -> int:
    return jax.tree_util.tree_leaves(q.buf)[0].shape[0]


def _batch_size(batch: Pytree) -> int:
    return jax.tree_util.tree_leaves(batch)[0].shape[0]


def make_queue(capacity: int, item_spec: Pytree) -> QueueState:
    """Create an empty queue.

    Args:
      capacity: static ring capacity.
      item_spec: pytree of ``jax.ShapeDtypeStruct`` (or arrays) describing a
        single item — leaves get a leading ``capacity`` dimension.
    """
    buf = jax.tree_util.tree_map(
        lambda s: jnp.zeros((capacity,) + tuple(s.shape), dtype=s.dtype),
        item_spec,
    )
    return QueueState(buf=buf, lo=jnp.int32(0), size=jnp.int32(0))


def queue_size(q: QueueState) -> jnp.ndarray:
    return q.size


def item_nbytes(item_spec: Pytree) -> int:
    """Bytes per queue item: sum over payload-pytree leaves (arrays or
    ``ShapeDtypeStruct``s describing ONE item, no capacity dimension).
    The single source of truth for item payload accounting — the
    master's ``bytes_moved`` and the runtime telemetry both derive from
    it."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(item_spec):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# Geometry predicates (the kernel modules own the block-tiling rules)
# ---------------------------------------------------------------------------


def kernel_push_available(capacity: int, max_push: int) -> bool:
    """Whether the Pallas ring-scatter kernel can serve a push of this
    geometry."""
    from repro.kernels.queue_push.kernel import ring_scatter_supported

    return ring_scatter_supported(capacity, max_push)


def kernel_pop_available(capacity: int, max_n: int) -> bool:
    """Whether the Pallas ring-slice kernel can serve a bulk pop of this
    geometry."""
    from repro.kernels.queue_push.kernel import ring_slice_supported

    return ring_slice_supported(capacity, max_n)


def kernel_steal_available(capacity: int, max_steal: int) -> bool:
    """Whether the Pallas ring-gather kernel can serve a steal of this
    geometry."""
    from repro.kernels.queue_steal.kernel import ring_gather_supported

    return ring_gather_supported(capacity, max_steal)


def kernel_transfer_available(capacity: int, max_steal: int) -> bool:
    """Whether the Pallas fused ring-transfer kernel can serve the
    compact superstep's thief-side cut-and-splice of this geometry."""
    from repro.kernels.queue_transfer.kernel import ring_transfer_supported

    return ring_transfer_supported(capacity, max_steal)


# ---------------------------------------------------------------------------
# Pure op implementations (the single source of truth for semantics)
# ---------------------------------------------------------------------------


def _push(q: QueueState, batch: Pytree, n: jnp.ndarray, *,
          kernel: bool) -> Tuple[QueueState, jnp.ndarray]:
    """Bulk push ``n`` items (owner side).

    ``batch`` leaves have static leading dim ``B >= n``; only the first ``n``
    rows are enqueued.  Returns ``(new_state, n_pushed)`` where ``n_pushed``
    is clamped to the available space.  Cost: one masked ring-scatter —
    O(B) vectorized, constant per item.  The ``size + n`` update is the
    linearization point.
    """
    cap = _capacity(q)
    bsz = _batch_size(batch)
    n = jnp.minimum(jnp.asarray(n, jnp.int32), jnp.int32(cap) - q.size)
    n = jnp.maximum(n, 0)
    if kernel and kernel_push_available(cap, bsz):
        from repro.kernels.queue_push.ops import push_scatter

        buf = push_scatter(
            q.buf, batch, (q.lo + q.size) % cap, n,
            use_pallas=jax.default_backend() == "tpu",
        )
        return QueueState(buf=buf, lo=q.lo, size=q.size + n), n
    offs = jnp.arange(bsz, dtype=jnp.int32)
    phys = (q.lo + q.size + offs) % cap
    # Rows beyond ``n`` are routed out of bounds and dropped.
    phys = jnp.where(offs < n, phys, cap)
    buf = jax.tree_util.tree_map(
        lambda b, x: b.at[phys].set(x, mode="drop"), q.buf, batch
    )
    return QueueState(buf=buf, lo=q.lo, size=q.size + n), n


def _pop(q: QueueState) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Pop the newest item (owner side, LIFO).

    Returns ``(new_state, item, valid)``; ``item`` is arbitrary when
    ``valid`` is False (queue empty) — the null-pointer analogue.
    """
    cap = _capacity(q)
    valid = q.size > 0
    idx = (q.lo + jnp.maximum(q.size - 1, 0)) % cap
    item = jax.tree_util.tree_map(lambda b: b[idx], q.buf)
    new_size = jnp.where(valid, q.size - 1, q.size)
    return QueueState(buf=q.buf, lo=q.lo, size=new_size), item, valid


def _mask_batch(batch: Pytree, live: jnp.ndarray, rows: int) -> Pytree:
    def _m(x):
        shape = (rows,) + (1,) * (x.ndim - 1)
        return jnp.where(live.reshape(shape), x, jnp.zeros_like(x))

    return jax.tree_util.tree_map(_m, batch)


def _pop_bulk(q: QueueState, max_n: int, n: jnp.ndarray, *,
              kernel: bool) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Bulk pop up to ``n`` newest items (owner side).

    Returns ``(new_state, batch, n_popped)``; ``batch`` leaves have static
    leading dim ``max_n`` with valid rows ``[0, n_popped)`` in queue order
    (oldest of the popped block first) and rows ``>= n_popped`` zeroed
    (safe for summing collectives, identical across backends).
    """
    cap = _capacity(q)
    n = jnp.minimum(jnp.minimum(jnp.asarray(n, jnp.int32), q.size), max_n)
    n = jnp.maximum(n, 0)
    if kernel and kernel_pop_available(cap, max_n):
        from repro.kernels.queue_push.ops import pop_slice

        batch = pop_slice(
            q.buf, q.lo, q.size, n, max_n=max_n,
            use_pallas=jax.default_backend() == "tpu",
        )
        return QueueState(buf=q.buf, lo=q.lo, size=q.size - n), batch, n
    offs = jnp.arange(max_n, dtype=jnp.int32)
    start = q.size - n  # logical offset of the popped block
    phys = (q.lo + start + offs) % cap
    batch = jax.tree_util.tree_map(lambda b: b[phys], q.buf)
    batch = _mask_batch(batch, offs < n, max_n)
    return QueueState(buf=q.buf, lo=q.lo, size=q.size - n), batch, n


def _gather_block(q: QueueState, n: jnp.ndarray, max_steal: int,
                  kernel: bool) -> Pytree:
    """Detach ``max_steal`` rows starting at ``lo`` (rows >= ``n`` zeroed).

    ``kernel=True`` routes the copy through
    :func:`repro.kernels.queue_steal.ops.steal_gather` (Pallas on TPU,
    the jnp oracle elsewhere); ``kernel=False`` keeps the inline gather
    (still used by the counted baseline so Fig. 8 measures what it
    claims to).
    """
    cap = _capacity(q)
    if kernel and kernel_steal_available(cap, max_steal):
        from repro.kernels.queue_steal.ops import steal_gather

        return steal_gather(
            q.buf, q.lo, n, max_steal=max_steal,
            use_pallas=jax.default_backend() == "tpu",
        )
    offs = jnp.arange(max_steal, dtype=jnp.int32)
    phys = (q.lo + offs) % cap
    batch = jax.tree_util.tree_map(lambda b: b[phys], q.buf)
    return _mask_batch(batch, offs < n, max_steal)


def _steal_plan(
    size: jnp.ndarray, proportion, queue_limit: int, max_steal: int
) -> jnp.ndarray:
    """Number of items to steal, following the paper's Listing 4 arithmetic.

    ``n_skip = floor(size * (1 - proportion))`` items remain with the owner;
    ``size - n_skip`` are stolen, clamped to the static transfer buffer.
    Aborts (returns 0) when ``size < queue_limit``.
    """
    size = jnp.asarray(size, jnp.int32)
    keep = jnp.asarray(
        jnp.floor(size.astype(jnp.float32) * (1.0 - proportion)), jnp.int32
    )
    # Clamp to [0, min(size, max_steal)]: proportions outside [0, 1]
    # (e.g. a paging caller spilling "up to half the ring" of a nearly
    # empty queue) must never detach more items than exist — a negative
    # size corrupts the ring.
    n = jnp.clip(size - keep, 0, jnp.minimum(size, jnp.int32(max_steal)))
    return jnp.where(size < queue_limit, jnp.int32(0), n)


def _steal(q: QueueState, proportion, *, max_steal: int, queue_limit: int,
           kernel: bool) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Bulk steal of ``~proportion`` of the queue from the tail (oldest side).

    The paper's *optimized* variant, which on TPU is the natural one: the
    stolen count is fully determined by the size snapshot and the cut
    arithmetic, so no tail traversal is ever needed.  The single
    ``lo += n`` cursor bump is the linearization point (the analogue of
    the ``start->next = null`` severing write).
    """
    cap = _capacity(q)
    n = _steal_plan(q.size, proportion, queue_limit, max_steal)
    batch = _gather_block(q, n, max_steal, kernel)
    new_lo = (q.lo + n) % cap
    return QueueState(buf=q.buf, lo=new_lo, size=q.size - n), batch, n


def _steal_exact(q: QueueState, n: jnp.ndarray, *, max_steal: int,
                 kernel: bool) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Steal exactly ``n`` items (clamped to size / ``max_steal``) from the
    tail.  Used by the virtual master once the plan has fixed per-victim
    amounts; rows ``>= n`` of the returned batch are zeroed so the batch
    can move through summing collectives safely."""
    n = jnp.clip(jnp.asarray(n, jnp.int32), 0, jnp.minimum(q.size, max_steal))
    cap = _capacity(q)
    batch = _gather_block(q, n, max_steal, kernel)
    new_lo = (q.lo + n) % cap
    return QueueState(buf=q.buf, lo=new_lo, size=q.size - n), batch, n


def _window(q: QueueState, *, max_steal: int, kernel: bool) -> Pytree:
    """Raw tail window: rows ``(lo + i) % cap`` for ``i < max_steal``,
    UNMASKED (rows past ``size`` carry whatever the ring holds — they
    are dead weight the compact superstep's all_gather carries and the
    thief never reads).  This is the victim-side contribution to the
    compact exchange: the detach itself is a pure cursor bump, so no
    masked intermediate is ever materialized."""
    return _gather_block(q, jnp.int32(max_steal), max_steal, kernel)


def _transfer(q: QueueState, gathered: Pytree, src_row, n, *,
              max_steal: int, kernel: bool
              ) -> Tuple[QueueState, jnp.ndarray]:
    """Thief-side fused cut-and-splice for the compact superstep: splice
    rows ``gathered[src_row, :n]`` (each ``gathered`` leaf is a
    ``(W, max_steal, ...)`` stack of per-lane windows) at the owner end
    of ``q``.  Semantically ``push(q, gathered[src_row], n)``; the
    kernel path (``kernels.queue_transfer.ring_transfer``) reads the
    gathered buffer directly through a dynamic source offset so the
    selected ``(max_steal, ...)`` block never materializes.  Returns
    ``(new_state, n_spliced)`` with ``n`` clamped to the available
    space, exactly like ``push``."""
    cap = _capacity(q)
    src_row = jnp.asarray(src_row, jnp.int32)
    n = jnp.minimum(jnp.asarray(n, jnp.int32),
                    jnp.minimum(jnp.int32(cap) - q.size,
                                jnp.int32(max_steal)))
    n = jnp.maximum(n, 0)
    if kernel and kernel_transfer_available(cap, max_steal):
        from repro.kernels.queue_transfer.ops import transfer_splice

        buf = transfer_splice(
            q.buf, gathered, (q.lo + q.size) % cap, src_row, n,
            max_steal=max_steal,
            use_pallas=jax.default_backend() == "tpu",
        )
        return QueueState(buf=buf, lo=q.lo, size=q.size + n), n
    # Reference path IS "select the victim's row, then push" — delegate
    # so the ring-splice write has one source of truth (_push).
    batch = jax.tree_util.tree_map(
        lambda g: lax.dynamic_index_in_dim(g, src_row, 0, keepdims=False),
        gathered,
    )
    return _push(q, batch, n, kernel=False)


def steal_counted(
    q: QueueState,
    proportion,
    *,
    max_steal: int,
    queue_limit: int = DEFAULT_QUEUE_LIMIT,
) -> Tuple[QueueState, Pytree, jnp.ndarray]:
    """Paper-faithful *non-optimized* steal: pays an explicit sequential
    traversal over the stolen segment to (re)count it, mirroring the second
    list walk in Listing 4 lines 30-37.  Semantically identical to the
    backends' ``steal``; exists so benchmarks can reproduce Fig. 8's gap.
    Always the reference gather — it measures the baseline's cost shape.
    """
    new_q, batch, n = _steal(q, proportion, max_steal=max_steal,
                             queue_limit=queue_limit, kernel=False)
    # Sequential dependent chain emulating pointer-chasing: each step reads
    # a payload element gated by the previous counter value, so XLA cannot
    # vectorize or elide it.
    lead = jax.tree_util.tree_leaves(batch)[0]
    flat = lead.reshape(lead.shape[0], -1)

    def body(i, carry):
        count, acc = carry
        live = i < n
        probe = flat[i, 0].astype(jnp.float32)
        acc = acc + jnp.where(live, probe * 0.0 + 1.0, 0.0) * (count + 1.0) * 0.0
        count = count + jnp.where(live, 1, 0)
        return count, acc

    count, acc = lax.fori_loop(0, max_steal, body, (jnp.int32(0), jnp.float32(0.0)))
    # ``count == n`` always; fold the dead value in so the loop is not DCE'd.
    n = count + jnp.asarray(acc, jnp.int32) * 0
    return new_q, batch, n


# ---------------------------------------------------------------------------
# Donating (in-place) variants — jitted once per (routing, geometry)
# ---------------------------------------------------------------------------
#
# The pure ops above copy-on-write the full-capacity ring every call when
# used as plain host-called functions.  The donating variants jit them
# with the queue state DONATED, so XLA aliases the input ring buffer to
# the output and the update lowers to an in-place scatter/cursor bump.
# Semantics are identical (tests assert equivalence); the caller must not
# reuse the donated input state afterwards.  Donation is a no-op (with
# identical results) on backends that don't implement it (CPU) — the
# call is still jitted, so host-driven loops pay one dispatch, not a
# retrace.


@functools.lru_cache(maxsize=None)
def _donating(kernel_push: bool, kernel_pop: bool, kernel_steal: bool,
              kernel_transfer: bool) -> types.SimpleNamespace:
    donate = () if jax.default_backend() == "cpu" else (0,)
    return types.SimpleNamespace(
        push=jax.jit(functools.partial(_push, kernel=kernel_push),
                     donate_argnums=donate),
        pop=jax.jit(_pop, donate_argnums=donate),
        pop_bulk=jax.jit(functools.partial(_pop_bulk, kernel=kernel_pop),
                         static_argnums=(1,), donate_argnums=donate),
        steal=jax.jit(functools.partial(_steal, kernel=kernel_steal),
                      static_argnames=("max_steal", "queue_limit"),
                      donate_argnums=donate),
        steal_exact=jax.jit(
            functools.partial(_steal_exact, kernel=kernel_steal),
            static_argnames=("max_steal",), donate_argnums=donate),
        window=jax.jit(functools.partial(_window, kernel=kernel_steal),
                       static_argnames=("max_steal",)),
        transfer=jax.jit(
            functools.partial(_transfer, kernel=kernel_transfer),
            static_argnames=("max_steal",), donate_argnums=donate),
    )


# ---------------------------------------------------------------------------
# The backend object
# ---------------------------------------------------------------------------


class BulkOps:
    """One queue-operation backend: the paper's bulk push/pop/steal
    contract with a fixed kernel routing.

    Instances are cheap, stateless value objects — the four ``kernel_*``
    booleans are the entire configuration, fixed at construction (this is
    where ``"auto"``'s geometry resolution happens, never per call).
    Obtain instances via :func:`make_ops`; compare routing with
    :attr:`resolved` (``"reference"`` / ``"pallas"`` / ``"mixed"``).
    """

    def __init__(self, name: str, *, kernel_push: bool = False,
                 kernel_pop: bool = False, kernel_steal: bool = False,
                 kernel_transfer: bool = False):
        self.name = name
        self.kernel_push = bool(kernel_push)
        self.kernel_pop = bool(kernel_pop)
        self.kernel_steal = bool(kernel_steal)
        self.kernel_transfer = bool(kernel_transfer)

    @property
    def resolved(self) -> str:
        """The effective routing: which implementation family serves ops."""
        flags = self._flags()
        if all(flags):
            return "pallas"
        if not any(flags):
            return "reference"
        return "mixed"

    def __repr__(self) -> str:
        return (f"BulkOps({self.name!r}, push={self.kernel_push}, "
                f"pop={self.kernel_pop}, steal={self.kernel_steal}, "
                f"transfer={self.kernel_transfer})")

    def __eq__(self, other) -> bool:
        return (isinstance(other, BulkOps)
                and self._flags() == other._flags())

    def __hash__(self) -> int:
        return hash(self._flags())

    def _flags(self) -> Tuple[bool, bool, bool, bool]:
        return (self.kernel_push, self.kernel_pop, self.kernel_steal,
                self.kernel_transfer)

    # -- operations ----------------------------------------------------------

    def push(self, q: QueueState, batch: Pytree, n, *,
             donate: bool = False) -> Tuple[QueueState, jnp.ndarray]:
        """Bulk push ``n`` items; returns ``(state, n_pushed)``."""
        if donate:
            return _donating(*self._flags()).push(q, batch, n)
        return _push(q, batch, n, kernel=self.kernel_push)

    def pop(self, q: QueueState, *, donate: bool = False
            ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
        """Pop the newest item; returns ``(state, item, valid)``."""
        if donate:
            return _donating(*self._flags()).pop(q)
        return _pop(q)

    def pop_bulk(self, q: QueueState, max_n: int, n, *,
                 donate: bool = False
                 ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
        """Bulk pop up to ``n`` newest items; returns
        ``(state, batch, n_popped)`` with ``batch`` rows >= n zeroed."""
        if donate:
            return _donating(*self._flags()).pop_bulk(q, max_n, n)
        return _pop_bulk(q, max_n, n, kernel=self.kernel_pop)

    def steal(self, q: QueueState, proportion, *, max_steal: int,
              queue_limit: int = DEFAULT_QUEUE_LIMIT,
              donate: bool = False
              ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
        """Proportional bulk steal from the tail; returns
        ``(state, batch, n_stolen)``."""
        if donate:
            return _donating(*self._flags()).steal(
                q, proportion, max_steal=max_steal, queue_limit=queue_limit)
        return _steal(q, proportion, max_steal=max_steal,
                      queue_limit=queue_limit, kernel=self.kernel_steal)

    def steal_exact(self, q: QueueState, n, *, max_steal: int,
                    donate: bool = False
                    ) -> Tuple[QueueState, Pytree, jnp.ndarray]:
        """Steal exactly ``n`` items (clamped); returns
        ``(state, batch, n_stolen)``."""
        if donate:
            return _donating(*self._flags()).steal_exact(
                q, n, max_steal=max_steal)
        return _steal_exact(q, n, max_steal=max_steal,
                            kernel=self.kernel_steal)

    def window(self, q: QueueState, *, max_steal: int,
               donate: bool = False) -> Pytree:
        """Raw (unmasked) ``max_steal``-row tail window at ``lo`` — the
        victim-side contribution to the compact superstep's all_gather.
        Pure read: the state is unchanged (the victim's detach is the
        caller's cursor bump)."""
        if donate:
            return _donating(*self._flags()).window(q, max_steal=max_steal)
        return _window(q, max_steal=max_steal, kernel=self.kernel_steal)

    def transfer(self, q: QueueState, gathered: Pytree, src_row, n, *,
                 max_steal: int, donate: bool = False
                 ) -> Tuple[QueueState, jnp.ndarray]:
        """Fused thief-side cut-and-splice: push ``gathered[src_row, :n]``
        (leaves ``(W, max_steal, ...)``) at the owner end without
        materializing the selected block; returns ``(state, n_spliced)``."""
        if donate:
            return _donating(*self._flags()).transfer(
                q, gathered, src_row, n, max_steal=max_steal)
        return _transfer(q, gathered, src_row, n, max_steal=max_steal,
                         kernel=self.kernel_transfer)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# A factory takes the geometry kwargs and returns a configured BulkOps.
BackendFactory = Callable[..., BulkOps]

_REGISTRY: Dict[str, BackendFactory] = {}


def register_backend(name: str, factory: BackendFactory) -> None:
    """Register a named backend factory.  The factory receives the
    geometry keywords of :func:`make_ops` (``capacity`` / ``max_push`` /
    ``max_pop`` / ``max_steal``, each possibly ``None``)."""
    _REGISTRY[name] = factory


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def _reference_factory(**_geometry) -> BulkOps:
    return BulkOps("reference")


def _pallas_factory(**_geometry) -> BulkOps:
    return BulkOps("pallas", kernel_push=True, kernel_pop=True,
                   kernel_steal=True, kernel_transfer=True)


def _auto_factory(*, capacity: Optional[int] = None,
                  max_push: Optional[int] = None,
                  max_pop: Optional[int] = None,
                  max_steal: Optional[int] = None) -> BulkOps:
    """Resolve the kernel routing once, from the geometry predicates.
    Unknown geometry components conservatively stay on the reference
    path (no per-call probing)."""
    def ok(op, pred, bound):
        if capacity is None or bound is None:
            return False  # unknown geometry: documented reference default
        if pred(capacity, bound):
            return True
        _warn_fallback(
            ("auto", op, capacity, bound),
            f"auto: {op} falls back to the reference path — the kernel "
            f"geometry predicate rejected capacity={capacity}, "
            f"bound={bound} (block tiling does not divide the ring)")
        return False

    return BulkOps(
        "auto",
        kernel_push=ok("push", kernel_push_available, max_push),
        kernel_pop=ok("pop_bulk", kernel_pop_available, max_pop),
        kernel_steal=ok("steal", kernel_steal_available, max_steal),
        kernel_transfer=ok("transfer", kernel_transfer_available, max_steal),
    )


register_backend("reference", _reference_factory)
register_backend("pallas", _pallas_factory)
register_backend("auto", _auto_factory)


def _env_check() -> bool:
    return os.environ.get(CHECK_ENV_VAR, "").strip().lower() in (
        "1", "true", "yes", "on")


def make_ops(backend: Optional[str] = "auto", *,
             capacity: Optional[int] = None,
             max_push: Optional[int] = None,
             max_pop: Optional[int] = None,
             max_steal: Optional[int] = None,
             check: Optional[bool] = None) -> BulkOps:
    """Construct a :class:`BulkOps` backend.

    ``backend`` is a registry name (``"reference"`` / ``"pallas"`` /
    ``"auto"`` / anything registered) or an existing :class:`BulkOps`
    (returned unchanged, so call sites can accept either).  ``"auto"``
    (also the ``backend=None`` default) resolves its kernel routing HERE,
    once, from the geometry keywords — and honours the
    ``REPRO_QUEUE_BACKEND`` environment override; explicit names are
    never overridden.

    ``check=True`` (default: the ``REPRO_CHECK`` environment switch)
    wraps the backend in the runtime sanitizer
    (``repro.analysis.sanitize.CheckedBulkOps``): every op validated
    against the sequential contract — conservation, cursor monotonicity,
    dead rows zeroed — eagerly on concrete states, via
    ``jax.debug.callback`` scalar checks under a trace.
    """
    if check is None:
        check = _env_check()
    if isinstance(backend, BulkOps):
        return _maybe_checked(backend, check)
    if backend is None:
        backend = "auto"
    if backend == "auto":
        env = os.environ.get(BACKEND_ENV_VAR, "").strip()
        if env and env != "auto":
            _warn_fallback(
                ("env", env),
                f"auto resolved to {env!r} via the {BACKEND_ENV_VAR} "
                f"environment override, not geometry routing")
            backend = env
    try:
        factory = _REGISTRY[backend]
    except KeyError:
        raise ValueError(
            f"unknown queue backend {backend!r}; "
            f"available: {available_backends()}") from None
    ops = factory(capacity=capacity, max_push=max_push, max_pop=max_pop,
                  max_steal=max_steal)
    return _maybe_checked(ops, check)


def _maybe_checked(ops: BulkOps, check: bool) -> BulkOps:
    if not check:
        return ops
    from repro.analysis.sanitize import CheckedBulkOps  # deferred: no cycle

    if isinstance(ops, CheckedBulkOps):
        return ops
    return CheckedBulkOps(ops)
