"""Steal policies and watermark scheduling for the virtual master.

The paper's master (a) waits until a victim is *nearly drained* before
redistributing (§II.B), (b) steals a *proportion* of the victim's queue in
one bulk operation, and (c) is the only stealer.  These translate to a
deterministic plan computed identically on every device from the gathered
size vector (see ``core.master``).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["StealPolicy", "proportional", "steal_half", "adaptive_chunk", "plan_transfers"]


@dataclasses.dataclass(frozen=True)
class StealPolicy:
    """Configuration of the master's rebalancing policy.

    Attributes:
      proportion: fraction of the victim's queue taken per steal (paper's
        ``steal(p)`` argument).  The default 0.25 is the BENCH_PR3
        adaptive-sweep winner (full-size Fig. 9 DAG drain: static
        p=0.25 at 400 supersteps vs 420 for p=0.5 and every adaptive
        config); ``steal_half()`` still gives the paper's 0.5.
      queue_limit: victims below this size are never stolen from (paper's
        ``_queue_limit_`` abort).
      low_watermark: a worker is *idle-eligible* (receives work) when its
        queue size is <= this — the paper's "nearly drained" criterion.
      high_watermark: a worker is a steal *victim* only above this.
      max_steal: static upper bound on a single bulk transfer (ring/buffer
        size on device).
      backend: name of the :class:`repro.core.ops.BulkOps` backend serving
        the master's queue ops (``"reference"`` / ``"pallas"`` /
        ``"auto"`` / ``"relaxed"``) — consumers resolve it via
        ``make_ops`` with their geometry; the default ``"auto"``
        resolves to the kernel routing exactly where the geometry
        predicates admit it (and honours the ``REPRO_QUEUE_BACKEND``
        override).
      exchange: which collective moves the stolen blocks in
        ``master.superstep`` — ``"compact"`` (default: one
        ``(max_steal, ...)`` window all_gather per lane + thief-side
        dynamic row-select, with a zero-transfer fast path) or
        ``"dense"`` (the O(W * max_steal)-payload outbox +
        ``all_to_all``, kept as the exchange oracle and for the Fig. 10
        scaling comparison).  Both are semantically identical
        (property-tested); the plan they execute is the same.
    """

    proportion: float = 0.25
    queue_limit: int = 2
    low_watermark: int = 1
    high_watermark: int = 8
    max_steal: int = 256
    backend: str = "auto"
    exchange: str = "compact"


def proportional(p: float, **kw) -> StealPolicy:
    """The paper's policy: steal fraction ``p`` of the victim's tail."""
    return StealPolicy(proportion=p, **kw)


def steal_half(**kw) -> StealPolicy:
    """Hendler-Shavit steal-half (paper §V), the common-case default."""
    return StealPolicy(proportion=0.5, **kw)


def adaptive_chunk(n_idle: int, n_busy: int, base: float = 0.5) -> float:
    """Adnan-Sato-style dynamic chunk sizing (paper §V): scale the stolen
    proportion with the idle/busy imbalance so one rebalancing round can
    feed several idle workers from one victim without over-stealing."""
    if n_busy <= 0:
        return 0.0
    ratio = n_idle / max(n_idle + n_busy, 1)
    return float(min(max(base * 2 * ratio, 0.125), 0.75))


def plan_transfers(sizes: jnp.ndarray, policy: StealPolicy) -> jnp.ndarray:
    """Compute a deterministic (victim -> thief) transfer plan.

    Args:
      sizes: int32 ``(n_workers,)`` queue sizes, identical on every device
        (from ``all_gather``).
      policy: the steal policy.

    Returns:
      int32 ``(n_workers, 2)``: for worker ``i``, ``plan[i] = (src, n)``
      meaning worker ``i`` *receives* ``n`` items stolen from ``src``
      (``src == i`` and ``n == 0`` when no transfer).  The plan pairs the
      k-th most idle worker with the k-th busiest victim — at most ONE steal
      per victim per round, which is the single-stealer invariant at
      superstep granularity.

    The function is pure jnp (usable inside jit / shard_map) and every
    device computes the identical plan from the identical size vector —
    the "virtual master".
    """
    n = sizes.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    idle = sizes <= policy.low_watermark
    victim = sizes >= jnp.maximum(policy.high_watermark, policy.queue_limit)

    # Rank idle workers (emptiest first) and victims (fullest first).
    idle_order = jnp.argsort(jnp.where(idle, sizes, jnp.int32(2**30)))
    victim_order = jnp.argsort(jnp.where(victim, -sizes, jnp.int32(2**30)))
    n_idle = jnp.sum(idle.astype(jnp.int32))
    n_victim = jnp.sum(victim.astype(jnp.int32))
    n_pairs = jnp.minimum(n_idle, n_victim)

    # Pair k-th idle with k-th victim.
    pair_rank = jnp.arange(n, dtype=jnp.int32)
    thief_of_pair = idle_order.astype(jnp.int32)
    victim_of_pair = victim_order.astype(jnp.int32)
    live = pair_rank < n_pairs

    steal_n = jnp.asarray(
        jnp.floor(sizes[victim_of_pair].astype(jnp.float32) * policy.proportion),
        jnp.int32,
    )
    steal_n = jnp.minimum(steal_n, jnp.int32(policy.max_steal))
    steal_n = jnp.where(live, steal_n, 0)

    # Scatter the plan back to per-worker rows (thief-indexed).
    src = jnp.full((n,), idx, dtype=jnp.int32)  # default: self (no-op)
    amt = jnp.zeros((n,), dtype=jnp.int32)
    src = src.at[thief_of_pair].set(
        jnp.where(live, victim_of_pair, thief_of_pair), mode="drop"
    )
    amt = amt.at[thief_of_pair].set(steal_n, mode="drop")
    return jnp.stack([src, amt], axis=-1)
