"""Faithful host-level port of the paper's lock-free work-stealing queue.

This module transcribes Listings 1-4 of the paper into Python as closely as
the language allows, for two reasons:

1. The **data pipeline** (``repro.data.pipeline``) runs on hosts, not TPUs,
   and its per-host shard queues have exactly the paper's concurrency model:
   one owner (the host's feeder thread) and one stealer (the straggler
   master).
2. The **benchmarks** (Figs. 6-8) compare the algorithm as published against
   Taskflow-style baselines; those run at host level too.

Fidelity notes (recorded per DESIGN.md §2):

* C++ ``std::atomic`` memory orders have no Python analogue.  CPython's GIL
  makes single attribute loads/stores atomic, which is *stronger* than the
  relaxed/acquire/release orders the paper needs, so the algorithm's logic
  transcribes 1:1 while the fence-level reasoning is vacuous here.  The
  *structure* — single cut linearization point, size re-check abort, second
  traversal for the non-optimized count — is preserved exactly.
* ``LinkedWSQueue.steal`` implements Listing 4 including the
  ``_queue_limit_`` abort and the drain consistency check
  (``ssz <= sz - (k >> 1)``); ``steal_optimized`` implements the paper's
  §IV optimization: skip the tail traversal when the owner made no
  concurrent update (detected by the size being unchanged), returning
  immediately after the cut.
"""

from __future__ import annotations

import threading
from typing import Any, Iterable, List, Optional, Protocol, Tuple, runtime_checkable

__all__ = [
    "LFNode",
    "llist_from_iter",
    "HostQueue",
    "LinkedWSQueue",
    "PerItemDequeQueue",
    "ResizingArrayQueue",
]

QUEUE_LIMIT = 2  # the paper's ``_queue_limit_``


@runtime_checkable
class HostQueue(Protocol):
    """The uniform host-level queue contract — the host analogue of
    :class:`repro.core.ops.BulkOps`.

    Every host implementation (the faithful :class:`LinkedWSQueue` port,
    the Taskflow-style :class:`PerItemDequeQueue` /
    :class:`ResizingArrayQueue` baselines, and the device-backed
    :class:`repro.core.queue.PagedQueue`) satisfies it, so the
    benchmark harness (``benchmarks/common.py``) and the serving /
    pipeline masters sweep or swap implementations through ONE surface.

    The protocol deliberately uses plain-python payload lists — the
    native representations (pre-linked ``llist`` batches, device rings)
    stay available on each class for the faithful benchmarks.
    """

    def push_bulk(self, items: Iterable[Any]) -> None:
        """Owner side: enqueue a batch of items (one bulk operation).
        Deque convention across ALL implementations: later items are
        newer — ``pop_item`` returns the batch's last item first, the
        stealer reaches its first items last-retained."""
        ...

    def pop_item(self) -> Optional[Any]:
        """Owner side: pop the newest item, or None when empty."""
        ...

    def steal_bulk(self, proportion: float) -> List[Any]:
        """Stealer side: detach ~``proportion`` of the queue from the
        steal side; returns the stolen payloads.  Intra-block order is
        implementation-defined.  The pure host implementations take
        exactly the oldest items; block/page-granular implementations
        (``PagedQueue``) approximate the oldest-side discipline at their
        transfer granularity — overflow pages move whole, whichever
        items they hold."""
        ...

    def make_batch(self, items: Iterable[Any]) -> Any:
        """Producer-side batch preparation (pre-linking, device transfer).
        Separated from :meth:`push_batch` so the benchmark harness times
        only the splice — the paper's Fig. 6 measures exactly that."""
        ...

    def push_batch(self, prepared: Any) -> None:
        """Owner side: splice a batch prepared by :meth:`make_batch`."""
        ...

    def __len__(self) -> int:
        ...


class LFNode:
    """``lf_node``: payload + next pointer.  (Cache-line padding from
    Listing 1 is meaningless in CPython and omitted.)"""

    __slots__ = ("next", "payload")

    def __init__(self, payload: Any = None):
        self.next: Optional["LFNode"] = None
        self.payload = payload


def llist_from_iter(items) -> Tuple[Optional[LFNode], Optional[LFNode], int]:
    """Build an ``llist`` (start, end, n) from an iterable — the pre-linked
    batch format the owner hands to ``push``."""
    start = end = None
    n = 0
    for it in items:
        node = LFNode(it)
        if start is None:
            start = end = node
        else:
            node.next = None
            end.next = node
            end = node
        n += 1
    return start, end, n


class LinkedWSQueue:
    """The paper's queue: singly linked list + ``size`` + ``head``.

    Owner API: :meth:`push`, :meth:`pop`.
    Stealer API: :meth:`steal`, :meth:`steal_optimized` (single concurrent
    stealer, enforced by the caller as in the paper's master-worker model).
    """

    def __init__(self, queue_limit: int = QUEUE_LIMIT):
        self.head: Optional[LFNode] = None
        self.size: int = 0
        self.queue_limit = queue_limit

    # -- owner ----------------------------------------------------------------

    def push(self, llist: Tuple[Optional[LFNode], Optional[LFNode], int]) -> None:
        """Listing 2: splice the pre-linked batch at the head.  O(1) in the
        batch size — the source of the paper's flat Fig. 6 latency."""
        start, end, n = llist
        if start is None:
            return
        end.next = self.head          # end->next = head.load(RELAXED)
        self.head = start             # head.store(start, RELEASE)
        self.size += n                # size.fetch_add(n, ACQ_REL)

    def pop(self) -> Optional[Any]:
        """Listing 3."""
        rv = self.head                # head.load(RELAXED)
        if rv is None:
            return None
        self.head = rv.next           # head.store(rv->next, RELAXED)
        self.size -= 1                # size.fetch_sub(1, ACQ_REL)
        rv.next = None
        return rv.payload

    # -- stealer --------------------------------------------------------------

    def steal(self, proportion: float):
        """Listing 4, non-optimized: traverse to the cut point, consistency
        check, sever, then traverse the stolen suffix to count it."""
        proportion = 1.0 - proportion
        sz = self.size                      # size.load(ACQUIRE)
        if sz < self.queue_limit:
            return (None, None, 0)
        n_skip = int(sz * proportion)
        k = n_skip

        start = self.head                   # head.load(ACQUIRE)
        while n_skip and start is not None:
            start = start.next
            n_skip -= 1
        if n_skip or start is None:
            return (None, None, 0)          # not enough nodes

        ssz = self.size                     # size.load(ACQUIRE)
        if ssz <= (sz - (k >> 1)):
            return (None, None, 0)          # draining too fast, abort

        begin = start.next
        start.next = None                   # THE linearization point
        # (release fence: size.fetch_add(0, RELEASE) — GIL supplies this)

        # Second traversal: count the stolen suffix (lines 30-37).
        end = begin
        count = 0
        while end is not None:
            count += 1
            if end.next is None:
                break
            end = end.next
        self.size -= count                  # size.fetch_sub(count)
        return (begin, end, count)

    def steal_optimized(self, proportion: float):
        """§IV optimized variant: if the owner made no update between the
        size snapshot and the cut (size unchanged), the stolen count is
        ``sz - cut_position`` and the tail traversal is skipped."""
        proportion = 1.0 - proportion
        sz = self.size
        if sz < self.queue_limit:
            return (None, None, 0)
        n_skip = int(sz * proportion)
        k = n_skip

        start = self.head
        while n_skip and start is not None:
            start = start.next
            n_skip -= 1
        if n_skip or start is None:
            return (None, None, 0)

        ssz = self.size
        if ssz <= (sz - (k >> 1)):
            return (None, None, 0)

        begin = start.next
        start.next = None                   # linearization point

        if self.size == sz and begin is not None:
            # Owner idle: count known from arithmetic; return immediately.
            # The cut node itself stays with the owner (begin = start->next),
            # so the stolen suffix has sz - k - 1 nodes.
            count = sz - k - 1
            self.size -= count
            return (begin, None, count)     # end not materialized (unused)

        # Fall back to the counted path.
        end = begin
        count = 0
        while end is not None:
            count += 1
            if end.next is None:
                break
            end = end.next
        self.size -= count
        return (begin, end, count)

    # -- helpers ---------------------------------------------------------------

    def drain(self) -> List[Any]:
        out = []
        while True:
            v = self.pop()
            if v is None and self.head is None:
                break
            out.append(v)
        return out

    def __len__(self) -> int:
        return self.size

    # -- HostQueue protocol adapters -------------------------------------------

    def push_bulk(self, items: Iterable[Any]) -> None:
        # The native splice consumes head-first (the batch's FIRST item
        # pops first); the protocol's deque convention is last-is-newest,
        # so pre-link in reverse.
        self.push(llist_from_iter(reversed(list(items))))

    def make_batch(self, items: Iterable[Any]):
        """Native pre-linked batch (head-first order, as in the paper's
        Listing 2 — ordering is implementation-defined here, unlike
        :meth:`push_bulk`)."""
        return llist_from_iter(items)

    def push_batch(self, prepared) -> None:
        self.push(prepared)

    def pop_item(self) -> Optional[Any]:
        return self.pop()

    def steal_bulk(self, proportion: float) -> List[Any]:
        begin, _, _count = self.steal_optimized(proportion)
        out: List[Any] = []
        node = begin
        while node is not None:
            out.append(node.payload)
            node = node.next
        return out


# ---------------------------------------------------------------------------
# Baselines (the paper compares against Taskflow's bounded/unbounded deques;
# we reproduce their *cost structure* rather than binding C++):
# ---------------------------------------------------------------------------


class PerItemDequeQueue:
    """Taskflow-unbounded-style baseline: bulk ops are simulated by repeated
    single-node operations (the inefficiency the paper calls out in §II.A).
    Owner pushes/pops at the right; the stealer takes items one at a time
    from the left, each under its own synchronization."""

    def __init__(self):
        import collections

        self._dq = collections.deque()
        self._lock = threading.Lock()

    def push(self, items) -> None:
        for it in items:                  # per-node operation, O(n) calls
            with self._lock:
                self._dq.append(it)

    def pop(self):
        with self._lock:
            return self._dq.pop() if self._dq else None

    def steal(self, proportion: float):
        with self._lock:
            n = int(len(self._dq) * proportion)
        out = []
        for _ in range(n):                # per-node steal
            with self._lock:
                if not self._dq:
                    break
                out.append(self._dq.popleft())
        return out

    def __len__(self):
        return len(self._dq)

    # -- HostQueue protocol adapters (push/steal are already list-shaped) ----

    push_bulk = push
    pop_item = pop
    steal_bulk = steal

    def make_batch(self, items):
        return list(items)

    push_batch = push


class ResizingArrayQueue:
    """Taskflow-bounded-style baseline: circular array that doubles and
    copies element-wise when full (the resizing overhead the paper's second
    requirement rejects)."""

    def __init__(self, capacity: int = 64):
        self._buf: List[Any] = [None] * capacity
        self._cap = capacity
        self._lo = 0
        self._n = 0
        self._lock = threading.Lock()

    def _grow(self) -> None:
        new = [None] * (self._cap * 2)
        for i in range(self._n):          # element-wise copy on resize
            new[i] = self._buf[(self._lo + i) % self._cap]
        self._buf, self._cap, self._lo = new, self._cap * 2, 0

    def push(self, items) -> None:
        for it in items:
            with self._lock:
                if self._n == self._cap:
                    self._grow()
                self._buf[(self._lo + self._n) % self._cap] = it
                self._n += 1

    def pop(self):
        with self._lock:
            if self._n == 0:
                return None
            self._n -= 1
            return self._buf[(self._lo + self._n) % self._cap]

    def steal(self, proportion: float):
        out = []
        with self._lock:
            n = int(self._n * proportion)
            for _ in range(n):
                out.append(self._buf[self._lo])
                self._lo = (self._lo + 1) % self._cap
                self._n -= 1
        return out

    def __len__(self):
        return self._n

    # -- HostQueue protocol adapters (push/steal are already list-shaped) ----

    push_bulk = push
    pop_item = pop
    steal_bulk = steal

    def make_batch(self, items):
        return list(items)

    push_batch = push
