"""repro.core — the paper's contribution: lock-free bulk work-stealing.

Layers:
  queue         functional ring-deque with bulk push / proportional bulk steal
  policy        steal policies + the virtual master's transfer planner
  master        SPMD rebalancing supersteps (all_gather + all_to_all)
  sharded_queue stacked per-worker queues, vmap/shard_map drivers
  host_queue    faithful host-threaded port of the paper's Listings 1-4
  dd            decision-diagram branch-and-bound solver (paper's application)
"""

from repro.core.queue import (  # noqa: F401
    QueueState,
    make_queue,
    queue_size,
    push,
    pop,
    pop_bulk,
    steal,
    steal_exact,
    steal_counted,
    PagedQueue,
)
from repro.core.policy import (  # noqa: F401
    StealPolicy,
    proportional,
    steal_half,
    adaptive_chunk,
    plan_transfers,
)
