"""repro.core — the paper's contribution: lock-free bulk work-stealing.

Layers:
  ops           the BulkOps backend contract (reference / pallas / auto)
                over the functional ring-deque: bulk push / pop /
                proportional bulk steal, one operation surface
  queue         QueueState + host paging; deprecated use_kernel shims
  policy        steal policies + the virtual master's transfer planner
  master        SPMD rebalancing supersteps (compact one-window
                all_gather exchange by default; dense all_to_all oracle)
  sharded_queue stacked per-worker queues, vmap/shard_map drivers
  host_queue    faithful host-threaded port of the paper's Listings 1-4,
                behind the HostQueue protocol
  dd            decision-diagram branch-and-bound solver (paper's application)
"""

from repro.core.ops import (  # noqa: F401
    BulkOps,
    QueueState,
    available_backends,
    make_ops,
    make_queue,
    queue_size,
    register_backend,
    steal_counted,
)
from repro.core.queue import (  # noqa: F401
    PagedQueue,
    pop,
    # Deprecated use_kernel-dialect shims, re-exported so pre-BulkOps
    # package-level imports keep working for one release (each call
    # emits DeprecationWarning).
    pop_bulk,
    push,
    steal,
    steal_exact,
)
from repro.core.policy import (  # noqa: F401
    StealPolicy,
    proportional,
    steal_half,
    adaptive_chunk,
    plan_transfers,
)
