"""repro.core — the paper's contribution: lock-free bulk work-stealing.

Layers:
  ops           the BulkOps backend contract (reference / pallas / auto /
                relaxed) over the functional ring-deque: bulk push /
                pop / proportional bulk steal, one operation surface
  relaxed       the fence-free multiplicity-tolerant backend
                (Castañeda & Piña): optimistic full-window steal +
                posterior reconcile, registered as "relaxed"
  queue         QueueState re-exports + host paging (PagedQueue)
  policy        steal policies + the virtual master's transfer planner
  master        SPMD rebalancing supersteps (compact one-window
                all_gather exchange by default; dense all_to_all oracle)
  sharded_queue stacked per-worker queues, vmap/shard_map drivers
  host_queue    faithful host-threaded port of the paper's Listings 1-4,
                behind the HostQueue protocol
  dd            decision-diagram branch-and-bound solver (paper's application)

(The pre-BulkOps ``use_kernel`` dialect — module-level queue ops and
their ``*_inplace`` variants — had its one deprecation release at PR 3
and is gone; construct a backend with :func:`make_ops`.)
"""

from repro.core.ops import (  # noqa: F401
    BulkOps,
    QueueState,
    available_backends,
    make_ops,
    make_queue,
    queue_size,
    register_backend,
    steal_counted,
)
from repro.core import relaxed as _relaxed  # noqa: F401  (registers "relaxed")
from repro.core.queue import (  # noqa: F401
    PagedQueue,
    pop,
)
from repro.core.policy import (  # noqa: F401
    StealPolicy,
    proportional,
    steal_half,
    adaptive_chunk,
    plan_transfers,
)
