from repro.configs.base import (
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    SHAPES,
    cells_for,
)
from repro.configs.registry import ARCH_IDS, get, reduced

__all__ = [
    "ModelConfig",
    "ParallelConfig",
    "ShapeConfig",
    "SHAPES",
    "cells_for",
    "ARCH_IDS",
    "get",
    "reduced",
]
