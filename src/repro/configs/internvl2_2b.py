"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT frontend + InternLM2 backbone.
[arXiv:2404.16821; hf]

Per the assignment, only the LM BACKBONE is modeled; the vision frontend
is a STUB: ``input_specs()`` provides precomputed patch embeddings
(batch, n_patches, frontend_dim) which a learned projection maps into the
token stream as a prefix.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    n_patches=256,               # one 448x448 tile => 256 patch embeddings
    frontend_dim=1024,           # InternViT-300M output width (stubbed)
)
