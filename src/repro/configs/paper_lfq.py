"""The paper's own configuration: lock-free bulk work-stealing queue
parameters + DD-solver instance defaults, mirroring §IV's evaluation
(queue of initial size 10,000; batch sizes 1..1024; steal proportions
10..60%; DAG workloads of 2.5M / 300M nodes — the large one is scaled to
this container in benchmarks, the full size is kept for the dry-run
planner)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class LFQConfig:
    queue_capacity: int = 16_384        # device ring capacity per worker
    queue_limit: int = 2                # paper's ``_queue_limit_``
    max_steal: int = 8_192              # static bulk-transfer upper bound
    steal_proportion: float = 0.5       # steal-half default (paper §V)
    low_watermark: int = 1              # "nearly drained" trigger (§II.B)
    high_watermark: int = 8
    push_batch_sizes: tuple = (1, 128, 512, 1024)       # Fig. 6
    steal_proportions: tuple = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6)  # Figs. 7-8
    bench_initial_size: int = 10_000    # Fig. 7 setup
    dag_nodes_small: int = 2_500_000    # Fig. 9
    dag_nodes_large: int = 300_000_000  # Fig. 9 (scaled on CPU)


CONFIG = LFQConfig()
