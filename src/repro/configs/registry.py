"""``--arch <id>`` registry + reduced smoke-test variants.

``get(arch_id)`` returns the full assigned config; ``reduced(cfg)`` returns
a small same-family config for CPU smoke tests (full configs are exercised
only via the dry-run's ShapeDtypeStructs, never allocated on CPU).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, cells_for

_MODULES: Dict[str, str] = {
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "zamba2-7b": "repro.configs.zamba2_7b",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family variant for CPU smoke tests: few layers, narrow
    width, tiny vocab, few experts — preserves every structural feature
    (GQA ratio, windowing, softcaps, MoE top-k, SSM heads, shared block)."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4 if not cfg.attn_every else 7),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32 if cfg.n_heads else None,
        tie_embeddings=cfg.tie_embeddings,
    )
    if cfg.n_heads:
        # Preserve the GQA group ratio where possible.
        ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(4 // min(ratio, 4), 1)
    if cfg.window:
        kw["window"] = 16
    if cfg.n_experts:
        kw["n_experts"] = 8
        kw["top_k"] = min(cfg.top_k, 2)
        kw["d_ff_expert"] = 64
    if cfg.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 16
        kw["ssm_chunk"] = 16
    if cfg.attn_every:
        kw["attn_every"] = 3
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 2
        kw["n_layers"] = 2
    if cfg.n_patches:
        kw["n_patches"] = 8
        kw["frontend_dim"] = 64
    if cfg.frontend_dim and not cfg.n_patches:
        kw["frontend_dim"] = 64
    return dataclasses.replace(cfg, **kw)


__all__ = ["ARCH_IDS", "get", "reduced", "SHAPES", "cells_for", "ShapeConfig"]
