"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="decoder",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,                # per hf config (d_model / n_heads would be 224)
    rope_theta=10_000.0,
    local_global_every=2,        # alternate: even layers local (SWA), odd global
    window=4096,                 # local-layer sliding window
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sandwich_norm=True,
    scale_embed=True,
    tie_embeddings=True,
)
