"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

The assigned d_ff=768 is the per-expert FFN width (Qwen3-MoE's
moe_intermediate_size); every layer is MoE.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,                      # no dense FFN; all layers MoE
    vocab_size=151936,
    head_dim=128,                # per hf config
    rope_theta=1_000_000.0,
    qk_norm=True,                # qwen3 per-head q/k RMSNorm
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
)
