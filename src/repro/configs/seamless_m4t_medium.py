"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=256206 — encoder-decoder, multimodal. [arXiv:2308.11596; hf]

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed audio-frame embeddings of shape (batch, frames,
frontend_dim); the transformer backbone (12L encoder + 12L decoder with
cross-attention) is what this config exercises.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                 # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10_000.0,
    frontend_dim=160,            # stub: 80-dim fbank x2 stacking
)
