"""Config system: model architecture + input shape + parallelism plan.

Every assigned architecture is a :class:`ModelConfig` instance in its own
module under ``repro.configs``; the registry in ``repro.configs.registry``
maps ``--arch <id>`` to it.  Shapes are :class:`ShapeConfig` instances —
the four assigned shape cells are declared here once and reused by every
arch (each arch filters out inapplicable cells via :func:`cells_for`).

Nothing in this package touches jax device state at import time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "AttentionKind",
    "ModelConfig",
    "ShapeConfig",
    "ParallelConfig",
    "SHAPES",
    "cells_for",
    "round_up",
]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all assigned families.

    family: "decoder" | "moe" | "encdec" | "vlm" | "ssm" | "hybrid"
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default: d_model // n_heads
    # Attention flavour ------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0              # chatglm3 "2d RoPE": rotary on half dims
    window: Optional[int] = None            # sliding-window size (SWA)
    local_global_every: Optional[int] = None  # gemma2: 1 == alternate local/global
    attn_logit_softcap: Optional[float] = None   # gemma2
    final_logit_softcap: Optional[float] = None  # gemma2
    qk_norm: bool = False                   # qwen3-style per-head q/k RMSNorm
    sandwich_norm: bool = False             # gemma2: post-norms after attn/mlp
    scale_embed: bool = False               # gemma2: embed * sqrt(d_model)
    # MoE --------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    # SSM (mamba2 / hybrid) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_dim: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0                     # hybrid: shared attn block every k layers
    # Enc-dec ----------------------------------------------------------------
    n_encoder_layers: int = 0
    # VLM --------------------------------------------------------------------
    n_patches: int = 0                      # stub frontend: precomputed patch embeds
    frontend_dim: int = 0                   # raw frame/patch embedding dim
    # Embedding / head -------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # Numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # Implementation switches ------------------------------------------------
    use_pallas: bool = False                # TPU target: Pallas kernels; CPU: jnp ref
    remat: bool = True
    moe_bulk_steal: bool = True             # the paper's technique in MoE dispatch
    moe_impl: str = "gspmd"                 # "gspmd" | "ep_shardmap" (§Perf)
    decode_impl: str = "gspmd"              # "gspmd" | "flash_shardmap" (§Perf)

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the head/embedding shard over 16-way TP (and the
        logits shard) always divides evenly."""
        return round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:               # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (sub-quadratic decode memory)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # SWA / alternating-local archs have window-bounded caches on local
        # layers; gemma2's global layers use sequence-sharded KV (SP).
        return self.window is not None or self.local_global_every is not None

    @property
    def has_decode(self) -> bool:
        return True  # no encoder-only arch in the assigned set

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        hd, H, K = self.hd, self.n_heads, self.n_kv_heads
        attn = (D * H * hd + 2 * D * K * hd + H * hd * D) if H else 0
        mlp = 3 * D * F if F else 0
        moe = 0
        if self.n_experts:
            moe = D * self.n_experts + self.n_experts * 3 * D * self.d_ff_expert
            mlp = 0
        ssm = 0
        if self.ssm_state:
            di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D,dt_bias
            ssm = D * (2 * di + 2 * ns + nh) + self.ssm_conv_dim * (di + 2 * ns) + di * D + 2 * nh
        per_layer = 2 * D  # norms
        if self.family == "ssm":
            per_layer += ssm
        elif self.family == "hybrid":
            per_layer += ssm
        elif self.family == "moe":
            per_layer += attn + moe
        else:
            per_layer += attn + mlp
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * D * F + 2 * D  # one shared block
        if self.family == "encdec":
            enc_per = 2 * D + attn + mlp
            dec_per = 3 * D + 2 * attn + mlp  # self + cross attn
            total = self.n_encoder_layers * enc_per + self.n_layers * dec_per
        total += V * D  # embedding
        if not self.tie_embeddings:
            total += D * V
        total += D  # final norm
        return int(total)


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def step_name(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step", "decode": "serve_step"}[self.kind]


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)


def cells_for(model: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells that apply to this arch (long_500k requires
    sub-quadratic decode; skips recorded in DESIGN.md)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not model.is_subquadratic:
            continue
        out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a mesh maps onto parallelism axes.

    data_axes are the DP/FSDP axes (batch + parameter sharding); model_axis
    is TP/EP/SP.  On the multi-pod mesh the "pod" axis joins DP for the
    batch but parameters stay replicated across pods (grads all-reduce over
    DCN once per step).
    """

    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    pod_axis: Optional[str] = None          # set on the multi-pod mesh
    fsdp_axis: Optional[str] = "data"       # None => pure DP (replicated params)
    remat: bool = True
    microbatch: int = 0                     # 0 => no gradient accumulation

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return ((self.pod_axis,) if self.pod_axis else ()) + self.data_axes
