"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32, MHA) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention blocks.
[arXiv:2411.15242; unverified]

Zamba2's signature trick: one set of attention+MLP parameters is SHARED
and applied every ``attn_every`` mamba blocks (we use 6, matching the
published ~13 shared-block applications over 81 layers).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,                  # MLP width of the shared block
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_dim=4,
    ssm_chunk=256,
    attn_every=6,
    tie_embeddings=True,
)
