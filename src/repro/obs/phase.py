"""Phase-attributed wall-clock for steal-runtime rounds.

The paper's claims are latency claims, but a dispatched round is one
opaque XLA program — there is no host-visible boundary between the
worker body, the exchange collective and the thief splice to put a
timer on.  Two mechanisms recover the split without touching the
committed computation:

**Unfused rounds (direct measurement).**  ``make_lane_step(stage=...)``
defines truncated *prefix* programs of the identical round: ``"worker"``
ends after the worker body, ``"exchange"`` ends after the block-exchange
collective (:func:`repro.core.master.exchange_probe`, whose returned
token data-depends on the spliced buffers so XLA cannot dead-code any of
the prefix).  The probe dispatches both prefixes on the SAME immutable
inputs the real round is about to consume (pure functions — results are
discarded, buffers are never donated), fences with
``jax.block_until_ready``, then lets the unchanged full round commit:

    worker_body = wall(P_worker)
    exchange    = wall(P_exchange) - wall(P_worker)
    splice      = wall(full round) - wall(P_exchange)

``adaptive_update`` is the host controller there, timed directly.

**Fused blocks (calibrated estimate).**  A ``lax.scan`` of k rounds
cannot be fenced per phase without breaking fusion (an in-trace
``jax.debug.callback`` costs ~0.4 ms per mark on CPU — an order of
magnitude over the <5 % overhead budget).  Instead the probe times the
whole dispatch, divides by the executed round count, and splits each
round by *calibrated phase fractions*: once per ``calibrate_every``
rounds it times the four prefix programs (worker / exchange / full /
full+adaptive) on the current state and caches the normalized deltas.
Fused samples are flagged ``estimated=True`` in the telemetry.

Compile-identity guarantee: prefix programs live in the runtime's
SEPARATE ``_probe_compiled`` cache — ``elastic.compile_count`` (which
audits ``_compiled``) is unchanged whether the probe is attached or
not, and with the probe disabled the dispatch path is byte-for-byte
today's code.  Because prefixes are pure and never donate, committed
results are bit-identical with the probe on or off.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["PHASES", "PhaseSample", "PhaseProbe", "timed_call",
           "trace_span"]

# Phase order is load-bearing: calibration deltas and trace children
# are emitted in this order.
PHASES: Tuple[str, ...] = ("worker_body", "exchange", "splice",
                           "adaptive_update")


@dataclasses.dataclass(frozen=True)
class PhaseSample:
    """One round's wall-clock split, in seconds.

    ``estimated`` distinguishes the fused path (whole-dispatch wall
    split by calibrated fractions) from the unfused path (each phase
    bounded by real fences).  ``total`` is the wall actually attributed
    to the round — phases sum to it by construction.
    """

    worker_body: float
    exchange: float
    splice: float
    adaptive_update: float
    total: float
    estimated: bool = False

    def as_record(self) -> Dict[str, Any]:
        """The kwargs `Telemetry.record(phases=...)` consumes."""
        return {
            "t_worker": self.worker_body,
            "t_exchange": self.exchange,
            "t_splice": self.splice,
            "t_adaptive": self.adaptive_update,
            "t_round": self.total,
            "phase_estimated": self.estimated,
        }


def timed_call(fn, args) -> Tuple[float, Any]:
    """Wall seconds of one dispatch, fenced on its OUTPUTS.  The caller
    is responsible for input readiness (in the probe's use the inputs
    were just fenced or read back by the previous round)."""
    t0 = time.perf_counter()
    out = fn(*args)
    out = jax.block_until_ready(out)
    return time.perf_counter() - t0, out


@contextlib.contextmanager
def trace_span(name: str):
    """Opt-in ``jax.profiler`` wrapping of one fused dispatch: when
    ``REPRO_TRACE=<dir>`` is set, the dispatch runs inside a profiler
    trace written under that directory (XLA/TensorBoard-level detail —
    complements, not replaces, the logical Chrome trace
    :mod:`repro.obs.trace` builds from telemetry).  A no-op otherwise,
    and degrades to a no-op if a trace is already active."""
    trace_dir = os.environ.get("REPRO_TRACE")
    if not trace_dir:
        yield
        return
    try:
        with jax.profiler.trace(os.path.join(trace_dir, name)):
            yield
    except RuntimeError:
        # A profiler session is already running (nested fused dispatch,
        # or the user armed their own) — observability must never turn
        # into a crash.
        yield


class PhaseProbe:
    """Host-side probe state: the enable switch plus the per-worker-fn
    calibration cache for fused attribution.

    ``calibrate_every`` is the re-calibration cadence in ROUNDS (not
    dispatches): fused blocks re-time the prefix programs only when the
    cached fractions are at least this stale, so steady-state overhead
    is the amortized cost of four extra dispatches per
    ``calibrate_every`` rounds plus two clock reads per block.
    """

    def __init__(self, *, enabled: bool = True,
                 calibrate_every: int = 512) -> None:
        self.enabled = bool(enabled)
        self.calibrate_every = max(int(calibrate_every), 1)
        self.rounds_attributed = 0
        self.calibrations = 0
        self._fractions: Dict[Any, np.ndarray] = {}
        self._cal_round: Dict[Any, int] = {}

    # -- calibration cache ---------------------------------------------------

    def needs_calibration(self, key: Any, rounds_run: int) -> bool:
        if key not in self._fractions:
            return True
        return rounds_run - self._cal_round[key] >= self.calibrate_every

    def store_calibration(self, key: Any, parts, rounds_run: int) -> None:
        """Cache phase fractions from raw per-phase seconds (clamped to
        >= 0 and normalized; a degenerate all-zero measurement falls back
        to a uniform split rather than NaN)."""
        parts = np.maximum(np.asarray(parts, dtype=np.float64), 0.0)
        total = float(parts.sum())
        if total <= 0.0:
            parts = np.full((len(PHASES),), 1.0 / len(PHASES))
        else:
            parts = parts / total
        self._fractions[key] = parts
        self._cal_round[key] = int(rounds_run)
        self.calibrations += 1

    def fractions(self, key: Any) -> np.ndarray:
        return self._fractions[key]

    # -- sample construction -------------------------------------------------

    def direct_sample(self, *, t_worker: float, t_exchange: float,
                      t_full: float, t_adaptive: float) -> PhaseSample:
        """Unfused attribution by subtraction of fenced prefix walls.
        Negative differences (clock noise on a near-empty phase) clamp
        to zero; the residual re-lands in ``splice`` so phases still sum
        to the measured total."""
        worker = max(t_worker, 0.0)
        exchange = max(t_exchange - t_worker, 0.0)
        adaptive = max(t_adaptive, 0.0)
        splice = max(t_full - worker - exchange, 0.0)
        self.rounds_attributed += 1
        return PhaseSample(worker_body=worker, exchange=exchange,
                           splice=splice, adaptive_update=adaptive,
                           total=worker + exchange + splice + adaptive,
                           estimated=False)

    def estimated_sample(self, key: Any, per_round_s: float,
                         n: int = 1) -> PhaseSample:
        """Fused attribution: one round's share of the dispatch wall,
        split by the cached calibration fractions.  Every round of one
        fused block gets the same attribution, so callers compute the
        sample ONCE and reuse it for all ``n`` rounds (keeps the probed
        read-back loop's Python cost per block, not per round)."""
        f = self.fractions(key)
        parts = [float(per_round_s) * float(f[i]) for i in range(len(PHASES))]
        self.rounds_attributed += int(n)
        return PhaseSample(worker_body=parts[0], exchange=parts[1],
                           splice=parts[2], adaptive_update=parts[3],
                           total=float(per_round_s), estimated=True)
