"""Chrome-trace / Perfetto export of one :class:`Telemetry` stream.

One telemetry object already carries the whole story of a run on a
single logical-round timeline: the master's rounds (with per-phase wall
splits when the probe was armed), the workload's waves, every served
request's admit -> first-token -> finish stamps, and the round-stamped
fault/detector event log.  This module renders that stream as standard
`Trace Event Format`_ JSON — load the file in ``chrome://tracing``,
https://ui.perfetto.dev or ``about:tracing`` and the rounds, phases,
waves, requests and failures line up on one zoomable timeline.

The clock is LOGICAL: one round occupies ``round_us`` microseconds of
trace time (default 1000 us = 1 ms per round), so traces from host,
vmap and mesh runs of the same schedule align event-for-event and are
directly diffable.  Within a probed round the phase children scale the
round span by their MEASURED fractions — so the picture shows real
relative cost (where did the round's wall go) on the deterministic
round grid.  Unprobed rounds render as bare round spans.

Emitted events (all standard phases, no extensions):

* ``X`` complete spans, pid 0 / tid 0: one ``round N`` per
  :class:`RoundRecord`, with nested ``worker_body`` / ``exchange`` /
  ``splice`` / ``adaptive_update`` children when the record is
  phase-timed (args carry steals, items moved, proportion, imbalance,
  and whether the split was estimated).
* ``X`` spans, pid 0 / tid 1: one ``wave N`` per :class:`WaveRecord`,
  spanning from the previous wave's closing round to its own (args:
  served, tokens, loads, SLO percentiles).
* ``b``/``n``/``e`` async events, pid 0 / tid 2, one series per
  request id: ``admit -> first_token -> finish`` (args: tokens, ttft
  and latency in rounds).
* ``i`` instant events, pid 0 / tid 0, one per :attr:`Telemetry.
  fault_log` entry — ``kill`` / ``revive`` / ``suspect`` /
  ``auto_kill`` / ``evict`` / ``straggler`` / ... at the round the
  event was recorded, lane-attributed in ``args``.

.. _Trace Event Format:
   https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

from repro.runtime.telemetry import Telemetry

__all__ = ["export_trace", "validate_trace"]

_PID = 0
_TID_ROUNDS = 0
_TID_WAVES = 1
_TID_REQUESTS = 2

# Phase child order must match repro.obs.phase.PHASES.
_PHASE_FIELDS = (("worker_body", "t_worker"), ("exchange", "t_exchange"),
                 ("splice", "t_splice"), ("adaptive_update", "t_adaptive"))


def _meta(name: str, tid: int, label: str) -> Dict[str, Any]:
    return {"ph": "M", "pid": _PID, "tid": tid, "name": name,
            "args": {"name": label}}


def _round_events(rec, round_us: float) -> List[Dict[str, Any]]:
    ts = rec.round * round_us
    events: List[Dict[str, Any]] = [{
        "ph": "X", "pid": _PID, "tid": _TID_ROUNDS, "ts": ts,
        "dur": round_us, "name": f"round {rec.round}", "cat": "round",
        "args": {
            "n_steals": rec.n_steals,
            "n_transferred": rec.n_transferred,
            "bytes_moved": rec.bytes_moved,
            "proportion": rec.proportion,
            "imbalance": rec.imbalance,
            "sizes_total": rec.sizes_total,
        },
    }]
    if not rec.phase_timed:
        return events
    events[0]["args"]["t_round_s"] = rec.t_round
    events[0]["args"]["phase_estimated"] = rec.phase_estimated
    total = rec.t_round or 1.0
    cursor = ts
    for name, field in _PHASE_FIELDS:
        dur = round_us * (getattr(rec, field) / total)
        events.append({
            "ph": "X", "pid": _PID, "tid": _TID_ROUNDS, "ts": cursor,
            "dur": dur, "name": name, "cat": "phase",
            "args": {"seconds": getattr(rec, field),
                     "estimated": rec.phase_estimated},
        })
        cursor += dur
    return events


def _wave_events(telemetry: Telemetry, round_us: float
                 ) -> List[Dict[str, Any]]:
    events = []
    prev_round = 0
    for w in telemetry.waves:
        # A wave recorded before round alignment existed (round == -1)
        # still renders: pin it one round wide at its index.
        end = w.round if w.round >= 0 else prev_round + 1
        start = min(prev_round, end)
        dur = max(end - start, 1) * round_us
        args = {"served": w.served, "tokens": w.tokens,
                "loads": list(w.loads), "evicted": w.evicted,
                "stragglers": w.stragglers, "migrated": w.migrated}
        if w.latency_p50 or w.ttft_p50:
            args.update(ttft_p50=w.ttft_p50, ttft_p95=w.ttft_p95,
                        latency_p50=w.latency_p50, latency_p95=w.latency_p95)
        events.append({
            "ph": "X", "pid": _PID, "tid": _TID_WAVES, "ts": start * round_us,
            "dur": dur, "name": f"wave {w.wave}", "cat": "wave",
            "args": args,
        })
        prev_round = end
    return events


def _request_events(telemetry: Telemetry, round_us: float
                    ) -> List[Dict[str, Any]]:
    events = []
    for r in telemetry.requests:
        name = f"request {r.rid}"
        common = {"pid": _PID, "tid": _TID_REQUESTS, "cat": "request",
                  "id": r.rid, "name": name}
        events.append({**common, "ph": "b", "ts": r.admit * round_us,
                       "args": {"tokens": r.tokens}})
        events.append({**common, "ph": "n", "ts": r.first * round_us,
                       "name": "first_token",
                       "args": {"ttft_rounds": r.ttft}})
        events.append({**common, "ph": "e", "ts": r.finish * round_us,
                       "args": {"latency_rounds": r.latency,
                                "tokens": r.tokens}})
    return events


def _fault_events(telemetry: Telemetry, round_us: float
                  ) -> List[Dict[str, Any]]:
    events = []
    for kind, lane, rnd in telemetry.fault_log:
        args: Dict[str, Any] = {"round": rnd}
        if lane >= 0:
            args["lane"] = lane
        events.append({
            "ph": "i", "pid": _PID, "tid": _TID_ROUNDS, "ts": rnd * round_us,
            "s": "p", "name": kind, "cat": "fault", "args": args,
        })
    return events


def export_trace(telemetry: Telemetry, path: Optional[str] = None, *,
                 round_us: float = 1000.0) -> Dict[str, Any]:
    """Render ``telemetry`` as a Chrome-trace dict (and write it as JSON
    when ``path`` is given).  ``round_us`` sets the logical clock: trace
    microseconds per round."""
    events: List[Dict[str, Any]] = [
        _meta("process_name", _TID_ROUNDS, "steal-runtime"),
        _meta("thread_name", _TID_ROUNDS, "rounds"),
        _meta("thread_name", _TID_WAVES, "waves"),
        _meta("thread_name", _TID_REQUESTS, "requests"),
    ]
    for rec in telemetry.rounds:
        events.extend(_round_events(rec, round_us))
    events.extend(_wave_events(telemetry, round_us))
    events.extend(_request_events(telemetry, round_us))
    events.extend(_fault_events(telemetry, round_us))
    trace = {"traceEvents": events, "displayTimeUnit": "ms",
             "otherData": {"clock": f"logical ({round_us} us per round)",
                           "summary": telemetry.summary(),
                           "phase_summary": telemetry.phase_summary()}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace


def validate_trace(trace: Dict[str, Any]) -> Dict[str, int]:
    """Structural check that ``trace`` is loadable Chrome-trace JSON:
    a ``traceEvents`` list whose entries all carry the mandatory
    ``ph``/``pid``/``ts`` fields (metadata events excepted for ``ts``),
    with matched async begin/end per request id.  Returns per-category
    event counts; raises ``ValueError`` on any violation — this is what
    the CI obs lane runs against the smoke trace."""
    if not isinstance(trace.get("traceEvents"), list):
        raise ValueError("trace has no traceEvents list")
    counts: Dict[str, int] = {}
    async_open: Dict[int, int] = {}
    for i, ev in enumerate(trace["traceEvents"]):
        for field in ("ph", "pid", "name"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        ph = ev["ph"]
        if ph not in ("M", "X", "b", "n", "e", "i"):
            raise ValueError(f"event {i} has unexpected phase {ph!r}")
        if ph != "M" and "ts" not in ev:
            raise ValueError(f"event {i} ({ph!r}) missing ts")
        if ph == "X" and ev.get("dur", -1.0) < 0:
            raise ValueError(f"event {i} X span missing/negative dur")
        if ph in ("b", "n", "e") and "id" not in ev:
            raise ValueError(f"event {i} async event missing id")
        if ph == "b":
            async_open[ev["id"]] = async_open.get(ev["id"], 0) + 1
        elif ph == "e":
            open_n = async_open.get(ev["id"], 0)
            if open_n <= 0:
                raise ValueError(f"event {i} ends async id {ev['id']} "
                                 f"with no open begin")
            async_open[ev["id"]] = open_n - 1
        counts[ev.get("cat", ph)] = counts.get(ev.get("cat", ph), 0) + 1
    dangling = {k: v for k, v in async_open.items() if v}
    if dangling:
        raise ValueError(f"unclosed async request events: {dangling}")
    return counts


# ---------------------------------------------------------------------------
# Smoke driver: a tiny seeded chaos drain + serve waves, one stream
# ---------------------------------------------------------------------------


def _smoke_telemetry() -> Telemetry:
    """A deterministic miniature of the full story in one telemetry
    stream: a 4-lane probed chaos drain (scheduled straggler window the
    detector converts into suspects, a scheduled kill, a live revive)
    with serve-style wave + request records layered on the same round
    clock."""
    import jax
    import jax.numpy as jnp

    from repro.core.policy import StealPolicy
    from repro.runtime.detector import DetectorPolicy
    from repro.runtime.executor import StealRuntime
    from repro.runtime.resilience import FaultPlan

    W, cap, items = 4, 64, 48
    rt = StealRuntime(
        W, cap, {"x": jax.ShapeDtypeStruct((), jnp.int32)},
        policy=StealPolicy(low_watermark=1, high_watermark=4),
        # Lane 1 straggles rounds 2..5 (-> detector suspects), lane 3
        # dies at round 6 (-> recovery superstep drains its ring).
        fault_plan=FaultPlan(kills=((3, 6),), delays=((1, 2, 3),)))
    rt.attach_detector(DetectorPolicy(suspect_after=2, dead_after=None))
    rt.attach_phase_probe(calibrate_every=4)
    # All work starts on lane 0: the drain IS the rebalance.
    rt.push(0, {"x": jnp.arange(items, dtype=jnp.int32)}, items)

    def body(q, carry):
        q, _, n = rt.ops.pop_bulk(q, 4, jnp.int32(2))
        return q, carry + n.astype(jnp.int32)

    admitted: List[int] = []
    for tick in range(6):
        rt.round(body)               # unfused: direct phase measurement
        rt.run_fused(2, body)        # fused: calibrated estimate
        if tick == 2:
            rt.revive_lane(3)
        # Serve layer on the same stream: admit one request per tick,
        # finish it two ticks later (stamps in logical rounds).
        admitted.append(rt.rounds_run)
        if tick >= 2:
            admit = admitted[tick - 2]
            rt.telemetry.record_request(rid=tick - 2, admit=admit,
                                        first=admit + 1,
                                        finish=rt.rounds_run, tokens=8)
        rt.telemetry.record_wave(loads=rt.sizes(), served=1 if tick >= 2
                                 else 0, tokens=8 if tick >= 2 else 0)
    return rt.telemetry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Export a Chrome trace from the repro steal runtime")
    ap.add_argument("--smoke", action="store_true",
                    help="run the built-in seeded chaos+serve drain and "
                         "export its trace")
    ap.add_argument("--out", default="trace.json",
                    help="output path (default trace.json)")
    ap.add_argument("--round-us", type=float, default=1000.0,
                    help="trace microseconds per logical round")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("only --smoke mode is runnable from the CLI; library "
                 "users call export_trace(telemetry, path)")
    tele = _smoke_telemetry()
    trace = export_trace(tele, args.out, round_us=args.round_us)
    counts = validate_trace(trace)
    print(f"wrote {args.out}: " + ", ".join(
        f"{v} {k}" for k, v in sorted(counts.items())))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
