"""repro.obs — observability for the steal runtime.

Four cooperating pieces (DESIGN.md §11):

* :mod:`repro.obs.phase` — per-round wall-clock attributed to
  ``worker_body`` / ``exchange`` / ``splice`` / ``adaptive_update`` via
  truncated-prefix re-execution (off by default; compile-identical and
  bit-identical when off).
* :mod:`repro.obs.trace` — Chrome-trace/Perfetto JSON export of one
  :class:`~repro.runtime.telemetry.Telemetry` stream: round spans with
  phase children, wave spans, per-request flows, fault/detector instant
  events on one timeline.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  Prometheus text exposition and JSON snapshots, fed by the telemetry,
  the failure detector, both admission masters and PagedQueue spill
  accounting.
* ``benchmarks/trend.py`` (outside the package, next to the BENCH
  history it reads) — perf-trend gating over the checked-in
  ``BENCH_*.json`` series.
"""

from repro.obs.metrics import (MetricsRegistry, master_metrics,  # noqa: F401
                               runtime_metrics)
from repro.obs.phase import PhaseProbe, PhaseSample  # noqa: F401
from repro.obs.trace import export_trace, validate_trace  # noqa: F401

__all__ = ["PhaseProbe", "PhaseSample", "MetricsRegistry",
           "runtime_metrics", "master_metrics", "export_trace",
           "validate_trace"]
