"""Metrics exposition: one registry, Prometheus text + JSON snapshot.

The runtime already measures everything a dashboard wants — per-round
steal counters and queue-depth statistics (:class:`~repro.runtime.
telemetry.Telemetry`), detector lane states (:class:`~repro.runtime.
detector.FailureDetector`), paging traffic (:class:`~repro.core.queue.
PagedQueue`), admission loads (both masters) — but each behind its own
Python surface.  This module is the thin exposition layer: a
:class:`MetricsRegistry` of counters / gauges / histograms, a family of
``collect_*`` functions that read those objects and set the current
values, and two renderings of the same registry:

* :meth:`MetricsRegistry.to_prometheus` — the standard `text exposition
  format`_ (``# HELP`` / ``# TYPE`` / ``name{labels} value``), suitable
  for a node-exporter textfile collector or a scrape endpoint;
* :meth:`MetricsRegistry.snapshot` — a plain JSON-able dict, what the
  CI obs lane schema-checks and the benchmark reports embed.

Collection is PULL-style and idempotent: calling a collector re-reads
the source object and overwrites the sample values, so a poller can
call ``runtime_metrics(rt)`` (or ``cluster.metrics()`` /
``run_resilient(metrics_path=...)``'s periodic textfile writes)
mid-run, at any cadence, without perturbing the run — no instrumentation
is threaded into the dispatch path.

.. _text exposition format:
   https://prometheus.io/docs/instrumenting/exposition_formats/
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "collect_telemetry", "collect_detector", "collect_runtime",
           "collect_paged_queue", "collect_master", "runtime_metrics",
           "master_metrics", "write_textfile"]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_labels(key: LabelKey, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._samples: Dict[LabelKey, float] = {}

    def _set(self, value: float, labels: Dict[str, Any]) -> None:
        self._samples[_label_key(labels)] = float(value)

    def samples(self) -> Dict[LabelKey, float]:
        return dict(self._samples)

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, value in sorted(self._samples.items()):
            lines.append(f"{self.name}{_render_labels(key)} {value:g}")
        return lines

    def snapshot(self) -> Any:
        if list(self._samples) == [()]:
            return self._samples[()]
        return {_render_labels(k) or "{}": v
                for k, v in sorted(self._samples.items())}


class Counter(_Metric):
    """Monotone total.  ``inc`` accumulates; collectors reading an
    external monotone source (e.g. ``telemetry.total_steals``) overwrite
    the absolute value with ``set_total`` instead."""

    kind = "counter"

    def inc(self, n: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        self._samples[key] = self._samples.get(key, 0.0) + float(n)

    def set_total(self, value: float, **labels) -> None:
        self._set(value, labels)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._set(value, labels)


class Histogram(_Metric):
    """Fixed-bucket cumulative histogram (Prometheus ``le`` semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Sequence[float] = (1, 2, 4, 8, 16, 32, 64, 128)):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sum: Dict[LabelKey, float] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        counts = self._counts.setdefault(key, [0] * len(self.buckets))
        for i, b in enumerate(self.buckets):
            if value <= b:
                counts[i] += 1
        self._sum[key] = self._sum.get(key, 0.0) + float(value)
        self._n[key] = self._n.get(key, 0) + 1

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._counts):
            for b, c in zip(self.buckets, self._counts[key]):
                le = 'le="%g"' % b
                lines.append(f"{self.name}_bucket"
                             f"{_render_labels(key, le)} {c}")
            inf = 'le="+Inf"'
            lines.append(f"{self.name}_bucket{_render_labels(key, inf)} "
                         f"{self._n[key]}")
            lines.append(f"{self.name}_sum{_render_labels(key)} "
                         f"{self._sum[key]:g}")
            lines.append(f"{self.name}_count{_render_labels(key)} "
                         f"{self._n[key]}")
        return lines

    def snapshot(self) -> Any:
        out = {_render_labels(k): {
            "buckets": dict(zip((f"{b:g}" for b in self.buckets),
                                self._counts[k])),
            "sum": self._sum[k], "count": self._n[k]}
            for k in sorted(self._counts)}
        # Same collapsing rule as scalar metrics: one unlabeled series
        # reads as its value directly.
        if set(out) == {""}:
            return out[""]
        return out


class MetricsRegistry:
    """A named collection of metrics with idempotent get-or-create
    accessors (collectors re-run against the same registry update values
    in place rather than redefining metrics)."""

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kwargs)
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = (1, 2, 4, 8, 16, 32, 64, 128)
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __iter__(self) -> Iterable[_Metric]:
        return iter(self._metrics.values())

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for m in self._metrics.values():
            lines.extend(m.expose())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        return {name: {"type": m.kind, "help": m.help,
                       "values": m.snapshot()}
                for name, m in sorted(self._metrics.items())}


def write_textfile(registry: MetricsRegistry, path: str) -> None:
    """Atomic textfile-collector write (tmp + rename, the node-exporter
    contract: a scraper never reads a half-written exposition)."""
    import os

    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(registry.to_prometheus())
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Collectors
# ---------------------------------------------------------------------------


def collect_telemetry(reg: MetricsRegistry, tele,
                      prefix: str = "repro") -> MetricsRegistry:
    """Read one :class:`~repro.runtime.telemetry.Telemetry` stream into
    ``reg``: lifetime round totals, the adaptive trajectory endpoints,
    wave/request SLO aggregates, fault-event counters and — on probed
    runs — the per-phase wall-clock attribution."""
    s = tele.summary()
    reg.counter(f"{prefix}_rounds_total",
                "rebalancing rounds recorded").set_total(s["rounds"])
    reg.counter(f"{prefix}_steals_total",
                "victim->thief transfers planned").set_total(s["steals"])
    reg.counter(f"{prefix}_items_transferred_total",
                "queue items moved by steals").set_total(
                    s["items_transferred"])
    reg.counter(f"{prefix}_bytes_moved_total",
                "exchange payload bytes (busiest lane)").set_total(
                    s["bytes_moved"])
    reg.gauge(f"{prefix}_steal_proportion",
              "current adaptive steal proportion").set(s["proportion_final"])
    reg.gauge(f"{prefix}_imbalance",
              "max/mean queue depth after the last round").set(
                  s["imbalance_final"])
    reg.counter(f"{prefix}_straggler_steps_total",
                "straggler boost steps applied").set_total(
                    s["straggler_steps"])
    faults = reg.counter(f"{prefix}_fault_events_total",
                         "resilience events by kind")
    for kind, n in tele.fault_events.items():
        faults.set_total(n, kind=kind)
    if tele.waves:
        reg.counter(f"{prefix}_waves_total",
                    "workload waves recorded").set_total(s["waves"])
        reg.counter(f"{prefix}_served_total",
                    "requests completed").set_total(s["served"])
        reg.counter(f"{prefix}_tokens_total",
                    "tokens generated").set_total(s["tokens"])
    if tele.requests:
        slo = reg.gauge(f"{prefix}_request_rounds",
                        "request SLO percentiles, in logical rounds")
        for metric in ("ttft", "latency"):
            for pct in ("p50", "p95", "p99"):
                slo.set(s[f"{metric}_{pct}"], metric=metric, quantile=pct)
        lat = reg.histogram(f"{prefix}_request_latency_rounds",
                            "admit->finish latency per request, in rounds")
        for r in tele.requests:
            lat.observe(r.latency)
    ps = tele.phase_summary()
    if ps["timed_rounds"]:
        reg.counter(f"{prefix}_phase_timed_rounds_total",
                    "rounds with phase attribution").set_total(
                        ps["timed_rounds"])
        reg.counter(f"{prefix}_phase_estimated_rounds_total",
                    "attributed rounds using calibrated estimates"
                    ).set_total(ps["estimated_rounds"])
        sec = reg.counter(f"{prefix}_phase_seconds_total",
                          "attributed wall seconds by round phase")
        frac = reg.gauge(f"{prefix}_phase_fraction",
                         "share of attributed wall by round phase")
        for name, agg in ps["phases"].items():
            sec.set_total(agg["total_s"], phase=name)
            frac.set(agg["fraction"], phase=name)
    return reg


def collect_detector(reg: MetricsRegistry, detector,
                     prefix: str = "repro") -> MetricsRegistry:
    """Lane-state census of one :class:`~repro.runtime.detector.
    FailureDetector` (healthy / suspected / dead counts plus the maximum
    live slow streak)."""
    states = detector.states()
    g = reg.gauge(f"{prefix}_detector_lanes",
                  "lanes per failure-detector state")
    for state in ("healthy", "suspected", "dead"):
        g.set(sum(1 for s in states if s == state), state=state)
    live_streaks = [detector.streak(w) for w in range(detector.n_lanes)
                    if states[w] != "dead"]
    reg.gauge(f"{prefix}_detector_max_slow_streak",
              "longest current consecutive-slow streak (live lanes)").set(
                  max(live_streaks) if live_streaks else 0)
    return reg


def collect_runtime(reg: MetricsRegistry, rt,
                    prefix: str = "repro") -> MetricsRegistry:
    """Poll one :class:`~repro.runtime.executor.StealRuntime` (or the
    mesh subclass): queue depths, dead lanes, compiled-program census,
    then its telemetry stream and attached detector."""
    sizes = rt.sizes()
    reg.gauge(f"{prefix}_queue_items",
              "live items across all lanes").set(int(sizes.sum()))
    reg.gauge(f"{prefix}_queue_items_max",
              "deepest lane").set(int(sizes.max()) if sizes.size else 0)
    reg.gauge(f"{prefix}_lanes", "queue lanes").set(rt.n_workers)
    reg.gauge(f"{prefix}_dead_lanes",
              "lanes currently dead in the fault schedule").set(
                  int(rt.dead_lanes().sum()))
    reg.gauge(f"{prefix}_compiled_programs",
              "entries in the round jit cache").set(len(rt._compiled))
    collect_telemetry(reg, rt.telemetry, prefix)
    if rt.detector is not None:
        collect_detector(reg, rt.detector, prefix)
    return reg


def collect_paged_queue(reg: MetricsRegistry, pq,
                        prefix: str = "repro_paged") -> MetricsRegistry:
    """Paging traffic of one :class:`~repro.core.queue.PagedQueue`: ring
    occupancy, host pages, and the spill/refill counters both ways."""
    reg.gauge(f"{prefix}_ring_items", "items in the device ring").set(
        int(pq.state.size))
    reg.gauge(f"{prefix}_host_pages", "overflow pages on host").set(
        len(pq.pages))
    reg.gauge(f"{prefix}_total_items",
              "ring + paged items").set(pq.total_size())
    reg.counter(f"{prefix}_spills_total",
                "host pages written").set_total(pq.spills)
    reg.counter(f"{prefix}_spilled_items_total",
                "items spilled to host").set_total(pq.spilled_items)
    reg.counter(f"{prefix}_refills_total",
                "host pages spliced back").set_total(pq.refills)
    reg.counter(f"{prefix}_refilled_items_total",
                "items refilled from host").set_total(pq.refilled_items)
    return reg


def collect_master(reg: MetricsRegistry, master,
                   prefix: str = "repro_serve") -> MetricsRegistry:
    """Admission-side view of either master (the host
    :class:`~repro.serve.scheduler.AdmissionMaster` or the device
    :class:`~repro.distributed.serve.RuntimeAdmissionMaster` — both
    expose the same ``replicas``/``stolen``/``proportion`` surface):
    per-replica load, eviction census, steal totals, plus the master's
    telemetry stream and detector when attached."""
    load = reg.gauge(f"{prefix}_replica_load",
                     "queued + in-flight requests per replica")
    queued = reg.gauge(f"{prefix}_replica_queued",
                       "queued requests per replica")
    completed = reg.counter(f"{prefix}_replica_completed_total",
                            "requests completed per replica")
    for r in master.replicas:
        rid = r.replica_id
        load.set(r.load(), replica=rid)
        queued.set(len(r.q), replica=rid)
        completed.set_total(r.completed, replica=rid)
    reg.gauge(f"{prefix}_evicted_replicas",
              "replicas currently evicted").set(
                  sum(1 for r in master.replicas if r.evicted))
    reg.counter(f"{prefix}_stolen_total",
                "requests moved by admission steals").set_total(
                    master.stolen)
    reg.gauge(f"{prefix}_proportion",
              "admission steal proportion").set(master.proportion)
    collect_telemetry(reg, master.telemetry, prefix)
    if getattr(master, "detector", None) is not None:
        collect_detector(reg, master.detector, prefix)
    return reg


# -- convenience entry points ------------------------------------------------


def runtime_metrics(rt, registry: Optional[MetricsRegistry] = None
                    ) -> MetricsRegistry:
    """One-call poll of a runtime: a fresh (or given) registry with
    :func:`collect_runtime` applied."""
    return collect_runtime(registry or MetricsRegistry(), rt)


def master_metrics(master, registry: Optional[MetricsRegistry] = None
                   ) -> MetricsRegistry:
    """One-call poll of an admission master (host or device)."""
    return collect_master(registry or MetricsRegistry(), master)
