"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak FLOP/s)
    memory term     = HLO_bytes / (chips x HBM bw)
    collective term = collective wire bytes / (chips x link bw)

``cost_analysis()`` on a GSPMD-compiled executable reports the PER-DEVICE
program's flops/bytes, so the "/ chips" division is already implicit —
we document both conventions and report per-device terms directly.

Collective bytes are NOT in cost_analysis: we parse the optimized HLO
text and apply ring-algorithm wire formulas per op:

    all-gather(result R bytes, group g):     R * (g-1)/g         received
    reduce-scatter(operand O bytes, group g): O * (g-1)/g        sent
    all-reduce(operand O bytes, group g):    2 * O * (g-1)/g     (RS + AG)
    all-to-all(operand O bytes, group g):    O * (g-1)/g
    collective-permute(operand O bytes):     O

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per direction).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes",
           "normalize_cost_analysis"]

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

def normalize_cost_analysis(ca) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    jax <= 0.4.30 returns a flat dict, jax 0.4.31+ (incl. 0.4.37) returns
    a *list* with one dict per program, and either may be ``None``/empty.
    Returns one flat dict (numeric values summed across programs) so
    callers can ``.get("flops", 0)`` unconditionally.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    out: Dict[str, float] = {}
    for entry in ca:  # list/tuple of per-program dicts
        for k, v in (entry or {}).items():
            if isinstance(v, (int, float)) and k in out:
                out[k] += v
            else:
                out[k] = v
    return out


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "e4m3": 1,
    "e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "f32[16,128]{1,0}"  or "bf16[2,4,8]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    bpe = _DTYPE_BYTES.get(dt)
    if bpe is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * bpe


def _result_bytes(line: str) -> int:
    """Sum byte sizes of the op's result (handles tuple results)."""
    # result type is between '=' and the op name
    try:
        lhs, rhs = line.split(" = ", 1)
    except ValueError:
        return 0
    # rhs starts with the type, e.g. "f32[8,16]{1,0} all-gather(" or
    # "(f32[8], f32[8]) all-reduce("
    ty = rhs.split(")", 1)[0] + ")" if rhs.startswith("(") else rhs.split(" ", 1)[0]
    return sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(ty))


def _operand_bytes(line: str) -> int:
    """Sum byte sizes of the operands (typed operand list in parens)."""
    # operands appear as  opname(f32[..] %x, bf16[..] %y, ...)
    m = re.search(r"\w[\w-]*\(([^)]*)\)", line.split(" = ", 1)[-1])
    if not m:
        return 0
    return sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(m.group(1)))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:  # replica_groups=[g,n] — iota form: n groups... format [num_groups, group_size]?
        a, b = int(m.group(1)), int(m.group(2))
        return b if b > 0 else default
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip() != ""]
        if ids:
            return len(ids)
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Estimated per-device wire bytes by collective kind (ring algorithm),
    for ONE execution of the program."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start)?\(", ls):
                kind = k
                break
        if kind is None or ls.startswith("ROOT tuple") or f"{kind}-done" in ls:
            continue
        g = _group_size(ls, n_devices)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            out[kind] += _result_bytes(ls) * frac
        elif kind == "reduce-scatter":
            out[kind] += _operand_bytes(ls) * frac
        elif kind == "all-reduce":
            out[kind] += 2.0 * _operand_bytes(ls) * frac
        elif kind == "all-to-all":
            out[kind] += _operand_bytes(ls) * frac
        else:  # collective-permute
            out[kind] += _operand_bytes(ls)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6*N*D analytic (global)
    useful_ratio: float           # model_flops / (flops_per_device * chips)
    peak_memory_bytes: int        # from memory_analysis
    argument_bytes: int
    output_bytes: int
    temp_bytes: int

    def terms(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s}


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, model_flops: float,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    ca = normalize_cost_analysis(compiled.cost_analysis())
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text, n_devices)

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll["total"] / ICI_BW
    bn = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]

    ma = compiled.memory_analysis()
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=coll["total"],
        collective_breakdown={k: v for k, v in coll.items() if k != "total"},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bn, model_flops=model_flops,
        useful_ratio=(model_flops / (flops * n_devices)) if flops else 0.0,
        peak_memory_bytes=int(getattr(ma, "temp_size_in_bytes", 0))
        + int(getattr(ma, "argument_size_in_bytes", 0))
        + int(getattr(ma, "output_size_in_bytes", 0))
        - int(getattr(ma, "alias_size_in_bytes", 0)),
        argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
    )
