"""End-to-end training driver.

On this CPU container it trains REDUCED configs for real (--preset smoke);
the same driver lowers the FULL assigned configs on the production mesh
(--preset full, TPU target).  Fault tolerance is on by default: atomic
checkpoints every --ckpt-every steps, SIGTERM-triggered final save,
restart-from-latest via train.fault.run_supervised, straggler-aware
work-stealing data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import WorkStealingPipeline
from repro.data.synthetic import synth_batch
from repro.models import build_model
from repro.train import checkpoint as ckpt_lib
from repro.train.fault import GracefulExit, StragglerMonitor, run_supervised
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def build(arch: str, preset: str):
    cfg = configs.get(arch)
    if preset == "smoke":
        cfg = configs.reduced(cfg)
    return cfg, build_model(cfg)


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, model = build(args.arch, args.preset)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=max(args.steps, 1))
    train_step = jax.jit(make_train_step(model, opt_cfg,
                                         microbatch=args.microbatch))

    pipeline = WorkStealingPipeline(
        n_hosts=1,
        make_batch=lambda shard, step: synth_batch(
            args.seed, shard, step, args.batch, args.seq, cfg.vocab_size),
    )

    def run(resume) -> int:
        params = model.init(jax.random.PRNGKey(args.seed))
        opt = adamw_init(params)
        start = 0
        data_state = {"step": 0}
        if args.ckpt_dir and (resume is not None
                              or ckpt_lib.latest_step(args.ckpt_dir)):
            try:
                (params, opt), start, extra = ckpt_lib.restore(
                    args.ckpt_dir, (params, opt))
                data_state = extra.get("data", data_state)
                print(f"[train] resumed from step {start}")
            except FileNotFoundError:
                pass

        mon = StragglerMonitor()
        with GracefulExit() as stop:
            for step in range(start, args.steps):
                mon.start()
                raw = pipeline.next_batch(0)
                if cfg.family == "vlm":
                    npatch = cfg.n_patches
                    batch = {
                        "tokens": jnp.asarray(raw["tokens"]),
                        "labels": jnp.asarray(raw["labels"]),
                        "patches": jnp.zeros(
                            (args.batch, npatch, cfg.frontend_dim),
                            jnp.float32),
                    }
                elif cfg.family == "encdec":
                    batch = {
                        "frames": jnp.ones(
                            (args.batch, args.seq, cfg.frontend_dim),
                            jnp.float32),
                        "tokens": jnp.asarray(raw["tokens"]),
                        "labels": jnp.asarray(raw["labels"]),
                    }
                else:
                    batch = {"tokens": jnp.asarray(raw["tokens"]),
                             "labels": jnp.asarray(raw["labels"])}
                params, opt, metrics = train_step(params, opt, batch)
                mon.observe()
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(f"[train] step {step} "
                          f"loss {float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e}")
                if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0
                                      or stop.requested
                                      or step == args.steps - 1):
                    ckpt_lib.save(args.ckpt_dir, step + 1, (params, opt),
                                  extra={"data": pipeline.queues[0].q and
                                         {"step": step + 1}})
                if stop.requested:
                    print("[train] SIGTERM: checkpointed and exiting")
                    return step + 1
        print(f"[train] done at step {args.steps}; "
              f"pipeline stats {pipeline.stats()}")
        return args.steps

    return run_supervised(run, max_restarts=2)


if __name__ == "__main__":
    main()
