"""Serving driver: N replicas + bulk-steal admission master.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --replicas 2 --requests 24

``--decode`` switches from the wave engine to the continuous-batching
decode subsystem (:mod:`repro.serve.decode`): per-round admission,
paged KV, real ``decode_step`` execution inside the steal runtime.

  PYTHONPATH=src python -m repro.launch.serve --decode \
      --execution vmap --replicas 4 --requests 32 --steal queue
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve.engine import Replica, ServeCluster
from repro.serve.scheduler import AdmissionMaster, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=list(configs.ARCH_IDS))
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--straggle", action="store_true",
                    help="make replica 0 slow to show bulk-steal rebalancing")
    ap.add_argument("--decode", action="store_true",
                    help="continuous-batching decode engine instead of waves")
    ap.add_argument("--execution", default="vmap",
                    choices=["host", "vmap", "mesh"],
                    help="(--decode) where the rebalancing master runs")
    ap.add_argument("--steal", default="queue", choices=["queue", "migrate"],
                    help="(--decode) steal only KV-free queued requests, or "
                         "also migrate in-flight sequences with their pages")
    args = ap.parse_args(argv)

    cfg = configs.reduced(configs.get(args.arch))
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve demo targets decoder-family archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    if args.decode:
        from repro.serve.decode import DecodeCluster, DecodePolicy

        pol = DecodePolicy(n_slots=4, max_prompt=8,
                           max_new=max(args.max_new, 1), steal=args.steal)
        cluster = DecodeCluster(model, params, policy=pol,
                                n_lanes=args.replicas,
                                execution=args.execution)
        reqs = [Request(prompt=list(rng.integers(
                            1, cfg.vocab_size,
                            size=int(rng.integers(1, 9)))),
                        max_new=int(rng.integers(1, args.max_new + 1)))
                for _ in range(args.requests)]
        t0 = time.time()
        cluster.submit(reqs)
        done = cluster.run_until_drained()
        dt = time.time() - t0
        st = cluster.stats()
        toks = sum(len(r.output or []) for r in done)
        tele = st["telemetry"]
        print(f"[serve.decode] {len(done)}/{args.requests} requests, "
              f"{toks} tokens in {dt:.1f}s ({args.execution}, "
              f"steal={args.steal})")
        print(f"[serve.decode] stolen={st['stolen']} "
              f"migrated={st['migrated']} stalls={st['stalls']} "
              f"ttft_p99={tele.get('ttft_p99', 0.0):.1f} "
              f"latency_p99={tele.get('latency_p99', 0.0):.1f} rounds")
        assert len(done) == args.requests
        return 0

    reps = [Replica(model, params, wave_size=4, max_seq=64)
            for _ in range(args.replicas)]
    if args.straggle and reps:
        reps[0].speed = 0.25
    cluster = ServeCluster(reps, AdmissionMaster(args.replicas))

    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, size=8)),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.time()
    cluster.submit(reqs)
    done = cluster.run_until_drained()
    dt = time.time() - t0
    st = cluster.master.stats()
    toks = sum(len(r.output or []) for r in done)
    print(f"[serve] {len(done)}/{args.requests} requests, {toks} tokens "
          f"in {dt:.1f}s")
    print(f"[serve] per-replica completed={st['completed']} "
          f"stolen={st['stolen']} rounds={st['rounds']}")
    assert len(done) == args.requests
    return 0


if __name__ == "__main__":
    main()
