"""Production mesh construction.  A FUNCTION (not a module-level constant)
so importing this module never touches jax device state."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_worker_mesh", "CHIPS_PER_POD"]

CHIPS_PER_POD = 256  # 16 x 16 TPU v5e pod


def make_worker_mesh(n_workers: int, *, pod_size: int | None = None,
                     axis_name: str = "workers", pod_axis: str = "pods"):
    """A queue-worker mesh for the distributed executor: one device per
    queue lane along ``axis_name`` (flat), or a 2-D ``(pod_axis,
    axis_name)`` mesh of ``n_workers // pod_size`` pods when
    ``pod_size`` is set (hierarchical supersteps: cheap ICI within a
    pod, one representative block across pods).  The axis names default
    to the executors' defaults so a
    :class:`~repro.distributed.MeshStealRuntime` built on this mesh is
    collective-compatible with the vmapped :class:`~repro.runtime.
    StealRuntime` worker bodies (same names resolve either way).

    Uses the first ``n_workers`` process devices (like
    :func:`make_production_mesh`, oversubscribed hosts just leave the
    tail idle); raises when the process exposes fewer.
    """
    import numpy as np

    devices = jax.devices()
    if len(devices) < n_workers:
        raise ValueError(
            f"make_worker_mesh(n_workers={n_workers}) needs at least that "
            f"many devices; this process has {len(devices)} (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_workers} "
            f"before jax initializes to fake them on CPU)")
    if pod_size is None:
        return jax.sharding.Mesh(np.asarray(devices[:n_workers]),
                                 (axis_name,))
    if n_workers % pod_size != 0:
        raise ValueError(
            f"n_workers={n_workers} not divisible by pod_size={pod_size}")
    shape = (n_workers // pod_size, pod_size)
    return jax.sharding.Mesh(
        np.asarray(devices[:n_workers]).reshape(shape),
        (pod_axis, axis_name))


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod; (pod=2, data=16, model=16) two pods.

    When the process exposes more host devices than the mesh needs (the
    dry-run forces 512), the single-pod mesh uses the first 256.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) > n:
        import numpy as np

        return jax.sharding.Mesh(
            np.asarray(devices[:n]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)
