"""Production mesh construction.  A FUNCTION (not a module-level constant)
so importing this module never touches jax device state."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "CHIPS_PER_POD"]

CHIPS_PER_POD = 256  # 16 x 16 TPU v5e pod


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod; (pod=2, data=16, model=16) two pods.

    When the process exposes more host devices than the mesh needs (the
    dry-run forces 512), the single-pod mesh uses the first 256.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) > n:
        import numpy as np

        return jax.sharding.Mesh(
            np.asarray(devices[:n]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)
