import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: measure (arch, shape) under named variants and
report the three roofline terms side by side.

  PYTHONPATH=src python -m repro.launch.perf \
      --arch qwen3-moe-30b-a3b --shape train_4k \
      --variants baseline,ep_moe,bf16_master,ep+bf16 \
      --out results/perf_qwen3_train.json
"""

import argparse
import json
from typing import Any, Dict

VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    "ep_moe": {"moe_impl": "ep_shardmap"},
    "bf16_master": {"param_dtype": "bfloat16"},
    "ep+bf16": {"moe_impl": "ep_shardmap", "param_dtype": "bfloat16"},
    "flash_decode": {"decode_impl": "flash_shardmap"},
    "no_steal": {"moe_bulk_steal": False},
    "no_remat": {"remat": False},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    rows = []
    for name in args.variants.split(","):
        variant = VARIANTS[name]
        try:
            r = run_cell(args.arch, args.shape, args.multi_pod,
                         verbose=False, unroll_costs=True,
                         variant=variant or None)
            r["variant"] = name
            rows.append(r)
            rt = r["roofline"]
            cb = r["collectives"]
            print(f"[{name:12s}] c/m/x = "
                  f"{rt['compute_s']*1e3:9.1f} / {rt['memory_s']*1e3:9.1f} / "
                  f"{rt['collective_s']*1e3:9.1f} ms   "
                  f"peak {r['memory_analysis']['peak_bytes']/2**30:6.2f} GiB  "
                  f"ag/ar/rs/a2a/cp MB = "
                  + "/".join(f"{cb.get(k,0)/2**20:.0f}" for k in
                             ("all-gather", "all-reduce", "reduce-scatter",
                              "all-to-all", "collective-permute")),
                  flush=True)
        except Exception as e:
            print(f"[{name:12s}] FAILED: {type(e).__name__}: {e}", flush=True)
            rows.append({"variant": name, "status": "error",
                         "error": str(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
