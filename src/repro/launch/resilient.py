"""Crash-and-preemption-safe runtime driving.

:func:`run_resilient` is the glue the resilience layer promises:
periodic queue snapshots (``StealRuntime.attach_snapshots`` — atomic,
elastic, at round boundaries only), SIGTERM/SIGINT handled as a final
snapshot + clean exit (:class:`repro.train.fault.GracefulExit`), and
crash recovery via :func:`repro.train.fault.run_supervised` — an
unhandled exception rebuilds the runtime, restores the latest snapshot
(bit-identical queue state; the checkpoint re-shards onto whatever
devices the replacement process has) and resumes the drive loop.

The CLI is a demonstration/chaos harness::

  PYTHONPATH=src python -m repro.launch.resilient \
      --workers 8 --items 2000 --snapshot-dir /tmp/steal_snap \
      --simulate-crash-at 6

kills the process's drive loop at round 6 on the first attempt, then
shows the supervised restart resuming from the last snapshot and
draining to completion.
"""

from __future__ import annotations

import argparse
from typing import Callable, Optional

from repro.train import checkpoint as ckpt_lib
from repro.train.fault import GracefulExit, run_supervised

__all__ = ["run_resilient"]


def run_resilient(make_runtime: Callable[[], "object"],
                  drive: Callable[["object", Callable[[], bool]], int], *,
                  snapshot_dir: str,
                  snapshot_every: int = 8,
                  keep: int = 3,
                  max_restarts: int = 3,
                  on_restart: Optional[Callable] = None,
                  metrics_path: Optional[str] = None,
                  metrics_every_s: float = 1.0) -> int:
    """Run ``drive(runtime, should_stop)`` under snapshot + restart
    supervision.

    Args:
      make_runtime: builds a FRESH runtime (called once per attempt —
        after a crash the old device state is gone by assumption).
      drive: the workload loop; called with the runtime and a
        ``should_stop()`` callable that turns True on SIGTERM/SIGINT —
        check it between rounds and return early for a graceful exit
        (a final snapshot is written either way).  Must return an int
        (e.g. rounds run / items processed).
      snapshot_dir / snapshot_every / keep: snapshot cadence, forwarded
        to ``attach_snapshots``; on (re)start the LATEST snapshot under
        ``snapshot_dir`` is restored when one exists, so a new process
        pointed at the same directory resumes where the dead one left
        off.
      max_restarts / on_restart: forwarded to ``run_supervised``.
      metrics_path / metrics_every_s: when ``metrics_path`` is set, the
        ``should_stop`` callable the drive loop already polls between
        rounds ALSO refreshes a Prometheus textfile there (atomic
        tmp+rename via :func:`repro.obs.metrics.write_textfile`,
        throttled to at most one write per ``metrics_every_s``) — the
        standard node-exporter textfile-collector contract, so a live
        run is scrapable with zero changes to the drive loop.  A final
        write lands after the loop exits.
    """

    def attempt(resume) -> int:
        rt = make_runtime()
        rt.attach_snapshots(snapshot_dir, every=snapshot_every, keep=keep)
        if ckpt_lib.latest_step(snapshot_dir) is not None:
            rt.restore_state(snapshot_dir)
            if resume is not None:
                rt.telemetry.record_fault("restart")

        def write_metrics() -> None:
            from repro.obs.metrics import write_textfile

            write_textfile(rt.metrics(), metrics_path)

        with GracefulExit() as stop:
            if metrics_path is None:
                should_stop = lambda: stop.requested  # noqa: E731
            else:
                import time as _time

                last = [float("-inf")]

                def should_stop() -> bool:
                    now = _time.monotonic()
                    if now - last[0] >= metrics_every_s:
                        last[0] = now
                        write_metrics()
                    return stop.requested

            result = drive(rt, should_stop)
            # A graceful exit's final state may postdate the last cadence
            # snapshot; save it so the NEXT process resumes exactly here.
            rt.save_state(snapshot_dir, keep=keep)
            if metrics_path is not None:
                write_metrics()
        return result

    return run_supervised(attempt, max_restarts=max_restarts,
                          on_restart=on_restart)


def main(argv: Optional[list] = None) -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.policy import StealPolicy
    from repro.runtime import FaultPlan, StealRuntime

    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=1024)
    ap.add_argument("--items", type=int, default=2000)
    ap.add_argument("--snapshot-dir", required=True)
    ap.add_argument("--snapshot-every", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-crash-at", type=int, default=0,
                    help="raise mid-drive at this round on attempt 0")
    ap.add_argument("--metrics-path", default=None,
                    help="write a Prometheus textfile here between rounds "
                         "(atomic; node-exporter textfile collector format)")
    ap.add_argument("--metrics-every-s", type=float, default=1.0)
    args = ap.parse_args(argv)

    crashed = {"done": False}

    def make_runtime():
        rt = StealRuntime(args.workers, args.capacity,
                          {"x": jax.ShapeDtypeStruct((), jnp.int32)},
                          policy=StealPolicy(),
                          fault_plan=FaultPlan())
        if ckpt_lib.latest_step(args.snapshot_dir) is None:
            rng = np.random.default_rng(args.seed)
            split = rng.multinomial(args.items,
                                    np.ones(args.workers) / args.workers)
            base = 0
            for w, n in enumerate(split):
                if n:
                    rt.push(w, {"x": jnp.arange(base, base + int(n),
                                                dtype=jnp.int32)}, int(n))
                base += int(n)
        return rt

    def drive(rt, should_stop) -> int:
        ops = rt.ops

        def worker(q, carry):
            # Toy worker: consume up to 4 items per lane per round.
            q, _batch, n = ops.pop_bulk(q, 4, jnp.int32(4))
            return q, carry + n

        for r in range(args.rounds):
            if should_stop():
                print(f"[resilient] graceful stop at round {rt.rounds_run}")
                break
            if (args.simulate_crash_at and not crashed["done"]
                    and rt.rounds_run >= args.simulate_crash_at):
                crashed["done"] = True
                raise RuntimeError(
                    f"simulated crash at round {rt.rounds_run}")
            rt.round(worker)
            if rt.total_size() == 0:
                break
        print(f"[resilient] rounds_run={rt.rounds_run} "
              f"remaining={rt.total_size()} "
              f"faults={rt.telemetry.fault_events}")
        return rt.rounds_run

    rounds = run_resilient(make_runtime, drive,
                           snapshot_dir=args.snapshot_dir,
                           snapshot_every=args.snapshot_every,
                           metrics_path=args.metrics_path,
                           metrics_every_s=args.metrics_every_s)
    print(f"[resilient] finished after {rounds} global rounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
