"""Generate the EXPERIMENTS.md §Dry-run and §Roofline sections from the
dry-run JSON, plus the §Observability section from the obs gate bench.

  PYTHONPATH=src python -m repro.launch.report results/dryrun.json \
      [BENCH_PR10.json]

The observability section renders only when its BENCH file exists
(second argument, default ``BENCH_PR10.json``) — per-phase wall split
across execution modes and the probe-contract gate results.
"""

from __future__ import annotations

import json
import os
import sys
from typing import List

GiB = 2 ** 30


def _f(x, nd=1):
    return f"{x:.{nd}f}"


def dryrun_section(results: List[dict]) -> str:
    lines = [
        "## §Dry-run",
        "",
        "Every (arch × shape × mesh) cell `.lower().compile()`d with",
        "ShapeDtypeStruct inputs (no allocation). `mem/dev` is",
        "`memory_analysis()` peak per device (arguments + temps + outputs −",
        "aliased); the fit budget is TPU v5e's 16 GiB HBM. Collective",
        "volumes are per-device wire bytes (ring formulas over the parsed",
        "optimized HLO; table in §Roofline). Multi-pod cells prove the",
        '"pod" axis shards (DP across pods, params replicated per pod,',
        "grads all-reduced over DCN once per step).",
        "",
        "| arch | shape | mesh | compile s | args GiB | temps GiB | out GiB | peak GiB | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR: "
                f"{r.get('error', '?')[:60]} | | | | | |")
            continue
        ma = r["memory_analysis"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']} | {_f(ma['argument_bytes']/GiB, 2)} | "
            f"{_f(ma['temp_bytes']/GiB, 2)} | "
            f"{_f(ma['output_bytes']/GiB, 2)} | "
            f"{_f(ma['peak_bytes']/GiB, 2)} | "
            f"{'Y' if ma.get('fits_16g') else 'N'} |")
    ok = sum(1 for r in results if r.get("status") == "ok")
    lines += ["", f"**{ok}/{len(results)} cells compile.**", ""]
    return "\n".join(lines)


def roofline_section(results: List[dict]) -> str:
    lines = [
        "## §Roofline",
        "",
        "Single-pod (16×16 = 256 chips) per-device terms, from a fully",
        "UNROLLED second lowering of each cell (XLA's `cost_analysis()`",
        "counts `while` bodies once, so the scanned program would",
        "undercount ~n_layers-fold — see launch/dryrun.py).",
        "",
        "- compute = HLO_FLOPs/dev ÷ 197 TF/s · memory = HLO_bytes/dev ÷",
        "  819 GB/s · collective = wire_bytes/dev ÷ 50 GB/s/link.",
        "- `useful` = MODEL_FLOPS (6·N·D train / 2·N·D inference,",
        "  N_active for MoE) ÷ (HLO_FLOPs × 256). The gap is attention",
        "  quadratics, remat recompute, and the blocked-attention 2×",
        "  causal waste.",
        "- CAVEAT: HLO_bytes comes from the CPU-backend cost model, which",
        "  reflects much weaker fusion than TPU codegen — treat the memory",
        "  term as an unfused UPPER bound and a relative metric between",
        "  variants, not a TPU wall-clock prediction.",
        "",
        "| arch | shape | compute ms | memory ms | collective ms | bottleneck | useful | ag/ar/rs/a2a/cp MB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        rt = r["roofline"]
        cb = r.get("collectives", {})
        mb = "/".join(
            f"{cb.get(k, 0)/2**20:.0f}"
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_f(rt['compute_s']*1e3)} | "
            f"{_f(rt['memory_s']*1e3)} | {_f(rt['collective_s']*1e3)} | "
            f"{r['bottleneck']} | {_f(r['useful_ratio'], 3)} | {mb} |")
    lines.append("")
    return "\n".join(lines)


def obs_section(bench: dict) -> str:
    """§Observability from a BENCH_PR10-schema dict (benchmarks/run.py
    --obs): the phase-probe gate results and the per-phase wall split
    per execution mode."""
    obs = bench["obs_overhead"]
    lines = [
        "## §Observability",
        "",
        "Phase-probe contract on the fused Fig. 9 drain (DESIGN.md §11):",
        "overhead is the median paired probed/unprobed wall ratio;",
        "bit-identity and compile-identity are exact checks.",
        "",
        f"- probe overhead {obs['probe_overhead']:.3f}x"
        f" (budget < {obs['overhead_limit']:g}x)"
        f" — gates {'ALL PASS' if obs['gates_ok'] else 'FAILING'}:"
        f" {', '.join(k for k, v in obs['gates'].items() if not v) or 'none failing'}",
        "",
        "| mode | rounds | worker_body | exchange | splice | adaptive |",
        "|---|---|---|---|---|---|",
    ]
    for mode, d in obs.get("phase_breakdown", {}).items():
        fr = d.get("phases", {})
        cells = " | ".join(
            f"{fr[p]['fraction']:.0%}" if p in fr else "-"
            for p in ("worker_body", "exchange", "splice",
                      "adaptive_update"))
        lines.append(f"| {mode} | {d['timed_rounds']} | {cells} |")
    lines.append("")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print(dryrun_section(results))
    print()
    print(roofline_section(results))
    obs_path = sys.argv[2] if len(sys.argv) > 2 else "BENCH_PR10.json"
    if os.path.exists(obs_path):
        with open(obs_path) as f:
            print()
            print(obs_section(json.load(f)))


if __name__ == "__main__":
    main()
