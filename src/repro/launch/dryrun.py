import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves (a) the sharding config is coherent (GSPMD
partitions without error), (b) the per-device program fits HBM
(memory_analysis), and (c) yields the roofline terms (cost_analysis +
collective-bytes parsing) recorded in EXPERIMENTS.md.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count on first init.  Only this entrypoint forces 512 host
devices; smoke tests and benchmarks see the real device count.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_compiled
from repro.models import build_model
from repro.models.zoo import input_specs
from repro.train.optimizer import AdamWConfig, adamw_init, opt_state_specs
from repro.train.trainer import make_train_step

Pytree = Any


def _ns(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train / 2*N*D inference (N_active for
    MoE; D = tokens processed)."""
    n = cfg.param_count()
    if cfg.n_experts:
        expert_p = 3 * cfg.d_model * cfg.d_ff_expert
        n -= cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert_p
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, parallel):
    """Build + lower + compile one cell; returns (compiled, lowered)."""
    model = build_model(cfg, parallel)
    batch_sds, batch_ps = input_specs(cfg, shape, parallel)
    pspecs = model.param_specs()
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_ns = _ns(mesh, pspecs)
    batch_ns = _ns(mesh, batch_ps)

    if shape.kind == "train":
        mw = cfg.param_dtype == "bfloat16"  # master-weights mixed precision
        opt_sds = jax.eval_shape(
            lambda p: adamw_init(p, master_weights=mw), params_sds)
        opt_ns = _ns(mesh, opt_state_specs(pspecs, master_weights=mw))
        step = make_train_step(model, AdamWConfig(master_weights=mw))
        jitted = jax.jit(step,
                         in_shardings=(param_ns, opt_ns, batch_ns),
                         out_shardings=(param_ns, opt_ns, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)

    elif shape.kind == "prefill":
        cache_ns = _ns(mesh, model.cache_specs(shape.seq_len,
                                               shape.global_batch))

        if cfg.family == "encdec":
            def step(params, batch):
                return model.prefill(params, batch["frames"], batch["tokens"])
        elif cfg.family == "vlm":
            def step(params, batch):
                return model.prefill(params, batch["tokens"], batch["patches"])
        else:
            def step(params, batch):
                return model.prefill(params, batch["tokens"])

        jitted = jax.jit(step,
                         in_shardings=(param_ns, batch_ns),
                         out_shardings=(None, cache_ns))
        lowered = jitted.lower(params_sds, batch_sds)

    else:  # decode
        B = shape.global_batch
        if cfg.family == "encdec":
            cache_sds = jax.eval_shape(
                lambda: model.make_cache(B, shape.seq_len, shape.seq_len))
        else:
            cache_sds = jax.eval_shape(
                lambda: model.make_cache(B, shape.seq_len))
        cache_ns = _ns(mesh, model.cache_specs(shape.seq_len, B))

        def step(params, cache, tokens):
            return model.decode_step(params, cache, tokens)

        jitted = jax.jit(step,
                         in_shardings=(param_ns, cache_ns,
                                       batch_ns["tokens"]),
                         out_shardings=(None, cache_ns),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_sds, cache_sds,
                               batch_sds["tokens"])

    compiled = lowered.compile()
    return compiled, lowered


def _cost_points(cfg: ModelConfig):
    """Small layer counts for the two/three-point cost extrapolation.

    Returns (points, combine) where ``combine(costs_by_L) -> scale dict``
    reconstructs the full-depth cost from the small unrolled variants:
    costs are linear in the layer count for homogeneous stacks, so
    f(L) = base + L_units * per_unit.
    """
    import dataclasses as dc

    L = cfg.n_layers
    if cfg.family == "hybrid" and cfg.attn_every:
        g = cfg.attn_every
        n_groups = L // g
        tail = L - n_groups * g
        pts = [g, 2 * g] + ([g + tail] if tail else [])

        def combine(f):
            per_group = _sub(f[2 * g], f[g])
            base = _sub(f[g], per_group)
            total = _add(base, _mul(per_group, n_groups))
            if tail:
                per_tail = _sub(f[g + tail], f[g])
                total = _add(total, per_tail)
            return total

        def make(n):
            return dc.replace(cfg, n_layers=n)
        return pts, combine, make

    group = 2 if cfg.local_global_every else 1
    pts = [group * 1, group * 2] if group > 1 else [2, 4]

    def combine(f):
        span = pts[1] - pts[0]
        per_layer = _mul(_sub(f[pts[1]], f[pts[0]]), 1.0 / span)
        base = _sub(f[pts[0]], _mul(per_layer, pts[0]))
        return _add(base, _mul(per_layer, L))

    def make(n):
        import dataclasses as dc
        if cfg.family == "encdec":
            return dc.replace(cfg, n_layers=n, n_encoder_layers=n)
        return dc.replace(cfg, n_layers=n)

    if cfg.family == "encdec":
        # enc and dec scale together: f(s) = base + s*(enc+dec); full has
        # Le = Ld = L so the same linear fit applies.
        pass
    return pts, combine, make


def _cost_dict(compiled, hlo, n_devices):
    from repro.launch.roofline import collective_bytes, normalize_cost_analysis

    ca = normalize_cost_analysis(compiled.cost_analysis())

    d = {"flops": float(ca.get("flops", 0.0)),
         "bytes": float(ca.get("bytes accessed", 0.0))}
    d.update({f"coll:{k}": v
              for k, v in collective_bytes(hlo, n_devices).items()})
    return d


def _sub(a, b):
    return {k: a[k] - b.get(k, 0.0) for k in a}


def _add(a, b):
    return {k: a.get(k, 0.0) + b.get(k, 0.0) for k in set(a) | set(b)}


def _mul(a, s):
    return {k: v * s for k, v in a.items()}


def extrapolated_costs(cfg: ModelConfig, shape, mesh, parallel,
                       n_devices: int) -> Dict[str, float]:
    """Exact-by-linearity cost accounting: compile small FULLY-UNROLLED
    variants (inner scans — MoE chunks, KV blocks, loss chunks — unroll
    too) and extrapolate to the full depth.  Bounds every cost compile to
    a few layers instead of unrolling 64-81 layer stacks."""
    from repro.models import layers as layers_mod

    pts, combine, make = _cost_points(cfg)
    f = {}
    try:
        layers_mod.set_scan_unroll(True)
        for n in pts:
            small = make(n)
            compiled, _ = lower_cell(small, shape, mesh, parallel)
            f[n] = _cost_dict(compiled, compiled.as_text(), n_devices)
    finally:
        layers_mod.set_scan_unroll(False)
    total = combine(f)
    return {k: max(v, 0.0) for k, v in total.items()}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, unroll_costs: bool = True,
             variant: Dict[str, Any] | None = None) -> Dict[str, Any]:
    import dataclasses as _dc

    from repro.models import layers as layers_mod
    from repro.launch import roofline as rf

    cfg = configs.get(arch)
    if variant:
        cfg = _dc.replace(cfg, **variant)
    shape = next(s for s in configs.SHAPES if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_devices = mesh.size
    parallel = ParallelConfig(pod_axis="pod" if multi_pod else None)

    t0 = time.time()
    with mesh:
        # Pass 1 — production (scanned) program: proves sharding coherence
        # and gives the honest memory_analysis.
        layers_mod.set_scan_unroll(False)
        compiled, lowered = lower_cell(cfg, shape, mesh, parallel)
        ma = compiled.memory_analysis()
        # Pass 2 — cost accounting via small unrolled variants: XLA's
        # cost_analysis counts while-loop bodies ONCE, so the scanned
        # program undercounts FLOPs/bytes/collectives ~n_layers-fold;
        # fully unrolling the assigned depths is compile-prohibitive, so
        # costs are extrapolated linearly in depth (exact for the
        # homogeneous stacks used here).
        if unroll_costs:
            costs = extrapolated_costs(cfg, shape, mesh, parallel, n_devices)
        else:
            costs = _cost_dict(compiled, compiled.as_text(), n_devices)

        flops = costs["flops"]
        byts = costs["bytes"]
        coll_total = costs.get("coll:total", 0.0)
        mf = model_flops_for(cfg, shape)
        compute_s = flops / rf.PEAK_FLOPS
        memory_s = byts / rf.HBM_BW
        collective_s = coll_total / rf.ICI_BW
        bottleneck = max([("compute", compute_s), ("memory", memory_s),
                          ("collective", collective_s)],
                         key=lambda kv: kv[1])[0]
    dt = time.time() - t0

    peak = (int(ma.argument_size_in_bytes) + int(ma.output_size_in_bytes)
            + int(ma.temp_size_in_bytes) - int(ma.alias_size_in_bytes))
    result = {
        "arch": arch, "shape": shape.name, "mesh": mesh_name,
        "status": "ok", "compile_s": round(dt, 1),
        "memory_analysis": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": peak,
            "fits_16g": peak <= 16 * 2 ** 30,
        },
        "cost_analysis": {
            "flops_per_device": flops,
            "bytes_per_device": byts,
        },
        "collectives": {k[5:]: v for k, v in costs.items()
                        if k.startswith("coll:") and k != "coll:total"},
        "collective_bytes_per_device": coll_total,
        "roofline": {"compute_s": compute_s, "memory_s": memory_s,
                     "collective_s": collective_s},
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": (mf / (flops * n_devices)) if flops else 0.0,
    }
    if verbose:
        print(f"[{arch} x {shape.name} x {mesh_name}] compile={dt:.1f}s "
              f"mem(arg/temp/out)={ma.argument_size_in_bytes/2**30:.2f}/"
              f"{ma.temp_size_in_bytes/2**30:.2f}/"
              f"{ma.output_size_in_bytes/2**30:.2f} GiB  "
              f"terms(c/m/x)={compute_s*1e3:.2f}/{memory_s*1e3:.2f}/"
              f"{collective_s*1e3:.2f} ms  bottleneck={bottleneck}",
              flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--no-unroll", action="store_true",
                    help="skip the unrolled cost pass (faster, undercounts)")
    ap.add_argument("--moe-impl", default=None,
                    choices=["gspmd", "ep_shardmap"],
                    help="override MoE dispatch impl (perf variant)")
    ap.add_argument("--param-dtype", default=None,
                    choices=["float32", "bfloat16"],
                    help="override param dtype (bf16 => master weights)")
    ap.add_argument("--moe-bulk-steal", default=None, choices=["on", "off"],
                    help="override the bulk-steal rebalancing (ablation)")
    args = ap.parse_args()

    variant: Dict[str, Any] = {}
    if args.moe_impl:
        variant["moe_impl"] = args.moe_impl
    if args.param_dtype:
        variant["param_dtype"] = args.param_dtype
    if args.moe_bulk_steal:
        variant["moe_bulk_steal"] = args.moe_bulk_steal == "on"

    archs = list(configs.ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    failures = 0

    def _flush():
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    for arch in archs:
        cfg = configs.get(arch)
        cells = configs.cells_for(cfg)
        shapes = ([s.name for s in cells] if args.shape == "all"
                  else [args.shape])
        for shape_name in shapes:
            if shape_name not in [s.name for s in cells]:
                print(f"[{arch} x {shape_name}] SKIP (inapplicable; see "
                      f"DESIGN.md long_500k rule)")
                continue
            for mp in meshes:
                # The roofline table (§Roofline) is single-pod only, so the
                # expensive unrolled cost pass runs only there; multi-pod
                # cells prove sharding coherence + memory fit.
                unroll = (not args.no_unroll) and not mp
                try:
                    results.append(run_cell(arch, shape_name, mp,
                                            unroll_costs=unroll,
                                            variant=variant or None))
                except Exception as e:  # record the failure, keep sweeping
                    failures += 1
                    traceback.print_exc()
                    results.append({
                        "arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                    })
                _flush()
    _flush()
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n== dry-run complete: {ok} ok / {failures} failed "
          f"-> {args.out} ==")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
