"""Mamba2 (SSD — state-space duality) blocks, pure-jnp reference path.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): split the sequence
into chunks of length Q; within a chunk the output is an attention-like
masked matmul (maps to the MXU), across chunks a small recurrence over the
per-chunk states (hd x ns per head) propagates history.  The inter-chunk
scan is O(S/Q) sequential steps on (nh, hd, ns) states — the TPU-native
replacement for the CUDA selective-scan kernel (see DESIGN.md §2).

``kernels/ssd_scan`` implements the intra-chunk block as a Pallas kernel
(VMEM-tiled); this module is the lowering/compile reference and the CPU
path, and is what the dry-run exercises.

Decode is O(1): state update + readout per token.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ShardPlan, dense_init, rms_norm, shard, pscan

Pytree = Any

__all__ = ["SSMConfig", "ssm_init", "ssd_chunked", "mamba_block", "mamba_decode_step"]


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int          # d_inner // head_dim
    head_dim: int
    state: int            # N — SSM state size
    conv_dim: int         # depthwise causal conv width
    chunk: int            # SSD chunk length


def ssm_init(key, L: int, cfg: SSMConfig, dtype) -> Pytree:
    """Projections for [z, x, B, C, dt] kept as SEPARATE weights (instead of
    mamba's packed in_proj) so each output dim gets a clean TP sharding with
    no packed-slice resharding; depthwise conv split per stream likewise."""
    di, ns, nh = cfg.d_inner, cfg.state, cfg.n_heads
    ks = jax.random.split(key, 9)
    return {
        "w_z": dense_init(ks[0], (L, cfg.d_model, di), dtype),
        "w_x": dense_init(ks[1], (L, cfg.d_model, di), dtype),
        "w_B": dense_init(ks[2], (L, cfg.d_model, ns), dtype),
        "w_C": dense_init(ks[3], (L, cfg.d_model, ns), dtype),
        "w_dt": dense_init(ks[4], (L, cfg.d_model, nh), dtype),
        "conv_x": dense_init(ks[5], (L, cfg.conv_dim, di), dtype, scale=0.5),
        "conv_B": dense_init(ks[6], (L, cfg.conv_dim, ns), dtype, scale=0.5),
        "conv_C": dense_init(ks[7], (L, cfg.conv_dim, ns), dtype, scale=0.5),
        "A_log": jnp.zeros((L, nh), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((L, nh), jnp.float32),
        "dt_bias": jnp.zeros((L, nh), jnp.float32),
        "out_proj": dense_init(ks[8], (L, di, cfg.d_model), dtype),
        "gate_norm": jnp.ones((L, di), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4): unrolled taps fuse into one kernel
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, D: jnp.ndarray,
                chunk: int,
                init_state: jnp.ndarray | None = None,
                sh: ShardPlan | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan, structured as ONE scan over chunks.

    x:  (B, S, nh, hd)    dt: (B, S, nh) (softplus'd, >0)
    A:  (nh,) (negative)  Bm/Cm: (B, S, ns)   D: (nh,)
    Returns (y (B,S,nh,hd), final_state (B,nh,hd,ns)).

    The inter-chunk state recurrence is inherently sequential, so the whole
    algorithm is expressed as a single ``lax.scan`` over chunks carrying the
    (B, nh, hd, ns) state; the intra-chunk (Q, Q)-masked block then only
    ever materializes ONE chunk's (B, Q, Q, nh) tensor, which shards over
    (dp × tp) to ~tens of MB per device instead of the ~85 TB a fully
    parallel formulation would need for the assigned mamba2 train cell.
    """
    Bsz, S, nh, hd = x.shape
    ns = Bm.shape[-1]
    Q = chunk
    f32 = jnp.float32
    sh = sh or ShardPlan()

    # Ragged tail: pad S up to a chunk multiple with dt = 0 — zero dt means
    # zero state contribution and exp(0) = 1 decay, so the final state is
    # exact; padded y rows are sliced off.
    S_orig = S
    if S % Q:
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    # (nc, B, Q, ...) scan layouts.
    xq = jnp.moveaxis(x.reshape(Bsz, nc, Q, nh, hd), 1, 0).astype(f32)
    dtq = jnp.moveaxis(dt.reshape(Bsz, nc, Q, nh), 1, 0).astype(f32)
    Bq = jnp.moveaxis(Bm.reshape(Bsz, nc, Q, ns), 1, 0).astype(f32)
    Cq = jnp.moveaxis(Cm.reshape(Bsz, nc, Q, ns), 1, 0).astype(f32)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, inp):
        xc, dtc, Bc, Cc = inp                   # (B,Q,nh,hd) (B,Q,nh) (B,Q,ns)
        dA = dtc * A[None, None, :]             # (B,Q,nh), <= 0
        cs = jnp.cumsum(dA, axis=1)
        seg_end = cs[:, -1, :]                  # (B,nh)

        # intra-chunk: L[i,j,h] = exp(cs_i - cs_j) for j <= i
        diff = cs[:, :, None, :] - cs[:, None, :, :]       # (B,Q,Q,nh)
        Lmat = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        G = jnp.einsum("bin,bjn->bij", Cc, Bc)             # (B,Q,Q)
        M = G[..., None] * Lmat * dtc[:, None, :, :]       # (B,Q,Q,nh)
        M = shard(M, sh.dp, None, None, sh.tp)
        y_intra = jnp.einsum("bijh,bjhd->bihd", M, xc)

        # inter-chunk: contribution of the carried state, then update it.
        y_inter = jnp.einsum("bin,bhdn,bih->bihd",
                             Cc, state, jnp.exp(cs))
        decay_to_end = jnp.exp(seg_end[:, None, :] - cs)   # (B,Q,nh)
        st_c = jnp.einsum("bjn,bjh,bjhd->bhdn", Bc, dtc * decay_to_end, xc)
        new_state = state * jnp.exp(seg_end)[:, :, None, None] + st_c
        new_state = shard(new_state, sh.dp, sh.tp, None, None)

        y = y_intra + y_inter + xc * D[None, None, :, None]
        return new_state, y

    st0 = (jnp.zeros((Bsz, nh, hd, ns), f32)
           if init_state is None else init_state.astype(f32))
    final, ys = pscan(chunk_step, st0, (xq, dtq, Bq, Cq))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, nh, hd)[:, :S_orig]
    return y.astype(x.dtype), final


def mamba_block(p: Pytree, x: jnp.ndarray, cfg: SSMConfig, sh: ShardPlan,
                compute_dtype) -> jnp.ndarray:
    """One Mamba2 block (pre-norm residual handled by caller).

    x: (B, S, D) -> (B, S, D). p leaves are per-layer (no L dim).
    """
    B, S, D = x.shape
    di, nh, hd, ns = cfg.d_inner, cfg.n_heads, cfg.head_dim, cfg.state
    xc = x.astype(compute_dtype)
    cd = compute_dtype
    z = jnp.einsum("bsd,dk->bsk", xc, p["w_z"].astype(cd))
    xs = jnp.einsum("bsd,dk->bsk", xc, p["w_x"].astype(cd))
    Bm = jnp.einsum("bsd,dn->bsn", xc, p["w_B"].astype(cd))
    Cm = jnp.einsum("bsd,dn->bsn", xc, p["w_C"].astype(cd))
    dt = jnp.einsum("bsd,dh->bsh", xc, p["w_dt"].astype(cd))
    xs = shard(xs, sh.dp, None, sh.tp)

    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"].astype(cd)))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"].astype(cd)))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"].astype(cd)))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xs = shard(xs.reshape(B, S, nh, hd), sh.dp, None, sh.tp, None)

    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, p["D"], cfg.chunk, sh=sh)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = jnp.einsum("bsk,kd->bsd", y.astype(compute_dtype),
                     p["out_proj"].astype(compute_dtype))
    return shard(out, sh.dp, None, None)


# ---------------------------------------------------------------------------
# O(1) decode
# ---------------------------------------------------------------------------


class SSMCache(NamedTuple):
    """conv_buf: (B, K-1, conv_ch) last inputs; state: (B, nh, hd, ns)."""

    conv_buf: jnp.ndarray
    state: jnp.ndarray


def mamba_decode_step(p: Pytree, x: jnp.ndarray, cache: SSMCache,
                      cfg: SSMConfig, sh: ShardPlan, compute_dtype
                      ) -> Tuple[jnp.ndarray, SSMCache]:
    """x: (B, 1, D) -> (B, 1, D); O(1) state update (the reason ssm/hybrid
    archs run the long_500k cell)."""
    B, _, D = x.shape
    di, nh, hd, ns = cfg.d_inner, cfg.n_heads, cfg.head_dim, cfg.state
    K = cfg.conv_dim
    cd = compute_dtype
    xc = x[:, 0].astype(cd)                               # (B, D)
    z = jnp.einsum("bd,dk->bk", xc, p["w_z"].astype(cd))
    xs = jnp.einsum("bd,dk->bk", xc, p["w_x"].astype(cd))
    Bm = jnp.einsum("bd,dn->bn", xc, p["w_B"].astype(cd))
    Cm = jnp.einsum("bd,dn->bn", xc, p["w_C"].astype(cd))
    dt = jnp.einsum("bd,dh->bh", xc, p["w_dt"].astype(cd))

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)      # (B, conv_ch)
    window = jnp.concatenate([cache.conv_buf, conv_in[:, None, :]], axis=1)
    w = jnp.concatenate(                                  # (K, conv_ch)
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1).astype(cd)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))
    xs, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + ns],
                  conv_out[..., di + ns:])
    new_conv_buf = window[:, 1:, :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])  # (B,nh)
    A = -jnp.exp(p["A_log"])                              # (nh,)
    dA = jnp.exp(dt * A[None, :])                         # (B,nh)
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    dBx = jnp.einsum("bn,bh,bhd->bhdn", Bm.astype(jnp.float32),
                     dt, xh)
    state = cache.state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhdn->bhd", Cm.astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = jnp.einsum("bk,kd->bd", y.astype(compute_dtype),
                     p["out_proj"].astype(compute_dtype))
    return out[:, None, :], SSMCache(conv_buf=new_conv_buf, state=state)
