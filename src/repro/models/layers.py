"""Shared building blocks: sharding helpers, norms, embeddings, MLPs.

All models are pure functions over parameter pytrees (dicts of jnp arrays).
Scanned layer stacks carry a leading ``(L, ...)`` dimension.  Sharding is
expressed through :func:`shard`, which applies a
``with_sharding_constraint`` only when a mesh context is active — so the
exact same model code runs un-annotated on a bare CPU (smoke tests) and
fully sharded under the production mesh (dry-run / launcher).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any

__all__ = [
    "shard",
    "axes",
    "ShardPlan",
    "rms_norm",
    "softcap",
    "dense_init",
    "embed_init",
    "mlp_init",
    "mlp_apply",
    "cross_entropy",
    "chunked_ce_loss",
]


# Roofline accounting mode: XLA's cost_analysis counts a while-loop body
# ONCE, not x trip-count, so scanned-layer FLOPs/bytes/collectives would be
# undercounted ~n_layers-fold.  The dry-run sets this True to lower a fully
# unrolled variant purely for cost extraction (the scanned program remains
# the production/memory artifact).
SCAN_UNROLL = False


def set_scan_unroll(flag: bool) -> None:
    global SCAN_UNROLL
    SCAN_UNROLL = bool(flag)


def pscan(body, init, xs, length=None):
    """lax.scan honoring the roofline unroll switch."""
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if SCAN_UNROLL else 1)


def _active_mesh():
    """The mesh installed by ``with mesh:`` (pjit's resource env), if any."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - accessor moved
        return None


def shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """``with_sharding_constraint(x, P(*spec))`` under an active mesh;
    identity otherwise.  Entries naming axes absent from the active mesh
    are dropped (so single-pod and multi-pod share one model code path)."""
    m = _active_mesh()
    if m is None:
        return x
    names = set(m.axis_names)

    def _filter(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    return jax.lax.with_sharding_constraint(x, P(*[_filter(e) for e in spec]))


class ShardPlan:
    """Named axis roles for a parallelism plan (see configs.ParallelConfig).

    dp:   batch axes (tuple — includes the pod axis on multi-pod meshes)
    tp:   tensor-parallel axis (heads / d_ff / vocab / experts / seq-SP)
    fsdp: parameter-sharding axis (None => replicated params, pure DP)
    """

    def __init__(self, dp: Tuple[str, ...] = ("data",), tp: str = "model",
                 fsdp: Optional[str] = "data"):
        self.dp, self.tp, self.fsdp = tuple(dp), tp, fsdp

    @classmethod
    def from_parallel(cls, par) -> "ShardPlan":
        return cls(dp=par.batch_axes, tp=par.model_axis, fsdp=par.fsdp_axis)


# Default plan used when models are called without explicit plan.
axes = ShardPlan()


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token CE; logits in fp32 for a stable softmax."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_ce_loss(hidden: jnp.ndarray, head: jnp.ndarray,
                    labels: jnp.ndarray, mask: Optional[jnp.ndarray],
                    sh: "ShardPlan", *, final_softcap: Optional[float] = None,
                    chunk: int = 512, remat: bool = True) -> jnp.ndarray:
    """LM head + CE in sequence chunks so (B, S, V) never materializes.

    hidden: (B, S, D); head: (D, V).  V can be 256k: a chunk's logits are
    (B, chunk, V) f32, sharded over (dp, -, tp).
    """
    from repro.models.layers import softcap as _softcap  # self-import ok

    B, S, D = hidden.shape
    nchunk = max(S // chunk, 1)
    while S % nchunk:           # nchunk must divide S (e.g. vlm's S=3840)
        nchunk -= 1
    csz = S // nchunk
    hs = jnp.moveaxis(hidden.reshape(B, nchunk, csz, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nchunk, csz), 1, 0)
    ms = (jnp.moveaxis(mask.reshape(B, nchunk, csz), 1, 0).astype(jnp.float32)
          if mask is not None
          else jnp.ones((nchunk, B, csz), jnp.float32))

    def chunk_loss(carry, inp):
        h, l, m = inp
        logits = jnp.einsum("bsd,dv->bsv", h, head)
        logits = _softcap(logits, final_softcap)
        logits = shard(logits, sh.dp, None, sh.tp)
        lo = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lo, axis=-1)
        gold = jnp.take_along_axis(lo, l[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(m)), None

    body = chunk_loss
    if remat:
        body = jax.checkpoint(
            chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = pscan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# initializers (smoke-test scale only; dry-run uses eval_shape)
# ---------------------------------------------------------------------------


def dense_init(key, shape: Sequence[int], dtype, scale: float = 0.02) -> jnp.ndarray:
    return (jax.random.normal(key, tuple(shape), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def mlp_init(key, L: int, d_model: int, d_ff: int, dtype) -> Pytree:
    """SwiGLU MLP, stacked over L layers."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (L, d_model, d_ff), dtype),
        "w_up": dense_init(k2, (L, d_model, d_ff), dtype),
        "w_down": dense_init(k3, (L, d_ff, d_model), dtype),
    }


def mlp_apply(p: Pytree, x: jnp.ndarray, sh: ShardPlan, compute_dtype) -> jnp.ndarray:
    """SwiGLU: down(silu(gate(x)) * up(x)). p leaves are per-layer (no L dim)."""
    x = x.astype(compute_dtype)
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(compute_dtype))
    h = shard(jax.nn.silu(h) * u, sh.dp, None, sh.tp)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(compute_dtype))
    return shard(out, sh.dp, None, None)
